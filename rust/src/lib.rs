//! # sparse-mezo
//!
//! Production reproduction of **"Sparse MeZO: Less Parameters for Better
//! Performance in Zeroth-Order LLM Fine-Tuning"** (Liu et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! This crate is **Layer 3**: the training coordinator. It owns the data
//! pipeline, the ZO training loop, seed management, evaluation, sweeps,
//! checkpointing, metrics and the experiment harness that regenerates every
//! table and figure of the paper. The compute itself — model forward passes
//! and the functional optimizer steps (Layer 2 JAX, with the Layer 1 Pallas
//! fused mask+perturb kernels inside) — was AOT-lowered to HLO text by
//! `python/compile/aot.py` and is executed through the PJRT C API (the
//! `xla` crate). Python never runs at training time.
//!
//! ## Module map
//! * [`util`] — hand-rolled substrates (JSON, TOML-subset config, CLI,
//!   counter PRNG mirroring the Python/Pallas one, logging, stats).
//! * [`runtime`] — PJRT client, artifact manifest, typed executables,
//!   device-resident packed training state.
//! * [`data`] — vocabulary, synthetic SuperGLUE-analog task generators,
//!   pretraining corpus, batcher.
//! * [`config`] — presets (models, tasks, optimizers) + experiment plans.
//! * [`zo`] — a pure-Rust MLP + every ZO optimizer variant, used as a
//!   property-testing substrate and cross-check (no PJRT needed).
//! * [`coordinator`] — trainer, evaluator, LR schedules, sweeps,
//!   convergence tracking, the Fig-2b/4 generalization probe, memory
//!   model (Table 4), checkpoints, experiment registry, report rendering.
//! * [`bench`] — the timing harness used by `cargo bench` targets.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod util;
pub mod zo;

/// Crate-wide result alias (anyhow is the only error dependency).
pub type Result<T> = anyhow::Result<T>;
