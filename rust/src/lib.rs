//! # sparse-mezo
//!
//! Production reproduction of **"Sparse MeZO: Less Parameters for Better
//! Performance in Zeroth-Order LLM Fine-Tuning"** (Liu et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! This crate is **Layer 3**: the training coordinator. It owns the data
//! pipeline, the ZO training loop, seed management, evaluation, sweeps,
//! checkpointing, metrics and the experiment harness that regenerates every
//! table and figure of the paper. Compute routes through a pluggable
//! [`runtime::backend::Backend`]:
//!
//! * the **native** pure-Rust backend (default) serves the whole CLI
//!   offline — no artifacts, no Python, no network;
//! * the **pjrt** backend (cargo feature `pjrt`) executes the model
//!   forward passes and functional optimizer steps that were AOT-lowered
//!   to HLO text by `python/compile/aot.py` (Layer 2 JAX, with the Layer 1
//!   Pallas fused mask+perturb kernels inside) through the PJRT C API.
//!   Python never runs at training time.
//!
//! ## Module map
//! * [`util`] — hand-rolled substrates (JSON, TOML-subset config, CLI,
//!   counter PRNG mirroring the Python/Pallas one, logging, stats).
//! * [`runtime`] — the backend trait, the native and PJRT backends, the
//!   artifact manifest, and the packed training state.
//! * [`data`] — vocabulary, synthetic SuperGLUE-analog task generators,
//!   pretraining corpus, batcher.
//! * [`config`] — presets (models, tasks, optimizers) + experiment plans.
//! * [`zo`] — a pure-Rust MLP + every ZO optimizer variant, used as a
//!   property-testing substrate and cross-check (no backend needed).
//! * [`coordinator`] — trainer, evaluator, LR schedules, parallel sweeps,
//!   convergence tracking, the Fig-2b/4 generalization probe, memory
//!   model (Table 4), checkpoints, experiment registry, report rendering.
//! * [`parallel`] — the worker pool (the crate's one scheduler), the
//!   seed-sync data-parallel trainer, sharded evaluation, and the
//!   step-exchange protocol + replayable journal.
//! * [`serve`] — sparse-delta adapters (extract/certify/swap/save), the
//!   multi-tenant adapter registry, dynamic micro-batching over the
//!   worker pool, and the std-only HTTP loopback server behind the
//!   `serve` subcommand.
//! * [`jobs`] — train-to-serve orchestration: the persistent async
//!   fine-tuning job queue, the cooperative slice scheduler over the
//!   worker pool (checkpoint/resume through the step journal), and
//!   auto-publication of finished adapters into the serve registry.
//! * [`obs`] — crate-wide observability: the process-wide metrics
//!   registry (atomic counters/gauges, log-bucket latency histograms),
//!   the span-timing API, the optional JSONL trace stream, and the
//!   Prometheus text exposition behind `GET /metrics`.
//! * [`bench`] — the timing harness used by `cargo bench` targets.

#![warn(missing_docs)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod jobs;
pub mod obs;
pub mod parallel;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod zo;

/// Crate-wide result alias (anyhow is the only error dependency).
pub type Result<T> = anyhow::Result<T>;
