//! The default pure-Rust compute backend.
//!
//! Implements the full [`Backend`](super::backend::Backend) surface with a
//! host-memory model so the whole CLI — `train`, `sweep`, `probe`,
//! `repro`, `memory-table` — runs offline with no AOT artifacts and no
//! PJRT. The model is a **bag-of-embeddings MLP classifier** over the
//! shared 512-token vocabulary:
//!
//! ```text
//!   x   = RMS-norm( recency-weighted mean of embed.tok[token] )   [D]
//!   h   = tanh( g1 ⊙ (x · W1) )                                   [H]
//!   y_c = g2_c ⊙ (h · W2)                                         [V]
//! ```
//!
//! It deliberately mirrors the ABI of the exported transformer programs:
//! the same flat-parameter layout discipline (matrix entries maskable,
//! vector entries always dense, PRNG stream id == layout-entry index),
//! the same packed `[params | slots | metrics]` step state, the same
//! 8-slot hyper vector and metric tail, and the *same counter PRNG* — so
//! every optimizer's seed-replay walk (paper Alg. 1–3) exercises exactly
//! the code paths the coordinator uses against PJRT, and the property /
//! integration suites validate real optimizer semantics (mask support,
//! sparsity-0 degeneracy, seed-replay restoration, divergence at large
//! LR) end to end.
//!
//! Masking follows the paper: S-MeZO keeps coordinates with
//! `|theta| <= h_entry` (dynamic — recomputed from the current parameters
//! every step, nothing stored), `smezo_large` inverts the mask (Fig. 2c),
//! `smezo_const` stores a sign-encoded mask in its slot block (the §3.3
//! vanilla ablation that pays the extra memory), and R-MeZO draws a
//! Bernoulli mask from the `mask_seed` hyper.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::util::prng;
use crate::zo::optim::percentile_threshold;

use super::backend::Backend;
use super::exec::Hypers;
use super::manifest::{LayoutEntry, Manifest, ModelInfo, ProgramInfo};
use super::state::{StateBuf, TrainState};
use super::store::{Overlay, ParamStore};

/// Metric-tail layout (mirrors `Manifest::metric_names` order).
const M_L_PLUS: usize = 0;
const M_L_MINUS: usize = 1;
const M_PROJ_GRAD: usize = 2;
const M_MASKED_FRAC: usize = 3;
const M_UPDATE_NORM_SQ: usize = 4;
const M_TRAIN_LOSS: usize = 5;
const M_ACCEPT: usize = 6;
/// Metric slot count `K`.
const N_METRICS: usize = 8;

/// RMS-norm epsilon for the pooled feature vector.
const RMS_EPS: f32 = 1e-6;

/// The native backend: a synthesized manifest plus the host-memory model.
pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    /// Build the backend with its synthesized model registry.
    pub fn new() -> NativeBackend {
        NativeBackend { manifest: native_manifest() }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// manifest synthesis
// ---------------------------------------------------------------------------

/// Step-program optimizers the native backend implements, with their
/// optimizer-slot counts as a function of (P = params, A = adapters).
fn native_optimizers(p: usize, a: usize) -> Vec<(&'static str, usize)> {
    vec![
        ("mezo", 0),
        ("smezo", 0),
        ("smezo_large", 0),
        ("smezo_const", p), // stored mask: the §3.3 vanilla ablation
        ("rmezo", 0),
        ("zo_sign", 0),
        ("zo_cons", 0),
        ("zo_mom", p),
        ("zo_adam", 2 * p + 1),
        ("zo_adamu", 2 * p + 1),
        ("mezo_lora", a),
        ("lora_fo", 3 * a + 1),
        ("fo_sgd", 0),
        ("fo_adam", 2 * p + 1),
    ]
}

/// Assemble one synthesized model entry.
#[allow(clippy::too_many_arguments)]
fn native_model(
    name: &str,
    family: &str,
    size: &str,
    d: usize,
    h: usize,
    n_layers: usize,
    d_ff: usize,
    window: usize,
) -> ModelInfo {
    let v = crate::data::vocab::SIZE;
    let r = 4usize; // LoRA rank
    let seq_len = 32;
    let batch = 16;

    let sizes = [v * d, d * h, h, h * v, v];
    let names = ["embed.tok", "mlp.w1", "mlp.g1", "mlp.w2", "mlp.g2"];
    let shapes: [Vec<usize>; 5] =
        [vec![v, d], vec![d, h], vec![h], vec![h, v], vec![v]];
    let kinds = ["matrix", "matrix", "vector", "matrix", "vector"];
    let mut layout = Vec::with_capacity(5);
    let mut off = 0usize;
    for i in 0..5 {
        layout.push(LayoutEntry {
            name: names[i].to_string(),
            shape: shapes[i].clone(),
            kind: kinds[i].to_string(),
            offset: off,
            size: sizes[i],
            layer_id: i,
        });
        off += sizes[i];
    }
    let n_params = off;

    let a_sizes = [d * r, r * h];
    let a_names = ["lora.a", "lora.b"];
    let a_shapes: [Vec<usize>; 2] = [vec![d, r], vec![r, h]];
    let mut lora_layout = Vec::with_capacity(2);
    let mut a_off = 0usize;
    for i in 0..2 {
        lora_layout.push(LayoutEntry {
            name: a_names[i].to_string(),
            shape: a_shapes[i].clone(),
            kind: "matrix".to_string(),
            offset: a_off,
            size: a_sizes[i],
            layer_id: i,
        });
        a_off += a_sizes[i];
    }
    let n_lora_params = a_off;

    let mut programs = BTreeMap::new();
    let prog = |file: String, slots, state_len, out_len| ProgramInfo { file, slots, state_len, out_len };
    programs.insert(
        "init".to_string(),
        prog(format!("{name}__init.native"), None, None, Some(n_params)),
    );
    programs.insert(
        "init_lora".to_string(),
        prog(format!("{name}__init_lora.native"), None, None, Some(n_lora_params)),
    );
    programs.insert(
        "thresh".to_string(),
        prog(format!("{name}__thresh.native"), None, None, Some(layout.len())),
    );
    programs.insert("logits".to_string(), prog(format!("{name}__logits.native"), None, None, None));
    programs.insert(
        "logits_lora".to_string(),
        prog(format!("{name}__logits_lora.native"), None, None, None),
    );
    programs.insert(
        "pretrain".to_string(),
        prog(
            format!("{name}__pretrain.native"),
            Some(2 * n_params + 1),
            Some(n_params + 2 * n_params + 1 + N_METRICS),
            None,
        ),
    );
    for (opt, slots) in native_optimizers(n_params, n_lora_params) {
        programs.insert(
            format!("step_{opt}"),
            prog(
                format!("{name}__step_{opt}.native"),
                Some(slots),
                Some(n_params + slots + N_METRICS),
                None,
            ),
        );
    }

    ModelInfo {
        name: name.to_string(),
        family: family.to_string(),
        size: size.to_string(),
        n_layers,
        d_model: d,
        n_heads: 4,
        d_ff,
        vocab: v,
        seq_len,
        batch,
        window,
        n_params,
        n_lora_params,
        lora_rank: r,
        n_entries: layout.len(),
        n_hypers: 8,
        n_metrics: N_METRICS,
        layout,
        lora_layout,
        programs,
    }
}

/// The synthesized manifest served by the native backend (no artifacts
/// directory required; `dir` is a placeholder that is never read).
pub fn native_manifest() -> Manifest {
    let mut models = BTreeMap::new();
    for m in [
        native_model("llama_tiny", "llama", "tiny", 64, 96, 2, 256, 0),
        native_model("llama_med", "llama", "med", 128, 192, 4, 512, 0),
        native_model("mistral_small", "mistral", "small", 80, 112, 2, 320, 8),
        native_model("opt_small", "opt", "small", 48, 64, 2, 192, 0),
    ] {
        models.insert(m.name.clone(), m);
    }
    Manifest {
        dir: PathBuf::from("native"),
        hyper_names: ["lr", "eps", "sparsity", "mask_seed", "beta1", "beta2", "adam_eps", "wd"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        metric_names: [
            "l_plus",
            "l_minus",
            "proj_grad",
            "masked_frac",
            "update_norm_sq",
            "train_loss",
            "accept",
            "reserved",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        models,
    }
}

// ---------------------------------------------------------------------------
// flat-parameter geometry
// ---------------------------------------------------------------------------

/// Resolved offsets of the native model's flat-parameter layout.
struct Geo {
    v: usize,
    d: usize,
    h: usize,
    r: usize,
    t: usize,
    b: usize,
    e_tok: usize,
    w1: usize,
    g1: usize,
    w2: usize,
    g2: usize,
    n_params: usize,
    n_lora: usize,
}

fn geometry(model: &ModelInfo) -> Result<Geo> {
    if model.layout.len() != 5 {
        bail!("model '{}' is not a native-backend model (layout has {} entries)", model.name, model.layout.len());
    }
    let e = &model.layout;
    let (v, d) = (e[0].shape[0], e[0].shape[1]);
    let h = e[1].shape[1];
    let geo = Geo {
        v,
        d,
        h,
        r: model.lora_rank,
        t: model.seq_len,
        b: model.batch,
        e_tok: e[0].offset,
        w1: e[1].offset,
        g1: e[2].offset,
        w2: e[3].offset,
        g2: e[4].offset,
        n_params: model.n_params,
        n_lora: model.n_lora_params,
    };
    if geo.g2 + v != geo.n_params {
        bail!("layout/n_params mismatch for '{}'", model.name);
    }
    Ok(geo)
}

// ---------------------------------------------------------------------------
// parameter sources
// ---------------------------------------------------------------------------

/// A read-only view of the flat parameter vector the forward pass can
/// pull contiguous runs from. The flat-slice impl hands back the
/// subslice directly (zero cost — the pre-paging code path, expression
/// for expression); the [`ParamStore`] / [`Overlay`] impls gather the
/// run into a caller-owned scratch buffer. Because every impl yields
/// exactly the same f32 bits for the same run, the generic forward pass
/// is bit-identical across sources.
pub(crate) trait ParamsSrc {
    /// Run `f` over params `[off, off + len)`.
    fn with_run<R>(
        &self,
        off: usize,
        len: usize,
        scratch: &mut Vec<f32>,
        f: impl FnOnce(&[f32]) -> R,
    ) -> R;
}

impl ParamsSrc for [f32] {
    #[inline]
    fn with_run<R>(
        &self,
        off: usize,
        len: usize,
        _scratch: &mut Vec<f32>,
        f: impl FnOnce(&[f32]) -> R,
    ) -> R {
        f(&self[off..off + len])
    }
}

impl ParamsSrc for ParamStore {
    fn with_run<R>(
        &self,
        off: usize,
        len: usize,
        scratch: &mut Vec<f32>,
        f: impl FnOnce(&[f32]) -> R,
    ) -> R {
        if scratch.len() < len {
            scratch.resize(len, 0.0);
        }
        self.read_into(off, &mut scratch[..len]);
        f(&scratch[..len])
    }
}

impl ParamsSrc for Overlay<'_> {
    fn with_run<R>(
        &self,
        off: usize,
        len: usize,
        scratch: &mut Vec<f32>,
        f: impl FnOnce(&[f32]) -> R,
    ) -> R {
        if scratch.len() < len {
            scratch.resize(len, 0.0);
        }
        self.read_run(off, &mut scratch[..len]);
        f(&scratch[..len])
    }
}

// ---------------------------------------------------------------------------
// forward pass
// ---------------------------------------------------------------------------

/// Per-row forward intermediates (kept for the backward pass).
struct Fwd {
    /// normalized features [D]
    x: Vec<f32>,
    /// pre-norm feature RMS denominator (sigma)
    sigma: f32,
    /// raw pooled features / sigma relationship: x = raw / sigma
    s1: Vec<f32>,
    /// post-tanh hidden [H]
    hid: Vec<f32>,
    /// pre-gain output accumulators [V]
    s2: Vec<f32>,
    /// final logits [V]
    logits: Vec<f32>,
}

/// One forward pass. `lora = Some(adapters)` adds the rank-r update
/// `(1/r) A·B` to `W1` (the logits_lora program). Generic over the
/// parameter source: every access is a row-granular run (an embedding
/// row, one W1/W2 row, a gain vector), so a paged source gathers at
/// most a few KiB at a time instead of materializing the full vector.
/// `scratch` is the reusable gather buffer (untouched for flat slices).
fn forward_row<S: ParamsSrc + ?Sized>(
    geo: &Geo,
    params: &S,
    lora: Option<&[f32]>,
    row: &[i32],
    scratch: &mut Vec<f32>,
) -> Fwd {
    let (d, h, v) = (geo.d, geo.h, geo.v);
    // raw pooled features (pre-norm), then normalize
    let mut raw = vec![0.0f32; d];
    let mut wsum = 0.0f32;
    for (p, &tok) in row.iter().enumerate() {
        if tok == crate::data::vocab::PAD {
            continue;
        }
        let w = 1.0 + (p + 1) as f32 / row.len() as f32;
        wsum += w;
        params.with_run(geo.e_tok + tok as usize * d, d, scratch, |e| {
            for i in 0..d {
                raw[i] += w * e[i];
            }
        });
    }
    if wsum > 0.0 {
        for ri in raw.iter_mut() {
            *ri /= wsum;
        }
    }
    let ms = raw.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let sigma = (ms + RMS_EPS).sqrt();
    let x: Vec<f32> = raw.iter().map(|&ri| ri / sigma).collect();

    // s1 = x · W1 (+ LoRA), hid = tanh(g1 ⊙ s1)
    let mut s1 = vec![0.0f32; h];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        params.with_run(geo.w1 + i * h, h, scratch, |wrow| {
            for j in 0..h {
                s1[j] += xi * wrow[j];
            }
        });
    }
    if let Some(ad) = lora {
        let r = geo.r;
        let a = &ad[..d * r];
        let b = &ad[d * r..d * r + r * h];
        let inv_r = 1.0 / r as f32;
        let mut xa = vec![0.0f32; r];
        for (i, &xi) in x.iter().enumerate() {
            for k in 0..r {
                xa[k] += xi * a[i * r + k];
            }
        }
        for k in 0..r {
            let scale = xa[k] * inv_r;
            let brow = &b[k * h..(k + 1) * h];
            for j in 0..h {
                s1[j] += scale * brow[j];
            }
        }
    }
    let hid: Vec<f32> =
        params.with_run(geo.g1, h, scratch, |g1| (0..h).map(|j| (g1[j] * s1[j]).tanh()).collect());

    // s2 = hid · W2, logits = g2 ⊙ s2
    let mut s2 = vec![0.0f32; v];
    for (j, &hj) in hid.iter().enumerate() {
        if hj == 0.0 {
            continue;
        }
        params.with_run(geo.w2 + j * v, v, scratch, |wrow| {
            for c in 0..v {
                s2[c] += hj * wrow[c];
            }
        });
    }
    let logits: Vec<f32> =
        params.with_run(geo.g2, v, scratch, |g2| (0..v).map(|c| g2[c] * s2[c]).collect());
    Fwd { x, sigma, s1, hid, s2, logits }
}

/// Row-major `[B, V]` last-position logits for a token batch.
fn logits_batch<S: ParamsSrc + ?Sized>(
    geo: &Geo,
    params: &S,
    lora: Option<&[f32]>,
    tokens: &[i32],
) -> Vec<f32> {
    let mut scratch = Vec::new();
    let mut out = Vec::with_capacity(geo.b * geo.v);
    for row in tokens.chunks(geo.t) {
        out.extend(forward_row(geo, params, lora, row, &mut scratch).logits);
    }
    out
}

/// Softmax cross-entropy of `label` under one logits row (f64 internals).
fn row_ce(logits: &[f32], label: i32) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&l| ((l as f64) - max).exp()).sum::<f64>().ln() + max;
    lse - logits[label as usize] as f64
}

/// Mean batch cross-entropy (the training loss of every step program).
fn batch_ce<S: ParamsSrc + ?Sized>(
    geo: &Geo,
    params: &S,
    lora: Option<&[f32]>,
    tokens: &[i32],
    labels: &[i32],
) -> f32 {
    let mut scratch = Vec::new();
    let mut total = 0.0f64;
    for (row, &label) in tokens.chunks(geo.t).zip(labels) {
        let fwd = forward_row(geo, params, lora, row, &mut scratch);
        total += row_ce(&fwd.logits, label);
    }
    (total / labels.len().max(1) as f64) as f32
}

// ---------------------------------------------------------------------------
// exact gradient (first-order baselines + pretraining)
// ---------------------------------------------------------------------------

/// Analytic gradient of the mean batch cross-entropy w.r.t. the flat
/// parameters; also returns the loss. Ground truth for the FO baselines
/// (`fo_sgd`, `fo_adam`) and the Fig-4 exact-gradient probe arm.
fn grad_batch(geo: &Geo, params: &[f32], tokens: &[i32], labels: &[i32]) -> (Vec<f32>, f32) {
    let (d, h, v) = (geo.d, geo.h, geo.v);
    let n = labels.len().max(1);
    let scale = 1.0 / n as f32;
    let mut g = vec![0.0f32; geo.n_params];
    let mut total = 0.0f64;

    let w1 = &params[geo.w1..geo.w1 + d * h];
    let g1 = &params[geo.g1..geo.g1 + h];
    let w2 = &params[geo.w2..geo.w2 + h * v];
    let g2 = &params[geo.g2..geo.g2 + v];
    let mut scratch = Vec::new();

    for (row, &label) in tokens.chunks(geo.t).zip(labels) {
        let fwd = forward_row(geo, params, None, row, &mut scratch);
        total += row_ce(&fwd.logits, label);

        // dL/dlogit_c = softmax_c - 1[c == label]
        let max = fwd.logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = fwd.logits.iter().map(|l| (l - max).exp()).collect();
        let z: f32 = exps.iter().sum();
        let mut dlogit = vec![0.0f32; v];
        for c in 0..v {
            dlogit[c] = exps[c] / z - if c as i32 == label { 1.0 } else { 0.0 };
        }

        // output gain + W2
        let mut dhid = vec![0.0f32; h];
        for c in 0..v {
            let dl = dlogit[c];
            if dl == 0.0 {
                continue;
            }
            g[geo.g2 + c] += scale * fwd.s2[c] * dl;
            let dg = dl * g2[c];
            for j in 0..h {
                g[geo.w2 + j * v + c] += scale * fwd.hid[j] * dg;
                dhid[j] += dg * w2[j * v + c];
            }
        }

        // tanh + hidden gain + W1
        let mut dx_hat = vec![0.0f32; d];
        for j in 0..h {
            let dpre = dhid[j] * (1.0 - fwd.hid[j] * fwd.hid[j]);
            g[geo.g1 + j] += scale * fwd.s1[j] * dpre;
            let dw = dpre * g1[j];
            for i in 0..d {
                g[geo.w1 + i * h + j] += scale * fwd.x[i] * dw;
                dx_hat[i] += dw * w1[i * h + j];
            }
        }

        // back through RMS norm: x_hat = raw / sigma
        let dot: f32 = dx_hat.iter().zip(&fwd.x).map(|(a, b)| a * b).sum();
        let inv_sigma = 1.0 / fwd.sigma;
        let mut draw = vec![0.0f32; d];
        for i in 0..d {
            draw[i] = inv_sigma * (dx_hat[i] - fwd.x[i] * dot / d as f32);
        }

        // distribute to token embeddings (recency-weighted mean pooling)
        let mut wsum = 0.0f32;
        for (p, &tok) in row.iter().enumerate() {
            if tok != crate::data::vocab::PAD {
                wsum += 1.0 + (p + 1) as f32 / row.len() as f32;
            }
        }
        if wsum > 0.0 {
            for (p, &tok) in row.iter().enumerate() {
                if tok == crate::data::vocab::PAD {
                    continue;
                }
                let w = (1.0 + (p + 1) as f32 / row.len() as f32) / wsum;
                let base = geo.e_tok + tok as usize * d;
                for i in 0..d {
                    g[base + i] += scale * w * draw[i];
                }
            }
        }
    }
    (g, (total / n as f64) as f32)
}

/// Gradient of the batch loss w.r.t. the LoRA adapters only (base frozen).
fn grad_lora(
    geo: &Geo,
    params: &[f32],
    adapters: &[f32],
    tokens: &[i32],
    labels: &[i32],
) -> (Vec<f32>, f32) {
    let (d, h, v, r) = (geo.d, geo.h, geo.v, geo.r);
    let n = labels.len().max(1);
    let scale = 1.0 / n as f32;
    let inv_r = 1.0 / r as f32;
    let mut ga = vec![0.0f32; geo.n_lora];
    let mut total = 0.0f64;
    let g1 = &params[geo.g1..geo.g1 + h];
    let g2 = &params[geo.g2..geo.g2 + v];
    let w2 = &params[geo.w2..geo.w2 + h * v];
    let a = &adapters[..d * r];
    let b = &adapters[d * r..d * r + r * h];
    let mut scratch = Vec::new();

    for (row, &label) in tokens.chunks(geo.t).zip(labels) {
        let fwd = forward_row(geo, params, Some(adapters), row, &mut scratch);
        total += row_ce(&fwd.logits, label);
        let max = fwd.logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = fwd.logits.iter().map(|l| (l - max).exp()).collect();
        let z: f32 = exps.iter().sum();
        let mut dhid = vec![0.0f32; h];
        for c in 0..v {
            let dl = exps[c] / z - if c as i32 == label { 1.0 } else { 0.0 };
            if dl == 0.0 {
                continue;
            }
            let dg = dl * g2[c];
            for j in 0..h {
                dhid[j] += dg * w2[j * v + c];
            }
        }
        // ds1_j, then dW1' = x ⊗ ds1; dA = dW1'·Bᵀ/r, dB = Aᵀ·dW1'/r
        let mut ds1 = vec![0.0f32; h];
        for j in 0..h {
            ds1[j] = dhid[j] * (1.0 - fwd.hid[j] * fwd.hid[j]) * g1[j];
        }
        // xa_k = x·A[:,k]; bs_k = ds1·B[k,:]
        let mut xa = vec![0.0f32; r];
        for (i, &xi) in fwd.x.iter().enumerate() {
            for k in 0..r {
                xa[k] += xi * a[i * r + k];
            }
        }
        let mut bs = vec![0.0f32; r];
        for k in 0..r {
            for j in 0..h {
                bs[k] += ds1[j] * b[k * h + j];
            }
        }
        for i in 0..d {
            for k in 0..r {
                ga[i * r + k] += scale * inv_r * fwd.x[i] * bs[k];
            }
        }
        for k in 0..r {
            for j in 0..h {
                ga[d * r + k * h + j] += scale * inv_r * xa[k] * ds1[j];
            }
        }
    }
    (ga, (total / n as f64) as f32)
}

// ---------------------------------------------------------------------------
// ZO machinery
// ---------------------------------------------------------------------------

/// One seed-replay perturbation stream: a contiguous span of the packed
/// state driven by `normal(key, local_index)`.
struct Stream {
    offset: usize,
    len: usize,
    key: u32,
}

/// Streams for the base parameter layout (one per entry, the manifest's
/// `PRNG stream id == entry index` convention).
fn base_streams(model: &ModelInfo, seed: (u32, u32)) -> Vec<Stream> {
    model
        .layout
        .iter()
        .map(|e| Stream {
            offset: e.offset,
            len: e.size,
            key: prng::layer_key(seed.0, seed.1, e.layer_id as u32),
        })
        .collect()
}

/// Streams for the LoRA adapter block (offset past the base params; the
/// stream ids are shifted past the base entries so they never collide).
fn lora_streams(model: &ModelInfo, p: usize, seed: (u32, u32)) -> Vec<Stream> {
    model
        .lora_layout
        .iter()
        .map(|e| Stream {
            offset: p + e.offset,
            len: e.size,
            key: prng::layer_key(seed.0, seed.1, (model.layout.len() + e.layer_id) as u32),
        })
        .collect()
}

/// `state[i] += scale * m_i * z_i` over all streams (Alg. 2 seed replay).
fn perturb(state: &mut [f32], streams: &[Stream], mask: Option<&[u8]>, scale: f32) {
    for st in streams {
        for j in 0..st.len {
            let idx = st.offset + j;
            if let Some(m) = mask {
                if m[idx] == 0 {
                    continue;
                }
            }
            state[idx] += scale * prng::normal(st.key, j as u32);
        }
    }
}

/// Which update rule the final fused walk applies.
enum Rule {
    /// `theta -= lr * g * m ⊙ z` (MeZO / S-MeZO / R-MeZO)
    Sgd,
    /// `theta -= lr * sign(g * m ⊙ z)`
    Sign,
    /// SGD step accepted only if the candidate loss does not regress
    Conservative,
    /// heavy-ball momentum on `g * m ⊙ z`; slot block holds the buffer
    Momentum,
    /// Adam moments on `g * m ⊙ z`; `clamp` additionally bounds each
    /// coordinate update to ±lr (the AdaMU-flavored variant)
    Adam { clamp: bool },
}

/// Outcome of one ZO walk, destined for the metric tail.
struct WalkInfo {
    l_plus: f32,
    l_minus: f32,
    g: f32,
    update_norm_sq: f32,
    accept: f32,
}

/// The fused Alg.-1 walk over the packed state:
/// `+eps` perturb -> loss -> `-2eps` -> loss -> fused restore+update.
/// `loss` receives the full packed state slice and reads what it needs,
/// so the same driver serves base-parameter and LoRA-adapter training.
#[allow(clippy::too_many_arguments)]
fn zo_walk<F: Fn(&[f32]) -> f32>(
    state: &mut Vec<f32>,
    streams: &[Stream],
    mask: Option<&[u8]>,
    rule: Rule,
    hypers: &Hypers,
    slot_off: usize,
    slot_base: usize,
    loss: F,
) -> WalkInfo {
    let eps = hypers.eps;
    let lr = hypers.lr;

    perturb(state, streams, mask, eps);
    let l_plus = loss(state.as_slice());
    perturb(state, streams, mask, -2.0 * eps);
    let l_minus = loss(state.as_slice());
    let g = (l_plus - l_minus) / (2.0 * eps);

    let mut norm = 0.0f32;
    let mut accept = 1.0f32;
    match rule {
        Rule::Sgd => {
            for st in streams {
                for j in 0..st.len {
                    let idx = st.offset + j;
                    if let Some(m) = mask {
                        if m[idx] == 0 {
                            continue;
                        }
                    }
                    let z = prng::normal(st.key, j as u32);
                    let u = lr * g * z;
                    state[idx] += eps * z - u;
                    norm += u * u;
                }
            }
        }
        Rule::Sign => {
            for st in streams {
                for j in 0..st.len {
                    let idx = st.offset + j;
                    if let Some(m) = mask {
                        if m[idx] == 0 {
                            continue;
                        }
                    }
                    let z = prng::normal(st.key, j as u32);
                    let gz = g * z;
                    state[idx] += eps * z;
                    if gz != 0.0 {
                        let u = lr * gz.signum();
                        state[idx] -= u;
                        norm += u * u;
                    }
                }
            }
        }
        Rule::Conservative => {
            // restore exactly, snapshot, try the SGD step, maybe reject
            perturb(state, streams, mask, eps);
            let before = state.clone();
            for st in streams {
                for j in 0..st.len {
                    let idx = st.offset + j;
                    if let Some(m) = mask {
                        if m[idx] == 0 {
                            continue;
                        }
                    }
                    let z = prng::normal(st.key, j as u32);
                    let u = lr * g * z;
                    state[idx] -= u;
                    norm += u * u;
                }
            }
            let l_cand = loss(state.as_slice());
            if l_cand > 0.5 * (l_plus + l_minus) {
                state.copy_from_slice(&before);
                norm = 0.0;
                accept = 0.0;
            }
        }
        Rule::Momentum => {
            let beta = hypers.beta1;
            for st in streams {
                for j in 0..st.len {
                    let idx = st.offset + j;
                    if let Some(m) = mask {
                        if m[idx] == 0 {
                            continue;
                        }
                    }
                    let z = prng::normal(st.key, j as u32);
                    let gz = g * z;
                    let mi = slot_off + (idx - slot_base);
                    state[mi] = beta * state[mi] + (1.0 - beta) * gz;
                    let u = lr * state[mi];
                    state[idx] += eps * z - u;
                    norm += u * u;
                }
            }
        }
        Rule::Adam { clamp } => {
            let n_train: usize = streams.iter().map(|s| s.len).sum();
            let t_idx = slot_off + 2 * n_train;
            state[t_idx] += 1.0;
            let t = state[t_idx];
            let bc1 = 1.0 - hypers.beta1.powf(t);
            let bc2 = 1.0 - hypers.beta2.powf(t);
            for st in streams {
                for j in 0..st.len {
                    let idx = st.offset + j;
                    if let Some(m) = mask {
                        if m[idx] == 0 {
                            continue;
                        }
                    }
                    let z = prng::normal(st.key, j as u32);
                    let gz = g * z;
                    let mi = slot_off + (idx - slot_base);
                    let vi = slot_off + n_train + (idx - slot_base);
                    state[mi] = hypers.beta1 * state[mi] + (1.0 - hypers.beta1) * gz;
                    state[vi] = hypers.beta2 * state[vi] + (1.0 - hypers.beta2) * gz * gz;
                    let mhat = state[mi] / bc1;
                    let vhat = state[vi] / bc2;
                    let mut u = lr * mhat / (vhat.sqrt() + hypers.adam_eps);
                    if clamp {
                        u = u.clamp(-lr, lr);
                    }
                    state[idx] += eps * z - u;
                    norm += u * u;
                }
            }
        }
    }
    WalkInfo { l_plus, l_minus, g, update_norm_sq: norm, accept }
}

/// Build the 0/1 mask over the base parameters for a masked variant.
/// Matrix entries test `|theta|` against their per-entry threshold;
/// vector entries (norm-gain analogs) are always dense — the paper's
/// §8.2 rule.
fn magnitude_mask(model: &ModelInfo, params: &[f32], thresholds: &[f32], large: bool) -> Vec<u8> {
    let mut m = vec![1u8; params.len()];
    for (i, e) in model.layout.iter().enumerate() {
        if e.kind != "matrix" {
            continue;
        }
        let h = thresholds[i];
        for j in e.offset..e.offset + e.size {
            let small = params[j].abs() <= h;
            m[j] = u8::from(small != large);
        }
    }
    m
}

/// Bernoulli mask over matrix entries keyed on the `mask_seed` hyper
/// (R-MeZO); vector entries stay dense.
fn random_mask(model: &ModelInfo, n_params: usize, keep_prob: f32, mask_seed: u32) -> Vec<u8> {
    let key = prng::layer_key(mask_seed, 0x52, 0);
    let mut m = vec![1u8; n_params];
    for e in &model.layout {
        if e.kind != "matrix" {
            continue;
        }
        for j in e.offset..e.offset + e.size {
            m[j] = u8::from(prng::uniform01(key, j as u32) < keep_prob);
        }
    }
    m
}

/// Adam moment update over an explicit gradient (FO baselines).
/// Slot layout: `[m (n) | v (n) | t (1)]` at `slot_off`; the trainable
/// block starts at `train_off`.
#[allow(clippy::too_many_arguments)]
fn adam_apply(
    state: &mut [f32],
    train_off: usize,
    grad: &[f32],
    slot_off: usize,
    hypers: &Hypers,
) -> f32 {
    let n = grad.len();
    let t_idx = slot_off + 2 * n;
    state[t_idx] += 1.0;
    let t = state[t_idx];
    let bc1 = 1.0 - hypers.beta1.powf(t);
    let bc2 = 1.0 - hypers.beta2.powf(t);
    let mut norm = 0.0f32;
    for i in 0..n {
        let gi = grad[i] + hypers.wd * state[train_off + i];
        let mi = slot_off + i;
        let vi = slot_off + n + i;
        state[mi] = hypers.beta1 * state[mi] + (1.0 - hypers.beta1) * gi;
        state[vi] = hypers.beta2 * state[vi] + (1.0 - hypers.beta2) * gi * gi;
        let u = hypers.lr * (state[mi] / bc1) / ((state[vi] / bc2).sqrt() + hypers.adam_eps);
        state[train_off + i] -= u;
        norm += u * u;
    }
    norm
}

/// Write the metric tail of the packed state.
fn write_metrics(state: &mut [f32], k_off: usize, info: &WalkInfo, masked_frac: f32, train_loss: f32) {
    state[k_off + M_L_PLUS] = info.l_plus;
    state[k_off + M_L_MINUS] = info.l_minus;
    state[k_off + M_PROJ_GRAD] = info.g;
    state[k_off + M_MASKED_FRAC] = masked_frac;
    state[k_off + M_UPDATE_NORM_SQ] = info.update_norm_sq;
    state[k_off + M_TRAIN_LOSS] = train_loss;
    state[k_off + M_ACCEPT] = info.accept;
    state[k_off + M_ACCEPT + 1] = 0.0;
}

// ---------------------------------------------------------------------------
// paged ZO step
// ---------------------------------------------------------------------------

/// [`perturb`] against a paged store: the same per-coordinate walk in
/// the same ascending order, applied through mutable page runs. Touched
/// pages dirty in place; nothing beyond the cache budget goes resident.
fn perturb_store(store: &ParamStore, streams: &[Stream], mask: Option<&[u8]>, scale: f32) {
    for st in streams {
        store.update_runs(st.offset, st.len, |goff, buf| {
            for (t, x) in buf.iter_mut().enumerate() {
                let idx = goff + t;
                if let Some(m) = mask {
                    if m[idx] == 0 {
                        continue;
                    }
                }
                *x += scale * prng::normal(st.key, (idx - st.offset) as u32);
            }
        });
    }
}

/// [`magnitude_mask`] read through page runs — same per-coordinate test,
/// same result bytes.
fn magnitude_mask_store(
    model: &ModelInfo,
    store: &ParamStore,
    thresholds: &[f32],
    large: bool,
) -> Vec<u8> {
    let mut m = vec![1u8; store.len()];
    for (i, e) in model.layout.iter().enumerate() {
        if e.kind != "matrix" {
            continue;
        }
        let h = thresholds[i];
        store.for_runs(e.offset, e.size, |goff, buf| {
            for (t, &x) in buf.iter().enumerate() {
                let small = x.abs() <= h;
                m[goff + t] = u8::from(small != large);
            }
        });
    }
    m
}

/// One ZO step against a [`StateBuf::Paged`] state: the fused Alg.-1
/// walk of the stateless family (`mezo`/`smezo`/`smezo_large`/`rmezo`,
/// the `Rule::Sgd` arm) replayed through page runs. Every coordinate is
/// visited in the same order with the same expressions as the resident
/// walk, so params, metrics and the journal scalars come out
/// bit-identical; the resident footprint stays at the page-cache budget
/// because dirty pages write back on eviction. Slot-stateful optimizers
/// are rejected — their slot blocks are host-resident by design.
#[allow(clippy::too_many_arguments)]
fn step_paged(
    model: &ModelInfo,
    geo: &Geo,
    optimizer: &str,
    hypers: &Hypers,
    thresholds: &[f32],
    state: &mut TrainState,
    tokens: &[i32],
    labels: &[i32],
    seed: (u32, u32),
) -> Result<()> {
    let (p, s) = (state.p, state.s);
    let StateBuf::Paged { store, tail } = &mut state.buf else {
        bail!("step_paged on a non-paged state")
    };
    let store = store.clone();
    // mask from the UNPERTURBED parameters, once per step (§3.3 EI
    // semantics) — identical bytes to the resident mask.
    let mask: Option<Vec<u8>> = match optimizer {
        "mezo" => None,
        "smezo" => Some(magnitude_mask_store(model, &store, thresholds, false)),
        "smezo_large" => Some(magnitude_mask_store(model, &store, thresholds, true)),
        "rmezo" => Some(random_mask(
            model,
            p,
            (1.0 - hypers.sparsity).clamp(0.0, 1.0),
            hypers.mask_seed as u32,
        )),
        other => bail!(
            "paged training (--page-cache-bytes) supports the stateless \
             mezo/smezo/smezo_large/rmezo family, not '{other}'"
        ),
    };
    let masked_frac = match &mask {
        Some(m) => m.iter().map(|&x| x as usize).sum::<usize>() as f32 / p as f32,
        None => 1.0,
    };

    let eps = hypers.eps;
    let lr = hypers.lr;
    let streams = base_streams(model, seed);
    perturb_store(&store, &streams, mask.as_deref(), eps);
    let l_plus = batch_ce(geo, &*store, None, tokens, labels);
    perturb_store(&store, &streams, mask.as_deref(), -2.0 * eps);
    let l_minus = batch_ce(geo, &*store, None, tokens, labels);
    let g = (l_plus - l_minus) / (2.0 * eps);

    // fused restore + SGD update, in stream/coordinate order so the
    // update-norm accumulation folds in the resident sequence
    let mut norm = 0.0f32;
    for st in &streams {
        store.update_runs(st.offset, st.len, |goff, buf| {
            for (t, x) in buf.iter_mut().enumerate() {
                let idx = goff + t;
                if let Some(m) = &mask {
                    if m[idx] == 0 {
                        continue;
                    }
                }
                let z = prng::normal(st.key, (idx - st.offset) as u32);
                let u = lr * g * z;
                *x += eps * z - u;
                norm += u * u;
            }
        });
    }
    let info = WalkInfo { l_plus, l_minus, g, update_norm_sq: norm, accept: 1.0 };
    let train_loss = 0.5 * (l_plus + l_minus);
    // the metric tail lives host-side: tail = [slots(S) | metrics(K)]
    write_metrics(tail, s, &info, masked_frac, train_loss);
    Ok(())
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

impl Backend for NativeBackend {
    fn platform(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>> {
        let geo = geometry(model)?;
        let mut p = vec![0.0f32; model.n_params];
        for e in &model.layout {
            if e.kind == "vector" {
                // norm-gain analogs start at exactly 1
                for x in &mut p[e.offset..e.offset + e.size] {
                    *x = 1.0;
                }
                continue;
            }
            // matrix entries: std * z from the shared counter PRNG, one
            // stream per layout entry (the cross-language init contract)
            let std = if e.offset == geo.e_tok {
                0.02
            } else if e.offset == geo.w1 {
                1.0 / (geo.d as f32).sqrt()
            } else {
                1.0 / (geo.h as f32).sqrt()
            };
            let key = prng::layer_key(seed.0, seed.1, e.layer_id as u32);
            for j in 0..e.size {
                p[e.offset + j] = std * prng::normal(key, j as u32);
            }
        }
        Ok(p)
    }

    fn init_lora(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>> {
        let geo = geometry(model)?;
        let mut p = vec![0.0f32; model.n_lora_params];
        // LoRA convention: A ~ N(0, 1/sqrt(d)), B = 0 (the update starts
        // at exactly zero)
        let e = &model.lora_layout[0];
        let key = prng::layer_key(seed.0, seed.1, (model.layout.len() + e.layer_id) as u32);
        let std = 1.0 / (geo.d as f32).sqrt();
        for j in 0..e.size {
            p[e.offset + j] = std * prng::normal(key, j as u32);
        }
        Ok(p)
    }

    fn thresholds(&self, model: &ModelInfo, params: &[f32], sparsity: f32) -> Result<Vec<f32>> {
        if params.len() != model.n_params {
            bail!("thresholds: params len {} != {}", params.len(), model.n_params);
        }
        Ok(model
            .layout
            .iter()
            .map(|e| {
                if e.kind == "matrix" {
                    percentile_threshold(&params[e.offset..e.offset + e.size], sparsity)
                } else {
                    f32::INFINITY
                }
            })
            .collect())
    }

    fn new_state(&self, host: Vec<f32>, p: usize, s: usize, k: usize) -> Result<TrainState> {
        if host.len() != p + s + k {
            bail!("state vector len {} != {p}+{s}+{k}", host.len());
        }
        Ok(TrainState { buf: StateBuf::Host(host), p, s, k })
    }

    fn read_state(&self, state: &TrainState, offset: usize, len: usize) -> Result<Vec<f32>> {
        if let StateBuf::Paged { store, tail } = &state.buf {
            let total = state.p + state.s + state.k;
            if offset + len > total {
                bail!("read_state [{offset}, +{len}) out of state len {total}");
            }
            // params prefix comes from the store; [slots | metrics] from
            // the host tail
            let mut out = vec![0.0f32; len];
            let from_store = len.min(state.p.saturating_sub(offset));
            if from_store > 0 {
                store.read_into(offset, &mut out[..from_store]);
            }
            for (i, o) in out.iter_mut().enumerate().skip(from_store) {
                *o = tail[offset + i - state.p];
            }
            return Ok(out);
        }
        let host = state.host()?;
        if offset + len > host.len() {
            bail!("read_state [{offset}, +{len}) out of state len {}", host.len());
        }
        Ok(host[offset..offset + len].to_vec())
    }

    fn step(
        &self,
        model: &ModelInfo,
        optimizer: &str,
        hypers: &Hypers,
        thresholds: &[f32],
        state: &mut TrainState,
        tokens: &[i32],
        labels: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        let geo = geometry(model)?;
        if thresholds.len() != model.n_entries {
            bail!("step: thresholds len {} != n_entries {}", thresholds.len(), model.n_entries);
        }
        let (p, s, k) = (state.p, state.s, state.k);
        if p != model.n_params || k != N_METRICS {
            bail!("step: state geometry [{p}|{s}|{k}] does not match model '{}'", model.name);
        }
        if matches!(state.buf, StateBuf::Paged { .. }) {
            return step_paged(
                model, &geo, optimizer, hypers, thresholds, state, tokens, labels, seed,
            );
        }
        let k_off = p + s;
        let vec = state.host_mut()?;

        // mask selection (None = dense). Masks are computed from the
        // UNPERTURBED parameters, exactly once per step — the dynamic-mask
        // EI semantics (paper §3.3).
        let mask: Option<Vec<u8>> = match optimizer {
            "smezo" => Some(magnitude_mask(model, &vec[..p], thresholds, false)),
            "smezo_large" => Some(magnitude_mask(model, &vec[..p], thresholds, true)),
            "smezo_const" => {
                // stored-mask ablation: computed once, parked in the slot
                // block as ±1 (slot 0 == 0.0 means "not yet initialized")
                if vec[p] == 0.0 {
                    let m = magnitude_mask(model, &vec[..p], thresholds, false);
                    for (i, &mi) in m.iter().enumerate() {
                        vec[p + i] = if mi != 0 { 1.0 } else { -1.0 };
                    }
                }
                Some((0..p).map(|i| u8::from(vec[p + i] > 0.0)).collect())
            }
            "rmezo" => Some(random_mask(
                model,
                p,
                (1.0 - hypers.sparsity).clamp(0.0, 1.0),
                hypers.mask_seed as u32,
            )),
            _ => None,
        };
        let masked_frac = match &mask {
            Some(m) => m.iter().map(|&x| x as usize).sum::<usize>() as f32 / p as f32,
            None => 1.0,
        };

        match optimizer {
            "mezo" | "smezo" | "smezo_large" | "smezo_const" | "rmezo" | "zo_sign" | "zo_cons"
            | "zo_mom" | "zo_adam" | "zo_adamu" => {
                let rule = match optimizer {
                    "zo_sign" => Rule::Sign,
                    "zo_cons" => Rule::Conservative,
                    "zo_mom" => Rule::Momentum,
                    "zo_adam" => Rule::Adam { clamp: false },
                    "zo_adamu" => Rule::Adam { clamp: true },
                    _ => Rule::Sgd,
                };
                // slot_base 0: slots are indexed by parameter coordinate;
                // smezo_const's mask slots take no optimizer slots.
                let slot_off = p + if optimizer == "smezo_const" { p } else { 0 };
                let streams = base_streams(model, seed);
                let info = zo_walk(
                    vec,
                    &streams,
                    mask.as_deref(),
                    rule,
                    hypers,
                    slot_off,
                    0,
                    |sv: &[f32]| batch_ce(&geo, &sv[..p], None, tokens, labels),
                );
                let train_loss = 0.5 * (info.l_plus + info.l_minus);
                write_metrics(vec, k_off, &info, masked_frac, train_loss);
            }
            "mezo_lora" => {
                let a = geo.n_lora;
                if s < a {
                    bail!("mezo_lora: slot block {s} < adapter count {a}");
                }
                let streams = lora_streams(model, p, seed);
                let info = zo_walk(
                    vec,
                    &streams,
                    None,
                    Rule::Sgd,
                    hypers,
                    p + a,
                    p,
                    |sv: &[f32]| batch_ce(&geo, &sv[..p], Some(&sv[p..p + a]), tokens, labels),
                );
                let train_loss = 0.5 * (info.l_plus + info.l_minus);
                write_metrics(vec, k_off, &info, 1.0, train_loss);
            }
            "lora_fo" => {
                let a = geo.n_lora;
                if s < 3 * a + 1 {
                    bail!("lora_fo: slot block {s} < 3A+1 = {}", 3 * a + 1);
                }
                let (grad, loss) = grad_lora(&geo, &vec[..p], &vec[p..p + a], tokens, labels);
                let norm = adam_apply(vec, p, &grad, p + a, hypers);
                let gnorm = grad.iter().map(|g| (g * g) as f64).sum::<f64>().sqrt() as f32;
                let info = WalkInfo {
                    l_plus: loss,
                    l_minus: loss,
                    g: gnorm,
                    update_norm_sq: norm,
                    accept: 1.0,
                };
                write_metrics(vec, k_off, &info, 1.0, loss);
            }
            "fo_sgd" | "fo_adam" => {
                let (grad, loss) = grad_batch(&geo, &vec[..p], tokens, labels);
                let norm = if optimizer == "fo_adam" {
                    if s < 2 * p + 1 {
                        bail!("fo_adam: slot block {s} < 2P+1");
                    }
                    adam_apply(vec, 0, &grad, p, hypers)
                } else {
                    let mut norm = 0.0f32;
                    for (i, gi) in grad.iter().enumerate() {
                        let u = hypers.lr * gi;
                        vec[i] -= u;
                        norm += u * u;
                    }
                    norm
                };
                let gnorm = grad.iter().map(|g| (g * g) as f64).sum::<f64>().sqrt() as f32;
                let info = WalkInfo {
                    l_plus: loss,
                    l_minus: loss,
                    g: gnorm,
                    update_norm_sq: norm,
                    accept: 1.0,
                };
                write_metrics(vec, k_off, &info, 1.0, loss);
            }
            other => bail!(
                "native backend has no step program '{other}' (have: {})",
                native_optimizers(0, 0).iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
            ),
        }
        Ok(())
    }

    fn pretrain_step(
        &self,
        model: &ModelInfo,
        hypers: &Hypers,
        state: &mut TrainState,
        tokens: &[i32],
        _seed: (u32, u32),
    ) -> Result<()> {
        let geo = geometry(model)?;
        let (p, s) = (state.p, state.s);
        if s < 2 * p + 1 {
            bail!("pretrain: slot block {s} < 2P+1");
        }
        let k_off = p + s;
        // next-token analog: predict the final token of each packed row
        // from its prefix
        let mut inputs = Vec::with_capacity(tokens.len());
        let mut labels = Vec::with_capacity(tokens.len() / geo.t);
        for row in tokens.chunks(geo.t) {
            labels.push(row[geo.t - 1]);
            inputs.extend_from_slice(&row[..geo.t - 1]);
            inputs.push(crate::data::vocab::PAD);
        }
        let vec = state.host_mut()?;
        let (grad, loss) = grad_batch(&geo, &vec[..p], &inputs, &labels);
        let norm = adam_apply(vec, 0, &grad, p, hypers);
        let gnorm = grad.iter().map(|g| (g * g) as f64).sum::<f64>().sqrt() as f32;
        let info =
            WalkInfo { l_plus: loss, l_minus: loss, g: gnorm, update_norm_sq: norm, accept: 1.0 };
        write_metrics(vec, k_off, &info, 1.0, loss);
        Ok(())
    }

    fn logits(&self, model: &ModelInfo, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let geo = geometry(model)?;
        if params.len() != model.n_params {
            bail!("logits: params len {} != {}", params.len(), model.n_params);
        }
        Ok(logits_batch(&geo, params, None, tokens))
    }

    fn logits_lora(
        &self,
        model: &ModelInfo,
        params: &[f32],
        adapters: &[f32],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let geo = geometry(model)?;
        if params.len() != model.n_params {
            bail!("logits_lora: params len {} != {}", params.len(), model.n_params);
        }
        if adapters.len() != model.n_lora_params {
            bail!("logits_lora: adapters len {} != {}", adapters.len(), model.n_lora_params);
        }
        Ok(logits_batch(&geo, params, Some(adapters), tokens))
    }

    fn compile_check(&self, model: &ModelInfo, program: &str) -> Result<()> {
        model.program(program).map(|_| ())
    }

    fn row_losses(
        &self,
        model: &ModelInfo,
        params: &[f32],
        tokens: &[i32],
        labels: &[i32],
    ) -> Result<Vec<f64>> {
        let geo = geometry(model)?;
        if params.len() != model.n_params {
            bail!("row_losses: params len {} != {}", params.len(), model.n_params);
        }
        if tokens.len() != labels.len() * geo.t {
            bail!(
                "row_losses: tokens len {} != {} rows x seq_len {}",
                tokens.len(),
                labels.len(),
                geo.t
            );
        }
        // Per-row values of exactly what batch_ce folds — the DP reducer
        // re-folds them in row order, reproducing a serial step bit-for-bit.
        let mut scratch = Vec::new();
        Ok(tokens
            .chunks(geo.t)
            .zip(labels)
            .map(|(row, &label)| {
                row_ce(&forward_row(&geo, params, None, row, &mut scratch).logits, label)
            })
            .collect())
    }

    fn zo_noise(&self, model: &ModelInfo, seed: (u32, u32), lo: usize, hi: usize) -> Result<Vec<f32>> {
        if lo > hi || hi > model.n_params {
            bail!("zo_noise: range [{lo}, {hi}) out of n_params {}", model.n_params);
        }
        let mut out = Vec::with_capacity(hi - lo);
        for e in &model.layout {
            let start = e.offset.max(lo);
            let end = (e.offset + e.size).min(hi);
            if start >= end {
                continue;
            }
            let key = prng::layer_key(seed.0, seed.1, e.layer_id as u32);
            for j in start..end {
                out.push(prng::normal(key, (j - e.offset) as u32));
            }
        }
        if out.len() != hi - lo {
            bail!("zo_noise: layout does not cover range [{lo}, {hi})");
        }
        Ok(out)
    }

    fn zo_mask(
        &self,
        model: &ModelInfo,
        optimizer: &str,
        hypers: &Hypers,
        thresholds: &[f32],
        params: &[f32],
    ) -> Result<Option<Vec<u8>>> {
        if params.len() != model.n_params {
            bail!("zo_mask: params len {} != {}", params.len(), model.n_params);
        }
        if thresholds.len() != model.n_entries {
            bail!("zo_mask: thresholds len {} != n_entries {}", thresholds.len(), model.n_entries);
        }
        match optimizer {
            // dense families: plain MeZO and the slot-stateful DP
            // optimizers, whose step programs apply no coordinate mask
            "mezo" | "zo_mom" | "zo_adam" | "zo_adamu" => Ok(None),
            "smezo" => Ok(Some(magnitude_mask(model, params, thresholds, false))),
            "smezo_large" => Ok(Some(magnitude_mask(model, params, thresholds, true))),
            "rmezo" => Ok(Some(random_mask(
                model,
                model.n_params,
                (1.0 - hypers.sparsity).clamp(0.0, 1.0),
                hypers.mask_seed as u32,
            ))),
            other => bail!(
                "optimizer '{other}' has no stateless step mask (data-parallel training \
                 supports the mezo/smezo/smezo_large/rmezo/zo_mom/zo_adam/zo_adamu family)"
            ),
        }
    }

    fn logits_rows(&self, model: &ModelInfo, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let geo = geometry(model)?;
        if params.len() != model.n_params {
            bail!("logits_rows: params len {} != {}", params.len(), model.n_params);
        }
        if tokens.is_empty() || tokens.len() % geo.t != 0 {
            bail!(
                "logits_rows: tokens len {} is not a positive multiple of seq_len {}",
                tokens.len(),
                geo.t
            );
        }
        // Row-independent forward passes: each output row is bit-identical
        // to the same row of `logits` on any batch carrying these tokens,
        // which is what lets the serving layer shard one batch freely.
        let mut scratch = Vec::new();
        let mut out = Vec::with_capacity((tokens.len() / geo.t) * geo.v);
        for row in tokens.chunks(geo.t) {
            out.extend(forward_row(&geo, params, None, row, &mut scratch).logits);
        }
        Ok(out)
    }
}

/// `logits_rows` over any [`ParamsSrc`] — the paged serving entry point.
/// The native backend is the only one that serves paged tenants, so this
/// is a free function rather than a `Backend` method (the trait stays
/// object-safe). Row outputs are bit-identical to
/// [`Backend::logits_rows`] over the flat equivalent of `src`.
pub(crate) fn logits_rows_src<S: ParamsSrc + ?Sized>(
    model: &ModelInfo,
    src: &S,
    tokens: &[i32],
) -> Result<Vec<f32>> {
    let geo = geometry(model)?;
    if tokens.is_empty() || tokens.len() % geo.t != 0 {
        bail!(
            "logits_rows: tokens len {} is not a positive multiple of seq_len {}",
            tokens.len(),
            geo.t
        );
    }
    let mut scratch = Vec::new();
    let mut out = Vec::with_capacity((tokens.len() / geo.t) * geo.v);
    for row in tokens.chunks(geo.t) {
        out.extend(forward_row(&geo, src, None, row, &mut scratch).logits);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    fn tiny(b: &NativeBackend) -> ModelInfo {
        b.manifest().model("llama_tiny").unwrap().clone()
    }

    #[test]
    fn manifest_layouts_validate() {
        let b = backend();
        for (_, m) in &b.manifest().models {
            let mut off = 0;
            for e in &m.layout {
                assert_eq!(e.offset, off, "{}/{}", m.name, e.name);
                off += e.size;
            }
            assert_eq!(off, m.n_params, "{}", m.name);
            assert_eq!(m.n_entries, m.layout.len());
            // every step program's state_len is consistent
            for (pname, prog) in &m.programs {
                if let (Some(slots), Some(state_len)) = (prog.slots, prog.state_len) {
                    assert_eq!(state_len, m.n_params + slots + m.n_metrics, "{}/{pname}", m.name);
                }
            }
        }
    }

    #[test]
    fn init_deterministic_and_contractual() {
        let b = backend();
        let m = tiny(&b);
        let p1 = b.init(&m, (42, 7)).unwrap();
        let p2 = b.init(&m, (42, 7)).unwrap();
        let p3 = b.init(&m, (43, 7)).unwrap();
        assert_eq!(p1, p2);
        assert_ne!(p1, p3);
        // embed.tok entries are 0.02 * z (the cross-language mirror check)
        let e = &m.layout[0];
        let z = prng::segment_normal(42, 7, e.layer_id as u32, 0, 8);
        for i in 0..8 {
            assert!((p1[e.offset + i] - 0.02 * z[i]).abs() < 1e-7);
        }
        // vector entries are exactly 1 at init
        for e in m.layout.iter().filter(|e| e.kind == "vector") {
            assert!(p1[e.offset..e.offset + e.size].iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn logits_shape_and_determinism() {
        let b = backend();
        let m = tiny(&b);
        let p = b.init(&m, (1, 1)).unwrap();
        let tokens = vec![5i32; m.batch * m.seq_len];
        let l1 = b.logits(&m, &p, &tokens).unwrap();
        let l2 = b.logits(&m, &p, &tokens).unwrap();
        assert_eq!(l1.len(), m.batch * m.vocab);
        assert_eq!(l1, l2);
        assert!(l1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn fo_grad_matches_finite_difference() {
        let b = backend();
        let m = tiny(&b);
        let geo = geometry(&m).unwrap();
        let mut p = b.init(&m, (3, 9)).unwrap();
        // one small batch of two rows
        let t = m.seq_len;
        let mut tokens = vec![0i32; 2 * t];
        tokens[t - 3..t].copy_from_slice(&[200, 201, 3]);
        tokens[2 * t - 2..].copy_from_slice(&[130, 4]);
        let labels = vec![3, 4];
        let (g, _) = grad_batch(&geo, &p, &tokens, &labels);
        let mut rng = crate::util::prng::Pcg32::new(5, 5);
        for _ in 0..12 {
            let i = rng.below(p.len() as u32) as usize;
            let h = 1e-3f32;
            let orig = p[i];
            p[i] = orig + h;
            let lp = batch_ce(&geo, &p, None, &tokens, &labels);
            p[i] = orig - h;
            let lm = batch_ce(&geo, &p, None, &tokens, &labels);
            p[i] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - g[i]).abs() < 3e-2 * g[i].abs().max(0.05),
                "coord {i}: fd {fd} vs analytic {}",
                g[i]
            );
        }
    }

    #[test]
    fn zo_noise_is_chunk_invariant_and_matches_streams() {
        let b = backend();
        let m = tiny(&b);
        let whole = b.zo_noise(&m, (7, 9), 0, m.n_params).unwrap();
        assert_eq!(whole.len(), m.n_params);
        // any chunking reassembles bit-identically (the DP engine shards
        // noise generation across the pool)
        let mid = m.n_params / 3;
        let mut parts = b.zo_noise(&m, (7, 9), 0, mid).unwrap();
        parts.extend(b.zo_noise(&m, (7, 9), mid, m.n_params).unwrap());
        assert_eq!(whole, parts);
        // and the values are exactly the per-entry counter-PRNG streams
        let e = &m.layout[1];
        let z = prng::segment_normal(7, 9, e.layer_id as u32, 0, 8);
        assert_eq!(&whole[e.offset..e.offset + 8], &z[..]);
        assert!(b.zo_noise(&m, (7, 9), 0, m.n_params + 1).is_err());
    }

    #[test]
    fn row_losses_fold_matches_batch_ce() {
        let b = backend();
        let m = tiny(&b);
        let geo = geometry(&m).unwrap();
        let p = b.init(&m, (1, 2)).unwrap();
        let tokens = vec![5i32; m.batch * m.seq_len];
        let labels = vec![3i32; m.batch];
        let rows = b.row_losses(&m, &p, &tokens, &labels).unwrap();
        assert_eq!(rows.len(), m.batch);
        let total: f64 = rows.iter().sum();
        let folded = (total / labels.len() as f64) as f32;
        // the DP reduction (sequential f64 fold, then the f32 cast) must
        // reproduce the step programs' training loss bit-for-bit
        assert_eq!(folded.to_bits(), batch_ce(&geo, &p, None, &tokens, &labels).to_bits());
        // ragged shards are fine
        let shard = b.row_losses(&m, &p, &tokens[..4 * m.seq_len], &labels[..4]).unwrap();
        assert_eq!(&shard[..], &rows[..4]);
    }

    #[test]
    fn zo_mask_mirrors_step_mask_family() {
        let b = backend();
        let m = tiny(&b);
        let p = b.init(&m, (4, 4)).unwrap();
        let h = Hypers::default();
        let th = b.thresholds(&m, &p, h.sparsity).unwrap();
        assert!(b.zo_mask(&m, "mezo", &h, &th, &p).unwrap().is_none());
        let small = b.zo_mask(&m, "smezo", &h, &th, &p).unwrap().unwrap();
        let large = b.zo_mask(&m, "smezo_large", &h, &th, &p).unwrap().unwrap();
        assert_eq!(small, magnitude_mask(&m, &p, &th, false));
        // small/large are complements on matrix entries, both dense on vectors
        for e in &m.layout {
            for j in e.offset..e.offset + e.size {
                if e.kind == "matrix" {
                    assert_eq!(small[j] ^ large[j], 1, "coord {j}");
                } else {
                    assert_eq!((small[j], large[j]), (1, 1), "coord {j}");
                }
            }
        }
        // the dense slot-stateful DP family answers None (no step mask)
        for opt in ["zo_mom", "zo_adam", "zo_adamu"] {
            assert!(b.zo_mask(&m, opt, &h, &th, &p).unwrap().is_none(), "{opt}");
        }
        // stored-mask optimizers are rejected with an actionable error
        assert!(b.zo_mask(&m, "smezo_const", &h, &th, &p).is_err());
    }

    #[test]
    fn logits_rows_ragged_matches_full_batch_rows() {
        let b = backend();
        let m = tiny(&b);
        let p = b.init(&m, (6, 6)).unwrap();
        let mut tokens = vec![0i32; m.batch * m.seq_len];
        for (i, t) in tokens.iter_mut().enumerate() {
            *t = (i % 97) as i32 % m.vocab as i32;
        }
        let full = b.logits(&m, &p, &tokens).unwrap();
        // any ragged slicing reproduces the corresponding rows bit-for-bit
        for rows in [1usize, 3, m.batch] {
            let part = b.logits_rows(&m, &p, &tokens[..rows * m.seq_len]).unwrap();
            assert_eq!(part.len(), rows * m.vocab);
            for (i, (a, f)) in part.iter().zip(&full[..rows * m.vocab]).enumerate() {
                assert_eq!(a.to_bits(), f.to_bits(), "coord {i} at {rows} rows");
            }
        }
        // shape guards
        assert!(b.logits_rows(&m, &p, &tokens[..m.seq_len - 1]).is_err());
        assert!(b.logits_rows(&m, &p, &[]).is_err());
    }

    #[test]
    fn zero_lr_step_is_identity_within_replay_tolerance() {
        let b = backend();
        let m = tiny(&b);
        let params = b.init(&m, (2, 2)).unwrap();
        let hypers = Hypers { lr: 0.0, ..Hypers::default() };
        let thresholds = b.thresholds(&m, &params, hypers.sparsity).unwrap();
        let mut state = b
            .new_state(
                {
                    let mut v = params.clone();
                    v.resize(params.len() + N_METRICS, 0.0);
                    v
                },
                params.len(),
                0,
                N_METRICS,
            )
            .unwrap();
        let tokens = vec![7i32; m.batch * m.seq_len];
        let labels = vec![3i32; m.batch];
        b.step(&m, "smezo", &hypers, &thresholds, &mut state, &tokens, &labels, (9, 9)).unwrap();
        let after = b.read_state(&state, 0, params.len()).unwrap();
        for i in 0..params.len() {
            assert!(
                (after[i] - params[i]).abs() <= 2e-6 * params[i].abs().max(1.0),
                "coord {i}: {} vs {}",
                after[i],
                params[i]
            );
        }
    }

    fn bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: coord {i} ({x} vs {y})");
        }
    }

    #[test]
    fn paged_logits_match_flat_bitwise() {
        use super::super::store::PAGE_BYTES;
        let b = backend();
        let m = tiny(&b);
        let p = b.init(&m, (6, 6)).unwrap();
        let mut tokens = vec![0i32; 4 * m.seq_len];
        for (i, t) in tokens.iter_mut().enumerate() {
            *t = (i % 97) as i32 % m.vocab as i32;
        }
        let flat = b.logits_rows(&m, &p, &tokens).unwrap();
        // 1-page cache: every row faults/evicts its way through the file
        let store = ParamStore::file_backed(&p, PAGE_BYTES).unwrap();
        let paged = logits_rows_src(&m, &store, &tokens).unwrap();
        bits_eq(&paged, &flat, "paged store logits");
        assert!(store.faults() > 0 && store.evictions() > 0);

        // overlay reads == swap-then-read, end to end through the forward
        let idx: Vec<u32> = vec![0, 5, (m.n_params / 2) as u32, (m.n_params - 1) as u32];
        let val: Vec<f32> = vec![0.5, -0.25, 2.0, -1.5];
        let mut patched = p.clone();
        for (i, v) in idx.iter().zip(&val) {
            patched[*i as usize] = *v;
        }
        let flat_patched = b.logits_rows(&m, &patched, &tokens).unwrap();
        let ov = Overlay::new(&store, &idx, &val);
        let paged_patched = logits_rows_src(&m, &ov, &tokens).unwrap();
        bits_eq(&paged_patched, &flat_patched, "overlay logits");
    }

    #[test]
    fn paged_step_bit_identical_to_resident() {
        use super::super::store::PAGE_BYTES;
        let b = backend();
        let m = tiny(&b);
        let params = b.init(&m, (11, 0x1717)).unwrap();
        let hypers = Hypers::default();
        let thresholds = b.thresholds(&m, &params, hypers.sparsity).unwrap();
        let tokens: Vec<i32> =
            (0..m.batch * m.seq_len).map(|i| (i % 89) as i32 % m.vocab as i32).collect();
        let labels: Vec<i32> = (0..m.batch).map(|i| (i % m.vocab) as i32).collect();
        for opt in ["mezo", "smezo", "smezo_large", "rmezo"] {
            let mut res = b
                .new_state(
                    {
                        let mut v = params.clone();
                        v.resize(params.len() + N_METRICS, 0.0);
                        v
                    },
                    params.len(),
                    0,
                    N_METRICS,
                )
                .unwrap();
            // cache budget of 2 pages << one full copy: the walk pages
            // its way through the scratch file every step
            let mut pag =
                TrainState::from_params_paged(&params, 0, N_METRICS, 2 * PAGE_BYTES).unwrap();
            for step_i in 0..3u32 {
                b.step(&m, opt, &hypers, &thresholds, &mut res, &tokens, &labels, (9, step_i))
                    .unwrap();
                b.step(&m, opt, &hypers, &thresholds, &mut pag, &tokens, &labels, (9, step_i))
                    .unwrap();
            }
            bits_eq(
                &pag.params_host(&crate::runtime::Runtime::native()).unwrap(),
                &b.read_state(&res, 0, params.len()).unwrap(),
                &format!("{opt} params"),
            );
            bits_eq(
                &b.read_state(&pag, params.len(), N_METRICS).unwrap(),
                &b.read_state(&res, params.len(), N_METRICS).unwrap(),
                &format!("{opt} metrics"),
            );
        }
    }

    #[test]
    fn paged_step_rejects_slot_stateful_family() {
        let b = backend();
        let m = tiny(&b);
        let params = b.init(&m, (3, 3)).unwrap();
        let hypers = Hypers::default();
        let thresholds = b.thresholds(&m, &params, hypers.sparsity).unwrap();
        let mut pag =
            TrainState::from_params_paged(&params, params.len(), N_METRICS, 1 << 16).unwrap();
        let tokens = vec![1i32; m.batch * m.seq_len];
        let labels = vec![0i32; m.batch];
        let err = b
            .step(&m, "zo_mom", &hypers, &thresholds, &mut pag, &tokens, &labels, (1, 1))
            .unwrap_err();
        assert!(err.to_string().contains("stateless"), "{err}");
    }
}
