//! The pluggable compute-backend contract.
//!
//! The coordinator (trainer, evaluator, sweeps, probes, repro harness)
//! only ever needs a small semantic surface from the compute layer:
//!
//! * **init** — deterministic parameter/adapter initialization,
//! * **thresholds** — per-layout-entry §8.2 percentile thresholds,
//! * **step** — one optimizer step on the packed
//!   `[params | slots | metrics]` state (the perturb / forward-loss /
//!   replay-update cycle of paper Alg. 1–3),
//! * **logits** — last-position logits for candidate-scored evaluation,
//! * **state plumbing** — creating and partially reading packed states.
//!
//! [`Backend`] captures exactly that surface, so the coordinator is
//! independent of *where* compute happens. Two implementations ship:
//!
//! * [`native`](super::native) (default) — a pure-Rust model + optimizer
//!   family built on the [`zo`](crate::zo) substrate and the shared
//!   counter PRNG. Runs everywhere, offline, no artifacts needed.
//! * [`pjrt`](super::pjrt) (behind the `pjrt` cargo feature) — executes
//!   the AOT-compiled XLA programs under `artifacts/` through the PJRT C
//!   API, as described in the module docs of [`super`].
//!
//! Backends must be `Send + Sync`: the sweep driver
//! ([`crate::coordinator::sweep`]) fans grid cells out across scoped
//! threads that share one backend reference.

use anyhow::{bail, Result};

use super::exec::Hypers;
use super::manifest::{Manifest, ModelInfo};
use super::state::TrainState;

/// A compute backend: everything the coordinator needs from the layer
/// that owns parameters and runs forward passes. See the module docs.
pub trait Backend: Send + Sync {
    /// Short platform tag for logs (`"native"`, `"pjrt"`).
    fn platform(&self) -> &'static str;

    /// The model/program manifest this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Deterministic parameter init: `seed -> f32[P]`.
    fn init(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>>;

    /// Deterministic LoRA adapter init: `seed -> f32[A]`.
    fn init_lora(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>>;

    /// Per-layout-entry magnitude thresholds at `sparsity` (paper §8.2):
    /// matrix entries get their |theta| percentile, vector entries get
    /// +inf (always dense). Returns `f32[n_entries]`.
    fn thresholds(&self, model: &ModelInfo, params: &[f32], sparsity: f32) -> Result<Vec<f32>>;

    /// Wrap an assembled host `[params | slots | metrics]` vector into a
    /// backend-resident [`TrainState`].
    fn new_state(&self, host: Vec<f32>, p: usize, s: usize, k: usize) -> Result<TrainState>;

    /// Read `len` floats at element `offset` from the packed state.
    fn read_state(&self, state: &TrainState, offset: usize, len: usize) -> Result<Vec<f32>>;

    /// One optimizer step of `optimizer` on `state` (paper Alg. 1 for the
    /// ZO family): evaluate the two perturbed losses on the batch, form
    /// the projected gradient, apply the masked update, and write the
    /// K-element metric tail.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        model: &ModelInfo,
        optimizer: &str,
        hypers: &Hypers,
        thresholds: &[f32],
        state: &mut TrainState,
        tokens: &[i32],
        labels: &[i32],
        seed: (u32, u32),
    ) -> Result<()>;

    /// One first-order LM pretraining step on `state` (next-token
    /// objective over a corpus batch).
    fn pretrain_step(
        &self,
        model: &ModelInfo,
        hypers: &Hypers,
        state: &mut TrainState,
        tokens: &[i32],
        seed: (u32, u32),
    ) -> Result<()>;

    /// Last-position logits `f32[B, V]` for a token batch under `params`.
    fn logits(&self, model: &ModelInfo, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>>;

    /// Last-position logits under frozen `params` + LoRA `adapters`.
    fn logits_lora(
        &self,
        model: &ModelInfo,
        params: &[f32],
        adapters: &[f32],
        tokens: &[i32],
    ) -> Result<Vec<f32>>;

    /// Verify one named program is loadable/executable (the
    /// `check-artifacts` smoke pass). PJRT compiles the artifact; the
    /// native backend validates the program name.
    fn compile_check(&self, model: &ModelInfo, program: &str) -> Result<()>;

    // ---- data-parallel surface (crate::parallel) --------------------------
    //
    // The seed-sync DP engine needs three finer-grained primitives than
    // `step`: per-row losses for a microbatch shard, the seed-replay
    // perturbation noise z, and the step's coordinate mask. The engine
    // reduces shard losses to one projected-gradient scalar and applies
    // the identical masked update on every replica — so these three
    // primitives, not `step`, are the unit of distribution. Backends
    // without a DP implementation inherit the `bail!` defaults (the
    // stubbed PJRT path compiles but reports "unsupported" at runtime).

    /// Per-row cross-entropy losses (f64, row order) of a token batch
    /// under `params`. Unlike [`Backend::logits`], the batch may be
    /// *ragged* — any row count, `tokens.len() == labels.len() * seq_len`
    /// — so a microbatch shard needs no padding. Row values must match
    /// what the step programs fold into their training loss, which is
    /// what makes the DP reduction bit-identical to a serial step.
    fn row_losses(
        &self,
        _model: &ModelInfo,
        _params: &[f32],
        _tokens: &[i32],
        _labels: &[i32],
    ) -> Result<Vec<f64>> {
        bail!("backend '{}' does not support data-parallel row losses", self.platform())
    }

    /// The seed-replay perturbation noise `z` for flat parameter
    /// coordinates `[lo, hi)` at `seed` — the same per-layout-entry
    /// counter-PRNG streams every step program regenerates (Alg. 2).
    /// Chunk-invariant: concatenating `[lo, m)` and `[m, hi)` equals
    /// `[lo, hi)` bit-for-bit, so callers may shard generation freely.
    fn zo_noise(&self, _model: &ModelInfo, _seed: (u32, u32), _lo: usize, _hi: usize) -> Result<Vec<f32>> {
        bail!("backend '{}' does not support host-side noise replay", self.platform())
    }

    /// The 0/1 coordinate mask `optimizer` would apply this step, computed
    /// from the **unperturbed** `params` (dynamic-mask EI semantics,
    /// paper §3.3). `None` means dense. Only the stateless mask family
    /// (`mezo`, `smezo`, `smezo_large`, `rmezo`) plus the dense
    /// slot-stateful DP family (`zo_mom`, `zo_adam`, `zo_adamu`, which
    /// answer `None`) is required; optimizers whose mask lives in
    /// optimizer slots may error.
    fn zo_mask(
        &self,
        _model: &ModelInfo,
        _optimizer: &str,
        _hypers: &Hypers,
        _thresholds: &[f32],
        _params: &[f32],
    ) -> Result<Option<Vec<u8>>> {
        bail!("backend '{}' does not support host-side mask computation", self.platform())
    }

    // ---- serving surface (crate::serve) -----------------------------------
    //
    // The multi-tenant inference server batches same-adapter requests
    // and shards the fused forward pass across the worker pool, so it
    // needs logits for a *ragged* row batch — any row count, no padding
    // to the compiled [B, T] shape. Backends without a serving
    // implementation inherit the `bail!` default (the stubbed PJRT path
    // compiles but reports "unsupported" at runtime).

    /// Last-position logits `f32[R, V]` for a **ragged** token batch
    /// under `params`: any row count `R >= 1`,
    /// `tokens.len() == R * seq_len`. Row r must be bit-identical to the
    /// same row of [`Backend::logits`] on any batch carrying the same
    /// tokens — the serving layer shards one logical batch across
    /// workers and re-concatenates in row order, and its
    /// batched-equals-serial guarantee rests on this contract.
    fn logits_rows(&self, _model: &ModelInfo, _params: &[f32], _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!("backend '{}' does not support ragged serving logits", self.platform())
    }

    /// Number of compiled executables held in the cache (perf accounting;
    /// 0 for backends without a compile step).
    fn cached_executables(&self) -> usize {
        0
    }

    /// Cumulative compile seconds (perf accounting).
    fn total_compile_seconds(&self) -> f64 {
        0.0
    }
}
