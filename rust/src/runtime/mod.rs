//! Runtime: the coordinator's gateway to compute, behind a pluggable
//! [`Backend`](backend::Backend).
//!
//! * [`backend`] — the trait capturing the forward-loss / perturb-replay
//!   surface the coordinator needs (init, thresholds, step, logits,
//!   packed-state plumbing).
//! * [`native`] — the default pure-Rust backend: a bag-of-embeddings MLP
//!   with the full optimizer family, synthesized manifest, no artifacts
//!   required. Everything runs offline.
//! * [`pjrt`] (feature `pjrt`) — executes the AOT-lowered XLA programs
//!   under `artifacts/` through the PJRT C API (the three-layer design:
//!   Python lowers JAX+Pallas to HLO once; Rust executes it forever).
//! * [`manifest`] — the L2→L3 ABI contract (also synthesized by the
//!   native backend).
//! * [`state`] — the backend-resident packed `[params | slots | metrics]`
//!   training state.
//! * [`store`] — the paged, tiered parameter store (resident /
//!   file-backed LRU page cache) plus the sparse [`store::Overlay`]
//!   view used by paged serving.
//! * [`exec`] — typed program wrappers that enforce shapes at call sites.

pub mod backend;
pub mod exec;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod state;
pub mod store;

use std::path::Path;

use anyhow::Result;

pub use manifest::{LayoutEntry, Manifest, ModelInfo, ProgramInfo};
pub use state::TrainState;

use backend::Backend;

/// Owns the active compute backend and routes the coordinator to it.
pub struct Runtime {
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Select a backend. With the `pjrt` feature enabled and a manifest
    /// present under `artifacts_dir`, the PJRT backend is attempted first.
    /// A present-but-invalid manifest is a hard error (silently training a
    /// different model than the artifacts describe would be worse than
    /// failing); only a PJRT *client* start failure — e.g. when built
    /// against the vendored API stub — falls back to native with a log
    /// line. Otherwise the native pure-Rust backend serves everything
    /// offline.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            if artifacts_dir.join("manifest.json").exists() {
                // corrupt/stale manifests must propagate, not fall back
                let manifest = Manifest::load(artifacts_dir)?;
                match pjrt::PjrtBackend::with_manifest(manifest) {
                    Ok(b) => return Ok(Runtime { backend: Box::new(b) }),
                    Err(e) => {
                        crate::info!("PJRT client unavailable ({e:#}); using native backend")
                    }
                }
            }
        }
        let _ = artifacts_dir;
        Ok(Runtime::native())
    }

    /// The native pure-Rust backend, unconditionally.
    pub fn native() -> Runtime {
        Runtime { backend: Box::new(native::NativeBackend::new()) }
    }

    /// The active backend.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// The model/program manifest the backend serves.
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// Look up one model's ABI description.
    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.backend.manifest().model(name)
    }

    /// Number of compiled executables held by the backend (0 for native).
    pub fn cached_executables(&self) -> usize {
        self.backend.cached_executables()
    }

    /// Cumulative backend compile seconds (0 for native).
    pub fn total_compile_seconds(&self) -> f64 {
        self.backend.total_compile_seconds()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_serves_models_offline() {
        let rt = Runtime::new(Path::new("/nonexistent/artifacts")).unwrap();
        assert_eq!(rt.backend().platform(), "native");
        let m = rt.model("llama_tiny").unwrap();
        assert!(m.n_params > 0);
        assert!(rt.model("no_such_model").is_err());
        assert_eq!(rt.cached_executables(), 0);
    }

    #[test]
    fn state_round_trips_through_backend() {
        let rt = Runtime::native();
        let params = vec![1.0f32, -2.0, 3.5];
        let state = TrainState::from_params(&rt, &params, 2, 1).unwrap();
        assert_eq!(state.state_len(), 6);
        assert_eq!(state.params_host(&rt).unwrap(), params);
        assert_eq!(state.slots_host(&rt).unwrap(), vec![0.0, 0.0]);
        assert_eq!(state.metrics(&rt).unwrap(), vec![0.0]);
        assert!(state.segment_host(&rt, 2, 2).is_err());
        assert_eq!(state.device_bytes(), 24);
    }
}
