//! Runtime: load + execute the AOT-compiled XLA programs via PJRT.
//!
//! The Python side (`python/compile/aot.py`) lowered every (model,
//! optimizer) program to HLO text under `artifacts/` together with a
//! `manifest.json` describing the packed-state ABI (DESIGN.md §3.1). This
//! module is everything Rust needs to run them:
//!
//! * [`manifest`] — parse the manifest into typed structs.
//! * [`client`] — PJRT CPU client wrapper + compiled-executable cache.
//! * [`state`] — the device-resident packed training state
//!   `[params | opt slots | metrics]` with partial host readback.
//! * [`exec`] — typed wrappers (`StepExec`, `LogitsExec`, ...) that enforce
//!   the ABI at the call site.

pub mod client;
pub mod exec;
pub mod manifest;
pub mod state;

pub use client::Runtime;
pub use manifest::{LayoutEntry, Manifest, ModelInfo, ProgramInfo};
pub use state::TrainState;
