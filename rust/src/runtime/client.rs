//! PJRT client wrapper + compiled-executable cache.
//!
//! Compilation of a step program takes O(seconds); every experiment in the
//! repro harness reuses the same handful of programs, so executables are
//! cached by artifact file name for the lifetime of the `Runtime`. The
//! client is CPU PJRT (`PjRtClient::cpu()`); interchange is HLO text
//! (see aot.py for why not serialized protos).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{Manifest, ModelInfo, ProgramInfo};

/// Owns the PJRT client, the manifest, and the executable cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative compile seconds (perf accounting)
    compile_seconds: RefCell<f64>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        crate::info!(
            "PJRT platform={} devices={} | {} models in manifest",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_seconds: RefCell::new(0.0),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    /// Load + compile (cached) one program.
    pub fn load(&self, prog: &ProgramInfo) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&prog.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(prog);
        let t0 = std::time::Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parsing HLO {}: {e:?}", path.display()))
            .with_context(|| "artifact missing or stale — run `make artifacts`")?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        let dt = t0.elapsed().as_secs_f64();
        *self.compile_seconds.borrow_mut() += dt;
        crate::debug!("compiled {} in {:.2}s", prog.file, dt);
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(prog.file.clone(), exe.clone());
        Ok(exe)
    }

    pub fn total_compile_seconds(&self) -> f64 {
        *self.compile_seconds.borrow()
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    // ---- host <-> device helpers -----------------------------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    pub fn upload_u32(&self, data: &[u32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload u32 {dims:?}: {e:?}"))
    }

    /// Full f32 readback of a device buffer.
    ///
    /// NOTE: the TFRT CPU PJRT plugin does not implement `CopyRawToHost`
    /// (partial raw reads), so readback goes through `to_literal_sync`,
    /// which copies the whole buffer. On the CPU "device" this is a host
    /// memcpy (~µs/MB); the packed-state design still avoids re-UPLOADING
    /// parameters each step, which is the expensive direction. See
    /// EXPERIMENTS.md §Perf for the measured cost.
    pub fn download_f32(&self, buf: &PjRtBuffer, len: usize) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download f32[{len}]: {e:?}"))?;
        let out: Vec<f32> = lit.to_vec().map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))?;
        if out.len() < len {
            anyhow::bail!("buffer has {} elements, wanted {len}", out.len());
        }
        Ok(out)
    }

    /// Ranged readback (element offset). Falls back to a full literal copy
    /// + host-side slice (see `download_f32`).
    pub fn download_f32_at(&self, buf: &PjRtBuffer, offset: usize, len: usize) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download f32[{offset}..+{len}]: {e:?}"))?;
        let all: Vec<f32> = lit.to_vec().map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))?;
        if offset + len > all.len() {
            anyhow::bail!("range [{offset}, +{len}) out of buffer len {}", all.len());
        }
        Ok(all[offset..offset + len].to_vec())
    }
}
