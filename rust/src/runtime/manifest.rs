//! `artifacts/manifest.json` — the L2->L3 ABI contract, parsed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One flat-parameter layout entry (mirror of python layout.Entry).
#[derive(Debug, Clone, PartialEq)]
pub struct LayoutEntry {
    /// parameter tensor name
    pub name: String,
    /// tensor shape
    pub shape: Vec<usize>,
    /// "matrix" (maskable) or "vector" (always dense)
    pub kind: String,
    /// flat offset into the packed parameter vector
    pub offset: usize,
    /// element count
    pub size: usize,
    /// PRNG stream id == entry index
    pub layer_id: usize,
}

/// One exported HLO program.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramInfo {
    /// artifact file name (doubles as the backend program id)
    pub file: String,
    /// optimizer slot count (step programs only)
    pub slots: Option<usize>,
    /// full packed state length (step programs only)
    pub state_len: Option<usize>,
    /// output vector length (init / thresh)
    pub out_len: Option<usize>,
}

/// One exported model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// model name (manifest key)
    pub name: String,
    /// architecture family (llama / mistral / opt)
    pub family: String,
    /// size tag (tiny / med / small / ...)
    pub size: String,
    /// layer count (drives the analytic memory model)
    pub n_layers: usize,
    /// model width
    pub d_model: usize,
    /// attention heads
    pub n_heads: usize,
    /// feed-forward width
    pub d_ff: usize,
    /// vocabulary size `V`
    pub vocab: usize,
    /// sequence length `T`
    pub seq_len: usize,
    /// batch size `B`
    pub batch: usize,
    /// sliding-window size (0 = full attention)
    pub window: usize,
    /// total trainable parameters `P`
    pub n_params: usize,
    /// LoRA adapter parameters `A`
    pub n_lora_params: usize,
    /// LoRA rank `r`
    pub lora_rank: usize,
    /// layout entry count `L`
    pub n_entries: usize,
    /// hyper vector length
    pub n_hypers: usize,
    /// metric tail length `K`
    pub n_metrics: usize,
    /// flat-parameter layout
    pub layout: Vec<LayoutEntry>,
    /// adapter layout
    pub lora_layout: Vec<LayoutEntry>,
    /// exported programs by name
    pub programs: BTreeMap<String, ProgramInfo>,
}

impl ModelInfo {
    /// Look up a program by name with an actionable error.
    pub fn program(&self, name: &str) -> Result<&ProgramInfo> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("model '{}' has no program '{name}' (have: {})",
                self.name,
                self.programs.keys().cloned().collect::<Vec<_>>().join(", ")))
    }

    /// The step program of `optimizer`.
    pub fn step_program(&self, optimizer: &str) -> Result<&ProgramInfo> {
        self.program(&format!("step_{optimizer}"))
    }

    /// Optimizer variants this model was exported with.
    pub fn step_variants(&self) -> Vec<String> {
        self.programs
            .keys()
            .filter_map(|k| k.strip_prefix("step_").map(|s| s.to_string()))
            .collect()
    }

    /// Total parameter bytes (f32).
    pub fn param_bytes(&self) -> usize {
        self.n_params * 4
    }

    pub fn matrix_entries(&self) -> impl Iterator<Item = &LayoutEntry> {
        self.layout.iter().filter(|e| e.kind == "matrix")
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// directory artifact files resolve against
    pub dir: PathBuf,
    /// hyper vector slot names
    pub hyper_names: Vec<String>,
    /// metric tail slot names
    pub metric_names: Vec<String>,
    /// models by name
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json` (with ABI validation).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let version = root.req("version")?.as_usize()?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let hyper_names = str_vec(root.req("hyper_names")?)?;
        let metric_names = str_vec(root.req("metric_names")?)?;
        let mut models = BTreeMap::new();
        for (name, m) in root.req("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(name, m).with_context(|| format!("model {name}"))?);
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }
        Ok(Manifest { dir: dir.to_path_buf(), hyper_names, metric_names, models })
    }

    /// Look up a model with an actionable error.
    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{name}' not in manifest (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", "))
        })
    }

    /// Path of a program's artifact file.
    pub fn artifact_path(&self, prog: &ProgramInfo) -> PathBuf {
        self.dir.join(&prog.file)
    }

    /// Index of a named hyper in the hypers vector.
    pub fn hyper_index(&self, name: &str) -> Result<usize> {
        self.hyper_names
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| anyhow!("unknown hyper '{name}'"))
    }
}

fn str_vec(v: &Json) -> Result<Vec<String>> {
    v.as_arr()?.iter().map(|x| Ok(x.as_str()?.to_string())).collect()
}

fn parse_layout(v: &Json) -> Result<Vec<LayoutEntry>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(LayoutEntry {
                name: e.req("name")?.as_str()?.to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                kind: e.req("kind")?.as_str()?.to_string(),
                offset: e.req("offset")?.as_usize()?,
                size: e.req("size")?.as_usize()?,
                layer_id: e.req("layer_id")?.as_usize()?,
            })
        })
        .collect()
}

fn parse_model(name: &str, m: &Json) -> Result<ModelInfo> {
    let mut programs = BTreeMap::new();
    for (pname, p) in m.req("programs")?.as_obj()? {
        programs.insert(
            pname.clone(),
            ProgramInfo {
                file: p.req("file")?.as_str()?.to_string(),
                slots: p.get("slots").map(|v| v.as_usize()).transpose()?,
                state_len: p.get("state_len").map(|v| v.as_usize()).transpose()?,
                out_len: p.get("out_len").map(|v| v.as_usize()).transpose()?,
            },
        );
    }
    let info = ModelInfo {
        name: name.to_string(),
        family: m.req("family")?.as_str()?.to_string(),
        size: m.req("size")?.as_str()?.to_string(),
        n_layers: m.req("n_layers")?.as_usize()?,
        d_model: m.req("d_model")?.as_usize()?,
        n_heads: m.req("n_heads")?.as_usize()?,
        d_ff: m.req("d_ff")?.as_usize()?,
        vocab: m.req("vocab")?.as_usize()?,
        seq_len: m.req("seq_len")?.as_usize()?,
        batch: m.req("batch")?.as_usize()?,
        window: m.req("window")?.as_usize()?,
        n_params: m.req("n_params")?.as_usize()?,
        n_lora_params: m.req("n_lora_params")?.as_usize()?,
        lora_rank: m.req("lora_rank")?.as_usize()?,
        n_entries: m.req("n_entries")?.as_usize()?,
        n_hypers: m.req("n_hypers")?.as_usize()?,
        n_metrics: m.req("n_metrics")?.as_usize()?,
        layout: parse_layout(m.req("layout")?)?,
        lora_layout: parse_layout(m.req("lora_layout")?)?,
        programs,
    };
    // ABI sanity: layout must tile [0, n_params) exactly.
    let mut off = 0;
    for e in &info.layout {
        if e.offset != off {
            bail!("layout entry '{}' offset {} != running {}", e.name, e.offset, off);
        }
        off += e.size;
    }
    if off != info.n_params {
        bail!("layout covers {off} != n_params {}", info.n_params);
    }
    if info.n_entries != info.layout.len() {
        bail!("n_entries mismatch");
    }
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
 "version": 1,
 "hyper_names": ["lr", "eps", "sparsity", "mask_seed", "beta1", "beta2", "adam_eps", "wd"],
 "metric_names": ["l_plus", "l_minus", "proj_grad", "masked_frac", "update_norm_sq", "train_loss", "accept", "reserved"],
 "models": {
  "toy": {
   "family": "llama", "size": "tiny", "n_layers": 1, "d_model": 4,
   "n_heads": 1, "d_ff": 8, "vocab": 16, "seq_len": 8, "batch": 2,
   "window": 0, "n_params": 72, "n_lora_params": 8, "lora_rank": 2,
   "n_entries": 2, "n_hypers": 8, "n_metrics": 8,
   "layout": [
     {"name": "embed.tok", "shape": [16, 4], "kind": "matrix", "offset": 0, "size": 64, "layer_id": 0},
     {"name": "final_norm", "shape": [8], "kind": "vector", "offset": 64, "size": 8, "layer_id": 1}
   ],
   "lora_layout": [
     {"name": "a", "shape": [4, 2], "kind": "matrix", "offset": 0, "size": 8, "layer_id": 0}
   ],
   "programs": {
     "init": {"file": "toy__init.hlo.txt", "out_len": 72},
     "step_mezo": {"file": "toy__step_mezo.hlo.txt", "slots": 0, "state_len": 80}
   }
  }
 }
}"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("smz_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.n_params, 72);
        assert_eq!(toy.step_variants(), vec!["mezo".to_string()]);
        assert_eq!(toy.step_program("mezo").unwrap().state_len, Some(80));
        assert!(toy.step_program("smezo").is_err());
        assert_eq!(m.hyper_index("eps").unwrap(), 1);
        assert!(m.hyper_index("nope").is_err());
        assert_eq!(toy.matrix_entries().count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_gapped_layout() {
        let bad = fake_manifest_json().replace("\"offset\": 64", "\"offset\": 65");
        let dir = std::env::temp_dir().join(format!("smz_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_helpful() {
        let dir = std::env::temp_dir().join("smz_no_such_dir_xyz");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
