//! Paged, tiered parameter storage — the §3.4 / ZO2-style offloading rung.
//!
//! The crate historically assumed "one resident `Vec<f32>` everywhere".
//! [`ParamStore`] replaces that with fixed-size pages over two backings:
//!
//! * **Resident** — the whole vector in memory behind a mutex. Same cost
//!   as before; exists so every layer can hold one store handle type and
//!   so [`AdapterRegistry`](crate::serve::registry::AdapterRegistry) can
//!   hand out cheap `Arc` snapshots instead of O(P) clones.
//! * **File-backed** — parameters live in an unlinked scratch file and
//!   only a bounded LRU page cache is resident. `mmap` is not reachable
//!   from std without libc, so this is the documented std-only fallback:
//!   positioned reads into the cache, dirty pages written back on
//!   eviction. Because dirty cached pages *are* the copy-on-write
//!   overlay, even a dense optimizer's working set stays at the cache
//!   budget — a ZO step only keeps resident the pages its mask recently
//!   touched.
//!
//! Bit-identity is the contract: reads return exactly the f32 bits that
//! were written, runs are iterated in ascending coordinate order, and
//! [`Overlay`] patches reproduce `SparseDelta::swap`-then-read bitwise.
//! The paged trainer/server paths therefore produce byte-identical
//! journals and bit-identical params/logits versus the resident paths
//! (asserted in `tests/jobs.rs` and `tests/serve.rs`).
//!
//! Observability: page faults / evictions / live working-set bytes are
//! tracked both per store and in module-wide atomics that
//! [`sync_registry`] bridges into the metrics registry at every
//! `/metrics` scrape (`store_page_faults_total`,
//! `store_page_evictions_total`, `store_params_bytes`,
//! `store_working_set_bytes`).

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::Result;

/// f32 elements per page: 64 KiB pages, the mmap-friendly granularity
/// the tiered layout is designed around.
pub const PAGE_FLOATS: usize = 16_384;
/// Bytes per full page.
pub const PAGE_BYTES: usize = PAGE_FLOATS * 4;

// Module-wide totals across every store in the process (scrape-time
// gauges/counters; per-store copies exist for deterministic tests).
static FAULTS: AtomicU64 = AtomicU64::new(0);
static EVICTIONS: AtomicU64 = AtomicU64::new(0);
static WORKING_SET: AtomicU64 = AtomicU64::new(0);
static PARAMS_BYTES: AtomicU64 = AtomicU64::new(0);
static SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);

/// Cumulative page faults (file reads into cache) across all stores.
pub fn page_faults_total() -> u64 {
    FAULTS.load(Ordering::Relaxed)
}

/// Cumulative page evictions (incl. dirty write-backs) across all stores.
pub fn page_evictions_total() -> u64 {
    EVICTIONS.load(Ordering::Relaxed)
}

/// Live cached-page bytes across all file-backed stores.
pub fn working_set_total() -> u64 {
    WORKING_SET.load(Ordering::Relaxed)
}

/// Total parameter bytes of the largest file-backed store ever created
/// in this process — the "one full resident copy" baseline that paged
/// working-set peaks are compared against.
pub fn params_bytes_gauge() -> u64 {
    PARAMS_BYTES.load(Ordering::Relaxed)
}

/// Publish the store totals into the metrics registry. Called from the
/// `/metrics` scrape path next to the other gauge syncs. Counters are
/// monotone, so the sync adds the delta since the last publish.
pub fn sync_registry() {
    static PUB_FAULTS: AtomicU64 = AtomicU64::new(0);
    static PUB_EVICTIONS: AtomicU64 = AtomicU64::new(0);
    let f = page_faults_total();
    let prev = PUB_FAULTS.swap(f, Ordering::Relaxed);
    crate::obs::counter("store_page_faults_total", &[]).add(f.saturating_sub(prev));
    let e = page_evictions_total();
    let prev = PUB_EVICTIONS.swap(e, Ordering::Relaxed);
    crate::obs::counter("store_page_evictions_total", &[]).add(e.saturating_sub(prev));
    crate::obs::gauge("store_working_set_bytes", &[]).set(working_set_total() as i64);
    crate::obs::gauge("store_params_bytes", &[]).set(params_bytes_gauge() as i64);
}

struct Page {
    data: Vec<f32>,
    dirty: bool,
    stamp: u64,
}

struct Cache {
    map: HashMap<usize, Page>,
    clock: u64,
}

enum Inner {
    Resident(Mutex<Vec<f32>>),
    File {
        file: File,
        cache: Mutex<Cache>,
        cache_pages: usize,
        faults: AtomicU64,
        evictions: AtomicU64,
    },
}

/// A parameter vector behind a paged storage tier. All methods take
/// `&self`; the store is shared via `Arc` across trainer, scheduler and
/// serve registry.
pub struct ParamStore {
    n: usize,
    inner: Inner,
}

impl ParamStore {
    /// Wrap a fully resident vector (the classic representation).
    pub fn resident(params: Vec<f32>) -> ParamStore {
        ParamStore { n: params.len(), inner: Inner::Resident(Mutex::new(params)) }
    }

    /// Tier `init` out to an unlinked scratch file, keeping at most
    /// `cache_bytes` of pages resident (minimum one page).
    pub fn file_backed(init: &[f32], cache_bytes: usize) -> Result<ParamStore> {
        let mut k = 0usize;
        Self::file_backed_streaming(init.len(), cache_bytes, || {
            let v = init[k];
            k += 1;
            v
        })
    }

    /// Build a file-backed store of `n` params by streaming `gen` page
    /// by page — never materializing the full vector (the `mem-report`
    /// paged arm depends on this: its in-scope peak is the cache budget
    /// plus one page of write buffer, not 4·P).
    pub fn file_backed_streaming(
        n: usize,
        cache_bytes: usize,
        mut gen: impl FnMut() -> f32,
    ) -> Result<ParamStore> {
        let file = scratch_file()?;
        let mut buf: Vec<u8> = Vec::with_capacity(PAGE_BYTES);
        let mut written = 0usize;
        while written < n {
            let len = PAGE_FLOATS.min(n - written);
            buf.clear();
            for _ in 0..len {
                buf.extend_from_slice(&gen().to_le_bytes());
            }
            (&file).write_all(&buf)?;
            written += len;
        }
        (&file).flush()?;
        PARAMS_BYTES.fetch_max((n * 4) as u64, Ordering::Relaxed);
        let cache_pages = (cache_bytes / PAGE_BYTES).max(1);
        Ok(ParamStore {
            n,
            inner: Inner::File {
                file,
                cache: Mutex::new(Cache { map: HashMap::new(), clock: 0 }),
                cache_pages,
                faults: AtomicU64::new(0),
                evictions: AtomicU64::new(0),
            },
        })
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when empty (clippy's `len`-without-`is_empty` convention).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// True for the file-backed (paged) tier.
    pub fn is_paged(&self) -> bool {
        matches!(self.inner, Inner::File { .. })
    }

    /// Page faults charged to this store.
    pub fn faults(&self) -> u64 {
        match &self.inner {
            Inner::Resident(_) => 0,
            Inner::File { faults, .. } => faults.load(Ordering::Relaxed),
        }
    }

    /// Page evictions charged to this store.
    pub fn evictions(&self) -> u64 {
        match &self.inner {
            Inner::Resident(_) => 0,
            Inner::File { evictions, .. } => evictions.load(Ordering::Relaxed),
        }
    }

    /// Bytes resident right now: the full vector for the resident tier,
    /// the cached pages for the file tier.
    pub fn working_set_bytes(&self) -> usize {
        match &self.inner {
            Inner::Resident(_) => self.n * 4,
            Inner::File { cache, .. } => {
                let c = cache.lock().unwrap();
                c.map.values().map(|p| p.data.len() * 4).sum()
            }
        }
    }

    /// Copy `out.len()` params starting at `offset` into `out`.
    pub fn read_into(&self, offset: usize, out: &mut [f32]) {
        assert!(offset + out.len() <= self.n, "store read out of range");
        match &self.inner {
            Inner::Resident(v) => {
                let v = v.lock().unwrap();
                out.copy_from_slice(&v[offset..offset + out.len()]);
            }
            Inner::File { .. } => {
                let mut done = 0usize;
                while done < out.len() {
                    let goff = offset + done;
                    let pidx = goff / PAGE_FLOATS;
                    let poff = goff % PAGE_FLOATS;
                    let take = (PAGE_FLOATS - poff).min(out.len() - done);
                    self.with_page(pidx, false, |data| {
                        out[done..done + take].copy_from_slice(&data[poff..poff + take]);
                    });
                    done += take;
                }
            }
        }
    }

    /// Materialize the whole vector (O(P) — used where a flat copy is
    /// genuinely required, e.g. seeding a journal replay).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        self.read_into(0, &mut out);
        out
    }

    /// Run `f` over the full vector as one flat slice. Resident: borrows
    /// in place (no copy, blocks while a serve checkout holds the base).
    /// File-backed: materializes a temporary copy for the duration.
    pub fn read_all_with<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        match &self.inner {
            Inner::Resident(v) => f(&v.lock().unwrap()),
            Inner::File { .. } => f(&self.to_vec()),
        }
    }

    /// Overwrite `src.len()` params starting at `offset`.
    pub fn write_range(&self, offset: usize, src: &[f32]) {
        assert!(offset + src.len() <= self.n, "store write out of range");
        match &self.inner {
            Inner::Resident(v) => {
                let mut v = v.lock().unwrap();
                v[offset..offset + src.len()].copy_from_slice(src);
            }
            Inner::File { .. } => {
                let mut done = 0usize;
                while done < src.len() {
                    let goff = offset + done;
                    let pidx = goff / PAGE_FLOATS;
                    let poff = goff % PAGE_FLOATS;
                    let take = (PAGE_FLOATS - poff).min(src.len() - done);
                    self.with_page(pidx, true, |data| {
                        data[poff..poff + take].copy_from_slice(&src[done..done + take]);
                    });
                    done += take;
                }
            }
        }
    }

    /// Iterate `[offset, offset+len)` as read-only page runs in
    /// ascending coordinate order: `f(run_global_offset, run_slice)`.
    /// Per-coordinate arithmetic folded over these runs is bit-identical
    /// to folding over one contiguous slice.
    pub fn for_runs(&self, offset: usize, len: usize, mut f: impl FnMut(usize, &[f32])) {
        assert!(offset + len <= self.n, "store run out of range");
        match &self.inner {
            Inner::Resident(v) => {
                let v = v.lock().unwrap();
                f(offset, &v[offset..offset + len]);
            }
            Inner::File { .. } => {
                let mut done = 0usize;
                while done < len {
                    let goff = offset + done;
                    let pidx = goff / PAGE_FLOATS;
                    let poff = goff % PAGE_FLOATS;
                    let take = (PAGE_FLOATS - poff).min(len - done);
                    self.with_page(pidx, false, |data| f(goff, &data[poff..poff + take]));
                    done += take;
                }
            }
        }
    }

    /// Read-modify-write `[offset, offset+len)` as mutable page runs in
    /// ascending coordinate order; touched file pages become dirty
    /// overlay pages (written back only on eviction).
    pub fn update_runs(&self, offset: usize, len: usize, mut f: impl FnMut(usize, &mut [f32])) {
        assert!(offset + len <= self.n, "store update out of range");
        match &self.inner {
            Inner::Resident(v) => {
                let mut v = v.lock().unwrap();
                f(offset, &mut v[offset..offset + len]);
            }
            Inner::File { .. } => {
                let mut done = 0usize;
                while done < len {
                    let goff = offset + done;
                    let pidx = goff / PAGE_FLOATS;
                    let poff = goff % PAGE_FLOATS;
                    let take = (PAGE_FLOATS - poff).min(len - done);
                    self.with_page(pidx, true, |data| f(goff, &mut data[poff..poff + take]));
                    done += take;
                }
            }
        }
    }

    /// Borrow the resident vector for in-place mutation (the serve
    /// registry's copy-free `SparseDelta::swap` checkout). Panics on a
    /// file-backed store — paged serving goes through [`Overlay`].
    pub(crate) fn lock_resident(&self) -> MutexGuard<'_, Vec<f32>> {
        match &self.inner {
            Inner::Resident(v) => v.lock().unwrap(),
            Inner::File { .. } => panic!("lock_resident on a paged store"),
        }
    }

    /// Load page `pidx` into the cache (faulting + evicting as needed)
    /// and run `f` on its data under the cache lock.
    fn with_page<R>(&self, pidx: usize, dirty: bool, f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
        let Inner::File { file, cache, cache_pages, faults, evictions } = &self.inner else {
            unreachable!("with_page on resident store")
        };
        let mut c = cache.lock().unwrap();
        c.clock += 1;
        let stamp = c.clock;
        if !c.map.contains_key(&pidx) {
            // evict LRU pages down to budget, writing dirty ones back
            while c.map.len() >= *cache_pages {
                let victim = *c
                    .map
                    .iter()
                    .min_by_key(|(_, p)| p.stamp)
                    .map(|(k, _)| k)
                    .expect("non-empty cache");
                let page = c.map.remove(&victim).expect("victim present");
                if page.dirty {
                    write_page(file, victim, &page.data);
                }
                WORKING_SET.fetch_sub((page.data.len() * 4) as u64, Ordering::Relaxed);
                evictions.fetch_add(1, Ordering::Relaxed);
                EVICTIONS.fetch_add(1, Ordering::Relaxed);
            }
            let plen = PAGE_FLOATS.min(self.n - pidx * PAGE_FLOATS);
            let data = read_page(file, pidx, plen);
            WORKING_SET.fetch_add((plen * 4) as u64, Ordering::Relaxed);
            faults.fetch_add(1, Ordering::Relaxed);
            FAULTS.fetch_add(1, Ordering::Relaxed);
            c.map.insert(pidx, Page { data, dirty: false, stamp });
        }
        let page = c.map.get_mut(&pidx).expect("page just ensured");
        page.stamp = stamp;
        page.dirty |= dirty;
        f(&mut page.data)
    }
}

impl Drop for ParamStore {
    fn drop(&mut self) {
        if let Inner::File { cache, .. } = &self.inner {
            if let Ok(c) = cache.lock() {
                let live: u64 = c.map.values().map(|p| (p.data.len() * 4) as u64).sum();
                WORKING_SET.fetch_sub(live, Ordering::Relaxed);
            }
        }
    }
}

/// A sparse patch viewed over a base store: reads return the base page
/// run with the adapter's `(idx, val)` coordinates substituted — exactly
/// the bits `SparseDelta::swap` would have installed, without mutating
/// the shared base or materializing a full tenant copy. `idx` must be
/// ascending (the `SparseDelta` invariant).
pub struct Overlay<'a> {
    store: &'a ParamStore,
    idx: &'a [u32],
    val: &'a [f32],
}

impl<'a> Overlay<'a> {
    /// View `(idx, val)` over `store`.
    pub fn new(store: &'a ParamStore, idx: &'a [u32], val: &'a [f32]) -> Overlay<'a> {
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "overlay idx must be ascending");
        Overlay { store, idx, val }
    }

    /// Total parameter count of the underlying store.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// True when the underlying store is empty.
    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Read `[offset, offset+out.len())` with the patch applied.
    pub fn read_run(&self, offset: usize, out: &mut [f32]) {
        self.store.read_into(offset, out);
        let end = offset + out.len();
        let lo = self.idx.partition_point(|&i| (i as usize) < offset);
        let hi = self.idx.partition_point(|&i| (i as usize) < end);
        for k in lo..hi {
            out[self.idx[k] as usize - offset] = self.val[k];
        }
    }
}

fn scratch_file() -> Result<File> {
    let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("smezo-store-{}-{}.page", std::process::id(), seq));
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(&path)?;
    // unlink immediately: the fd keeps the backing alive, nothing leaks
    // on crash (best-effort — on platforms without POSIX unlink-while-
    // open semantics the scratch file simply stays until process exit)
    let _ = std::fs::remove_file(&path);
    Ok(file)
}

fn write_page(file: &File, pidx: usize, data: &[f32]) {
    let mut buf: Vec<u8> = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    (&mut &*file)
        .seek(SeekFrom::Start((pidx * PAGE_BYTES) as u64))
        .and_then(|_| (&mut &*file).write_all(&buf))
        .expect("param store scratch write");
}

fn read_page(file: &File, pidx: usize, plen: usize) -> Vec<f32> {
    let mut buf = vec![0u8; plen * 4];
    (&mut &*file)
        .seek(SeekFrom::Start((pidx * PAGE_BYTES) as u64))
        .and_then(|_| (&mut &*file).read_exact(&mut buf))
        .expect("param store scratch read");
    buf.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i % 251) as f32 - 125.0) / 17.0).collect()
    }

    #[test]
    fn file_backed_reads_match_resident_bitwise() {
        let n = 2 * PAGE_FLOATS + 777; // partial last page
        let v = probe(n);
        let st = ParamStore::file_backed(&v, PAGE_BYTES).unwrap(); // 1-page cache
        assert!(st.is_paged());
        assert_eq!(st.len(), n);
        assert_eq!(st.to_vec(), v);
        // unaligned cross-page range
        let mut out = vec![0.0f32; 5000];
        st.read_into(PAGE_FLOATS - 100, &mut out);
        assert_eq!(out, v[PAGE_FLOATS - 100..PAGE_FLOATS - 100 + 5000]);
        // run iteration covers everything exactly once, ascending
        let mut got = Vec::new();
        st.for_runs(0, n, |off, run| {
            assert_eq!(off, got.len());
            got.extend_from_slice(run);
        });
        assert_eq!(got, v);
    }

    #[test]
    fn dirty_pages_write_back_on_eviction_and_cache_stays_bounded() {
        let n = 4 * PAGE_FLOATS;
        let v = probe(n);
        let st = ParamStore::file_backed(&v, 2 * PAGE_BYTES).unwrap();
        // mutate every coordinate through a 2-page cache
        st.update_runs(0, n, |off, run| {
            for (t, x) in run.iter_mut().enumerate() {
                *x += (off + t) as f32;
            }
        });
        assert!(st.working_set_bytes() <= 2 * PAGE_BYTES, "ws {}", st.working_set_bytes());
        assert!(st.faults() >= 4);
        assert!(st.evictions() >= 2, "evictions {}", st.evictions());
        // every write survived eviction round-trips through the file
        let got = st.to_vec();
        for (i, (g, orig)) in got.iter().zip(v.iter()).enumerate() {
            assert_eq!(g.to_bits(), (orig + i as f32).to_bits(), "coord {i}");
        }
    }

    #[test]
    fn write_range_round_trips_across_page_boundary() {
        let n = PAGE_FLOATS + 50;
        let st = ParamStore::file_backed(&vec![0.0; n], PAGE_BYTES).unwrap();
        let patch: Vec<f32> = (0..120).map(|i| i as f32 * 0.5 - 7.0).collect();
        let off = PAGE_FLOATS - 60;
        st.write_range(off, &patch);
        let mut out = vec![0.0f32; 120];
        st.read_into(off, &mut out);
        assert_eq!(out, patch);
        assert_eq!(st.to_vec()[off - 1], 0.0);
    }

    #[test]
    fn streaming_init_equals_eager_init() {
        let n = PAGE_FLOATS + 123;
        let v = probe(n);
        let eager = ParamStore::file_backed(&v, PAGE_BYTES).unwrap();
        let mut k = 0usize;
        let streamed = ParamStore::file_backed_streaming(n, PAGE_BYTES, || {
            let x = v[k];
            k += 1;
            x
        })
        .unwrap();
        assert_eq!(eager.to_vec(), streamed.to_vec());
    }

    #[test]
    fn resident_store_has_full_working_set_and_no_faults() {
        let v = probe(1000);
        let st = ParamStore::resident(v.clone());
        assert!(!st.is_paged());
        assert_eq!(st.working_set_bytes(), 4000);
        assert_eq!(st.to_vec(), v);
        assert_eq!((st.faults(), st.evictions()), (0, 0));
        st.read_all_with(|s| assert_eq!(s, &v[..]));
    }

    #[test]
    fn overlay_read_matches_swap_then_read_bitwise() {
        let n = PAGE_FLOATS + 400;
        let base = probe(n);
        let idx: Vec<u32> =
            vec![0, 3, (PAGE_FLOATS - 1) as u32, PAGE_FLOATS as u32, (n - 1) as u32];
        let val: Vec<f32> = vec![9.25, -3.5, 0.015625, 1e-20, -0.0];
        // reference: install into a flat copy
        let mut swapped = base.clone();
        for (i, v) in idx.iter().zip(val.iter()) {
            swapped[*i as usize] = *v;
        }
        let st = ParamStore::file_backed(&base, PAGE_BYTES).unwrap();
        let ov = Overlay::new(&st, &idx, &val);
        assert_eq!(ov.len(), n);
        for (off, len) in [(0usize, 10usize), (PAGE_FLOATS - 5, 10), (n - 3, 3), (0, n)] {
            let mut out = vec![0.0f32; len];
            ov.read_run(off, &mut out);
            for (t, x) in out.iter().enumerate() {
                assert_eq!(x.to_bits(), swapped[off + t].to_bits(), "off {off} t {t}");
            }
        }
    }

    #[test]
    fn module_totals_accumulate() {
        let before = (page_faults_total(), page_evictions_total());
        let n = 3 * PAGE_FLOATS;
        let st = ParamStore::file_backed(&probe(n), PAGE_BYTES).unwrap();
        let mut sink = 0.0f32;
        st.for_runs(0, n, |_, run| sink += run[0]);
        assert!(sink.is_finite());
        assert!(page_faults_total() >= before.0 + 3);
        assert!(page_evictions_total() >= before.1 + 2);
        assert!(params_bytes_gauge() >= (n * 4) as u64);
        drop(st);
    }
}
