//! Backend-resident packed training state.
//!
//! `state = [params f32[P] | opt slots f32[S] | metrics f32[K]]` lives
//! wherever the active [`Backend`](super::backend::Backend) keeps compute
//! state — host memory for the native backend, a device buffer for PJRT —
//! and is *chained* through step executions so parameters never round-trip
//! through the coordinator during training. The only per-step host traffic
//! is the K-element metric tail, which is the design that keeps
//! coordinator overhead negligible (see `benches/coordinator_overhead.rs`).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::store::ParamStore;
use super::Runtime;

/// Where the packed state actually lives.
pub enum StateBuf {
    /// Host memory (native backend): the packed vector itself.
    Host(Vec<f32>),
    /// Paged tier (native backend, `--page-cache-bytes > 0`): the
    /// parameter prefix lives in a file-backed [`ParamStore`] whose
    /// resident footprint is the page-cache budget; the short
    /// `[slots | metrics]` tail stays host-resident.
    Paged {
        /// paged parameter prefix (`P` floats)
        store: Arc<ParamStore>,
        /// host-resident `[slots | metrics]` tail (`S + K` floats)
        tail: Vec<f32>,
    },
    /// Device-resident PJRT buffer (pjrt backend).
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

/// The packed `[params | slots | metrics]` training state.
pub struct TrainState {
    /// Backing storage, owned by the backend that created it.
    pub(crate) buf: StateBuf,
    /// parameter count
    pub p: usize,
    /// optimizer slot count
    pub s: usize,
    /// metric slot count
    pub k: usize,
}

impl TrainState {
    /// Total packed length `P + S + K`.
    pub fn state_len(&self) -> usize {
        self.p + self.s + self.k
    }

    /// Assemble a fresh state from host parameters
    /// (slots and metrics zeroed).
    pub fn from_params(rt: &Runtime, params: &[f32], s: usize, k: usize) -> Result<TrainState> {
        let mut host = Vec::with_capacity(params.len() + s + k);
        host.extend_from_slice(params);
        host.resize(params.len() + s + k, 0.0);
        rt.backend().new_state(host, params.len(), s, k)
    }

    /// Assemble a fresh *paged* state: the parameter prefix is tiered
    /// out to a file-backed [`ParamStore`] bounded by `cache_bytes` of
    /// resident pages; slots and metrics are zeroed host-side. Only the
    /// native backend's stateless ZO family executes against this
    /// representation (`runtime/native.rs::step_paged`).
    pub fn from_params_paged(
        params: &[f32],
        s: usize,
        k: usize,
        cache_bytes: usize,
    ) -> Result<TrainState> {
        let store = Arc::new(ParamStore::file_backed(params, cache_bytes)?);
        Ok(TrainState {
            buf: StateBuf::Paged { store, tail: vec![0.0; s + k] },
            p: params.len(),
            s,
            k,
        })
    }

    /// Assemble with pre-filled slots (checkpoint restore, LoRA adapters).
    pub fn from_parts(rt: &Runtime, params: &[f32], slots: &[f32], k: usize) -> Result<TrainState> {
        let mut host = Vec::with_capacity(params.len() + slots.len() + k);
        host.extend_from_slice(params);
        host.extend_from_slice(slots);
        host.resize(params.len() + slots.len() + k, 0.0);
        rt.backend().new_state(host, params.len(), slots.len(), k)
    }

    /// Read the K-element metric tail (cheap partial copy).
    pub fn metrics(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.backend().read_state(self, self.p + self.s, self.k)
    }

    /// Read the parameter prefix (checkpointing, eval, analysis).
    pub fn params_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.backend().read_state(self, 0, self.p)
    }

    /// Read one layout segment of the parameters.
    pub fn segment_host(&self, rt: &Runtime, offset: usize, len: usize) -> Result<Vec<f32>> {
        if offset + len > self.p {
            bail!("segment [{offset}, +{len}) out of params range {}", self.p);
        }
        rt.backend().read_state(self, offset, len)
    }

    /// Read optimizer slots (checkpointing).
    pub fn slots_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.backend().read_state(self, self.p, self.s)
    }

    /// First `n` floats of the slot block (the LoRA adapter segment).
    pub fn segment_slots(&self, rt: &Runtime, n: usize) -> Result<Vec<f32>> {
        if n > self.s {
            bail!("slot segment {n} > slots {}", self.s);
        }
        rt.backend().read_state(self, self.p, n)
    }

    /// Live backend bytes held by this state (Table-4 measured accounting).
    pub fn device_bytes(&self) -> usize {
        self.state_len() * 4
    }

    /// Host view of the packed state (native backend, resident only).
    pub(crate) fn host(&self) -> Result<&[f32]> {
        match &self.buf {
            StateBuf::Host(v) => Ok(v),
            StateBuf::Paged { .. } => bail!("state is paged, no contiguous host buffer"),
            #[cfg(feature = "pjrt")]
            StateBuf::Pjrt(_) => bail!("state is device-resident, not host"),
        }
    }

    /// Mutable host view of the packed state (native backend, resident
    /// only).
    pub(crate) fn host_mut(&mut self) -> Result<&mut Vec<f32>> {
        match &mut self.buf {
            StateBuf::Host(v) => Ok(v),
            StateBuf::Paged { .. } => bail!("state is paged, no contiguous host buffer"),
            #[cfg(feature = "pjrt")]
            StateBuf::Pjrt(_) => bail!("state is device-resident, not host"),
        }
    }
}
