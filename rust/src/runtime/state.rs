//! Device-resident packed training state.
//!
//! `state = [params f32[P] | opt slots f32[S] | metrics f32[K]]` lives as a
//! single PJRT buffer and is *chained* through step executions via
//! `execute_b` — parameters never round-trip through the host during
//! training. The only per-step host traffic is the K-element metric tail
//! (partial `copy_raw_to_host_sync`), which is the design that makes the
//! coordinator overhead negligible (EXPERIMENTS.md §Perf).

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use super::client::Runtime;

pub struct TrainState {
    pub buffer: PjRtBuffer,
    /// parameter count
    pub p: usize,
    /// optimizer slot count
    pub s: usize,
    /// metric slot count
    pub k: usize,
}

impl TrainState {
    pub fn state_len(&self) -> usize {
        self.p + self.s + self.k
    }

    /// Assemble a fresh state on device from host parameters
    /// (slots and metrics zeroed).
    pub fn from_params(rt: &Runtime, params: &[f32], s: usize, k: usize) -> Result<TrainState> {
        let mut host = Vec::with_capacity(params.len() + s + k);
        host.extend_from_slice(params);
        host.resize(params.len() + s + k, 0.0);
        let buffer = rt.upload_f32(&host, &[host.len()])?;
        Ok(TrainState { buffer, p: params.len(), s, k })
    }

    /// Assemble with pre-filled slots (checkpoint restore).
    pub fn from_parts(rt: &Runtime, params: &[f32], slots: &[f32], k: usize) -> Result<TrainState> {
        let mut host = Vec::with_capacity(params.len() + slots.len() + k);
        host.extend_from_slice(params);
        host.extend_from_slice(slots);
        host.resize(params.len() + slots.len() + k, 0.0);
        let buffer = rt.upload_f32(&host, &[host.len()])?;
        Ok(TrainState { buffer, p: params.len(), s: slots.len(), k })
    }

    /// Adopt the output buffer of a step execution.
    pub fn replace(&mut self, new_buffer: PjRtBuffer) {
        self.buffer = new_buffer;
    }

    /// Read the K-element metric tail (cheap partial copy).
    pub fn metrics(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.download_f32_at(&self.buffer, self.p + self.s, self.k)
    }

    /// Read the parameter prefix (checkpointing, eval, analysis).
    pub fn params_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.download_f32_at(&self.buffer, 0, self.p)
    }

    /// Read one layout segment of the parameters.
    pub fn segment_host(&self, rt: &Runtime, offset: usize, len: usize) -> Result<Vec<f32>> {
        if offset + len > self.p {
            bail!("segment [{offset}, +{len}) out of params range {}", self.p);
        }
        rt.download_f32_at(&self.buffer, offset, len)
    }

    /// Read optimizer slots (checkpointing).
    pub fn slots_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.download_f32_at(&self.buffer, self.p, self.s)
    }

    /// Live device bytes held by this state (Table-4 measured accounting).
    pub fn device_bytes(&self) -> usize {
        self.state_len() * 4
    }
}
