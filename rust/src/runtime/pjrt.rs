//! The PJRT execution backend (behind the `pjrt` cargo feature).
//!
//! Runs the AOT-compiled XLA programs under `artifacts/` (lowered by
//! `python/compile/aot.py`) through the PJRT C API. Compilation of a step
//! program takes O(seconds); every experiment reuses the same handful of
//! programs, so executables are cached by artifact file name for the
//! lifetime of the backend. Interchange is HLO text (see aot.py for why
//! not serialized protos).
//!
//! NOTE: the workspace vendors an API *stub* for the `xla` crate
//! (`rust/vendor/xla`) so this module type-checks offline; against the
//! stub every entry point reports "PJRT unavailable" and
//! [`Runtime::new`](super::Runtime::new) falls back to the native
//! backend. Link the real `xla` crate to execute artifacts.
//!
//! UPLOAD-ONCE CACHING (closes the ROADMAP open item left by the
//! backend-trait port): `logits`/`logits_lora` receive parameters as
//! host slices and `step` receives hypers + thresholds per call, but
//! within one evaluation pass / training run those inputs are *the same
//! bytes* call after call. A small content-addressed device-buffer
//! cache ([`BufCache`]) therefore keys constant-ish f32 uploads by an
//! FNV-1a hash of their bits + dims and reuses the device buffer on
//! hit: the params vector uploads once per eval pass instead of once
//! per batch, and the 8-float hypers / L-float thresholds upload once
//! per run instead of once per step. Hashing a params slice is a read
//! of the same bytes the upload would copy anyway, so a miss costs
//! ~one extra pass over the data and a hit saves the transfer
//! entirely. Tokens/labels/seeds change every call and stay uncached.
//! The packed training state itself still never round-trips.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::Backend;
use super::exec::Hypers;
use super::manifest::{Manifest, ModelInfo, ProgramInfo};
use super::state::{StateBuf, TrainState};

/// Content-addressed cache of constant-ish f32 device buffers (params,
/// hypers, thresholds). FIFO-bounded: the working set is a handful of
/// distinct values per run, so `CAP` entries with first-in eviction is
/// plenty and keeps worst-case device memory bounded at
/// `CAP * max(P) * 4` bytes.
struct BufCache {
    map: HashMap<u64, Arc<PjRtBuffer>>,
    order: VecDeque<u64>,
}

impl BufCache {
    /// Bounded entry count (largest entries are full param vectors).
    const CAP: usize = 8;

    fn new() -> BufCache {
        BufCache { map: HashMap::new(), order: VecDeque::new() }
    }
}

/// FNV-1a (word-at-a-time) over an f32 slice's raw bits and its dims —
/// the upload-once cache key. Bit-exact: distinct NaN payloads or
/// -0.0/+0.0 hash differently, which is the conservative direction.
fn content_key_f32(data: &[f32], dims: &[usize]) -> u64 {
    const PRIME: u64 = 0x100_0000_01b3; // FNV-1a 64-bit prime, 2^40 + 0x1b3
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &d in dims {
        h ^= d as u64;
        h = h.wrapping_mul(PRIME);
    }
    h ^= data.len() as u64;
    h = h.wrapping_mul(PRIME);
    for &v in data {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Backend that owns the PJRT client, the manifest, and the executable
/// cache. Interior caches are mutex-guarded so the sweep driver can share
/// one backend across scoped threads (PJRT CPU executions serialize on
/// the cache only during compile, not during execute).
pub struct PjrtBackend {
    client: PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    /// upload-once device buffers for constant-ish inputs (see module docs)
    buf_cache: Mutex<BufCache>,
    /// cumulative compile seconds (perf accounting)
    compile_seconds: Mutex<f64>,
}

impl PjrtBackend {
    /// Load the manifest from `artifacts_dir` and start the CPU client.
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
        Self::with_manifest(Manifest::load(artifacts_dir)?)
    }

    /// Start the CPU client against an already-loaded manifest. Errors
    /// here mean "PJRT itself is unavailable" (the caller may fall back
    /// to native), never "the manifest is bad".
    pub fn with_manifest(manifest: Manifest) -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        crate::info!(
            "PJRT platform={} devices={} | {} models in manifest",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(PjrtBackend {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            buf_cache: Mutex::new(BufCache::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    /// Load + compile (cached) one program.
    fn load(&self, prog: &ProgramInfo) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&prog.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(prog);
        let t0 = std::time::Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parsing HLO {}: {e:?}", path.display()))
            .with_context(|| "artifact missing or stale — run `make artifacts`")?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let dt = t0.elapsed().as_secs_f64();
        *self.compile_seconds.lock().unwrap() += dt;
        crate::debug!("compiled {} in {:.2}s", prog.file, dt);
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(prog.file.clone(), exe.clone());
        Ok(exe)
    }

    // ---- host <-> device helpers -----------------------------------------

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    /// Upload-once path for constant-ish f32 inputs (params, hypers,
    /// thresholds): content-hash the bytes, reuse the device buffer on a
    /// hit, upload + remember on a miss.
    fn cached_upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Arc<PjRtBuffer>> {
        let key = content_key_f32(data, dims);
        if let Some(buf) = self.buf_cache.lock().unwrap().map.get(&key).cloned() {
            return Ok(buf);
        }
        let buf = Arc::new(self.upload_f32(data, dims)?);
        let mut cache = self.buf_cache.lock().unwrap();
        if !cache.map.contains_key(&key) {
            if cache.order.len() >= BufCache::CAP {
                if let Some(evicted) = cache.order.pop_front() {
                    cache.map.remove(&evicted);
                }
            }
            cache.order.push_back(key);
            cache.map.insert(key, buf.clone());
        }
        Ok(buf)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    fn upload_u32(&self, data: &[u32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload u32 {dims:?}: {e:?}"))
    }

    /// Ranged f32 readback (element offset). The TFRT CPU PJRT plugin
    /// does not implement partial raw reads, so readback goes through a
    /// full literal copy + host-side slice; on the CPU "device" this is a
    /// host memcpy. The packed-state design still avoids re-UPLOADING
    /// parameters each step, which is the expensive direction.
    fn download_f32_at(&self, buf: &PjRtBuffer, offset: usize, len: usize) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download f32[{offset}..+{len}]: {e:?}"))?;
        let all: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        if offset + len > all.len() {
            anyhow::bail!("range [{offset}, +{len}) out of buffer len {}", all.len());
        }
        Ok(all[offset..offset + len].to_vec())
    }

    fn single_output(mut outs: Vec<Vec<PjRtBuffer>>, what: &str) -> Result<PjRtBuffer> {
        if outs.len() != 1 || outs[0].len() != 1 {
            anyhow::bail!(
                "{what}: expected 1 output buffer, got {}x{}",
                outs.len(),
                outs.first().map(|v| v.len()).unwrap_or(0)
            );
        }
        Ok(outs.remove(0).remove(0))
    }

    /// Run a single-output program whose inputs are already uploaded.
    fn run1(&self, prog: &ProgramInfo, args: &[&PjRtBuffer], what: &str) -> Result<PjRtBuffer> {
        let exe = self.load(prog)?;
        let outs = exe.execute_b(args).map_err(|e| anyhow!("{what}: {e:?}"))?;
        Self::single_output(outs, what)
    }

    fn state_buffer<'s>(state: &'s TrainState, what: &str) -> Result<&'s PjRtBuffer> {
        match &state.buf {
            StateBuf::Pjrt(b) => Ok(b),
            StateBuf::Host(_) => anyhow::bail!("{what}: state is host-resident, not a PJRT buffer"),
        }
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>> {
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self.run1(model.program("init")?, &[&seed_buf], "init")?;
        self.download_f32_at(&out, 0, model.n_params)
    }

    fn init_lora(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>> {
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self.run1(model.program("init_lora")?, &[&seed_buf], "init_lora")?;
        self.download_f32_at(&out, 0, model.n_lora_params)
    }

    fn thresholds(&self, model: &ModelInfo, params: &[f32], sparsity: f32) -> Result<Vec<f32>> {
        if params.len() != model.n_params {
            anyhow::bail!("thresh: params len {} != {}", params.len(), model.n_params);
        }
        let p_buf = self.cached_upload_f32(params, &[params.len()])?;
        let s_buf = self.upload_f32(&[sparsity], &[1])?;
        let out = self.run1(model.program("thresh")?, &[p_buf.as_ref(), &s_buf], "thresh")?;
        self.download_f32_at(&out, 0, model.n_entries)
    }

    fn new_state(&self, host: Vec<f32>, p: usize, s: usize, k: usize) -> Result<TrainState> {
        if host.len() != p + s + k {
            anyhow::bail!("state vector len {} != {p}+{s}+{k}", host.len());
        }
        let buffer = self.upload_f32(&host, &[host.len()])?;
        Ok(TrainState { buf: StateBuf::Pjrt(buffer), p, s, k })
    }

    fn read_state(&self, state: &TrainState, offset: usize, len: usize) -> Result<Vec<f32>> {
        let buf = Self::state_buffer(state, "read_state")?;
        self.download_f32_at(buf, offset, len)
    }

    fn step(
        &self,
        model: &ModelInfo,
        optimizer: &str,
        hypers: &Hypers,
        thresholds: &[f32],
        state: &mut TrainState,
        tokens: &[i32],
        labels: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        let prog = model.step_program(optimizer)?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let lab_buf = self.upload_i32(labels, &[model.batch])?;
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        // hypers + thresholds are constant across a run: upload-once
        let hyp_buf = self.cached_upload_f32(&hypers.to_vec(), &[8])?;
        let thr_buf = self.cached_upload_f32(thresholds, &[thresholds.len()])?;
        let out = {
            let state_buf = Self::state_buffer(state, "step")?;
            self.run1(
                prog,
                &[state_buf, &tok_buf, &lab_buf, &seed_buf, hyp_buf.as_ref(), thr_buf.as_ref()],
                &format!("step({optimizer})"),
            )?
        };
        state.buf = StateBuf::Pjrt(out);
        Ok(())
    }

    fn pretrain_step(
        &self,
        model: &ModelInfo,
        hypers: &Hypers,
        state: &mut TrainState,
        tokens: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        let prog = model.program("pretrain")?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let hyp_buf = self.cached_upload_f32(&hypers.to_vec(), &[8])?;
        let out = {
            let state_buf = Self::state_buffer(state, "pretrain")?;
            self.run1(prog, &[state_buf, &tok_buf, &seed_buf, hyp_buf.as_ref()], "pretrain")?
        };
        state.buf = StateBuf::Pjrt(out);
        Ok(())
    }

    fn logits(&self, model: &ModelInfo, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        // the same params slice arrives for every batch of an eval pass:
        // upload-once instead of once per batch
        let p_buf = self.cached_upload_f32(params, &[params.len()])?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let out = self.run1(model.program("logits")?, &[p_buf.as_ref(), &tok_buf], "logits")?;
        self.download_f32_at(&out, 0, model.batch * model.vocab)
    }

    fn logits_lora(
        &self,
        model: &ModelInfo,
        params: &[f32],
        adapters: &[f32],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let p_buf = self.cached_upload_f32(params, &[params.len()])?;
        let a_buf = self.cached_upload_f32(adapters, &[adapters.len()])?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let out = self.run1(
            model.program("logits_lora")?,
            &[p_buf.as_ref(), a_buf.as_ref(), &tok_buf],
            "logits_lora",
        )?;
        self.download_f32_at(&out, 0, model.batch * model.vocab)
    }

    fn compile_check(&self, model: &ModelInfo, program: &str) -> Result<()> {
        self.load(model.program(program)?).map(|_| ())
    }

    fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn total_compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_key_is_bit_exact_and_dim_sensitive() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 3.0];
        assert_eq!(content_key_f32(&a, &[3]), content_key_f32(&b, &[3]));
        // same data under different dims is a different device value
        assert_ne!(content_key_f32(&a, &[3]), content_key_f32(&a, &[1, 3]));
        // any bit flip changes the key
        let c = [1.0f32, 2.0, 4.0];
        assert_ne!(content_key_f32(&a, &[3]), content_key_f32(&c, &[3]));
        // -0.0 vs +0.0 are distinct bit patterns — conservative direction
        assert_ne!(content_key_f32(&[0.0], &[1]), content_key_f32(&[-0.0], &[1]));
    }

    #[test]
    fn buf_cache_evicts_fifo_at_capacity() {
        let mut cache = BufCache::new();
        // exercise the bookkeeping without a live client: keys only
        for k in 0..(BufCache::CAP as u64 + 3) {
            if cache.order.len() >= BufCache::CAP {
                let evicted = cache.order.pop_front().unwrap();
                cache.map.remove(&evicted);
            }
            cache.order.push_back(k);
        }
        assert_eq!(cache.order.len(), BufCache::CAP);
        assert_eq!(*cache.order.front().unwrap(), 3);
    }
}
