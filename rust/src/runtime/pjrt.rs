//! The PJRT execution backend (behind the `pjrt` cargo feature).
//!
//! Runs the AOT-compiled XLA programs under `artifacts/` (lowered by
//! `python/compile/aot.py`) through the PJRT C API. Compilation of a step
//! program takes O(seconds); every experiment reuses the same handful of
//! programs, so executables are cached by artifact file name for the
//! lifetime of the backend. Interchange is HLO text (see aot.py for why
//! not serialized protos).
//!
//! NOTE: the workspace vendors an API *stub* for the `xla` crate
//! (`rust/vendor/xla`) so this module type-checks offline; against the
//! stub every entry point reports "PJRT unavailable" and
//! [`Runtime::new`](super::Runtime::new) falls back to the native
//! backend. Link the real `xla` crate to execute artifacts.
//!
//! KNOWN COST (tracked in ROADMAP.md): the backend-trait port passes
//! parameters as host slices, so `logits`/`logits_lora` re-upload the
//! full `f32[P]` vector per evaluation batch and `step` re-uploads the
//! 8-float hypers + L-float thresholds per step — the pre-refactor
//! wrappers cached those device buffers across calls. Restore an
//! upload-once params handle (a backend-owned buffer cache) when the
//! real `xla` crate is linked; on the CPU plugin the upload is a host
//! memcpy, and the packed training state itself still never round-trips.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::Backend;
use super::exec::Hypers;
use super::manifest::{Manifest, ModelInfo, ProgramInfo};
use super::state::{StateBuf, TrainState};

/// Backend that owns the PJRT client, the manifest, and the executable
/// cache. Interior caches are mutex-guarded so the sweep driver can share
/// one backend across scoped threads (PJRT CPU executions serialize on
/// the cache only during compile, not during execute).
pub struct PjrtBackend {
    client: PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    /// cumulative compile seconds (perf accounting)
    compile_seconds: Mutex<f64>,
}

impl PjrtBackend {
    /// Load the manifest from `artifacts_dir` and start the CPU client.
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
        Self::with_manifest(Manifest::load(artifacts_dir)?)
    }

    /// Start the CPU client against an already-loaded manifest. Errors
    /// here mean "PJRT itself is unavailable" (the caller may fall back
    /// to native), never "the manifest is bad".
    pub fn with_manifest(manifest: Manifest) -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        crate::info!(
            "PJRT platform={} devices={} | {} models in manifest",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(PjrtBackend {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    /// Load + compile (cached) one program.
    fn load(&self, prog: &ProgramInfo) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&prog.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(prog);
        let t0 = std::time::Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parsing HLO {}: {e:?}", path.display()))
            .with_context(|| "artifact missing or stale — run `make artifacts`")?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let dt = t0.elapsed().as_secs_f64();
        *self.compile_seconds.lock().unwrap() += dt;
        crate::debug!("compiled {} in {:.2}s", prog.file, dt);
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(prog.file.clone(), exe.clone());
        Ok(exe)
    }

    // ---- host <-> device helpers -----------------------------------------

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    fn upload_u32(&self, data: &[u32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload u32 {dims:?}: {e:?}"))
    }

    /// Ranged f32 readback (element offset). The TFRT CPU PJRT plugin
    /// does not implement partial raw reads, so readback goes through a
    /// full literal copy + host-side slice; on the CPU "device" this is a
    /// host memcpy. The packed-state design still avoids re-UPLOADING
    /// parameters each step, which is the expensive direction.
    fn download_f32_at(&self, buf: &PjRtBuffer, offset: usize, len: usize) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download f32[{offset}..+{len}]: {e:?}"))?;
        let all: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        if offset + len > all.len() {
            anyhow::bail!("range [{offset}, +{len}) out of buffer len {}", all.len());
        }
        Ok(all[offset..offset + len].to_vec())
    }

    fn single_output(mut outs: Vec<Vec<PjRtBuffer>>, what: &str) -> Result<PjRtBuffer> {
        if outs.len() != 1 || outs[0].len() != 1 {
            anyhow::bail!(
                "{what}: expected 1 output buffer, got {}x{}",
                outs.len(),
                outs.first().map(|v| v.len()).unwrap_or(0)
            );
        }
        Ok(outs.remove(0).remove(0))
    }

    /// Run a single-output program whose inputs are already uploaded.
    fn run1(&self, prog: &ProgramInfo, args: &[&PjRtBuffer], what: &str) -> Result<PjRtBuffer> {
        let exe = self.load(prog)?;
        let outs = exe.execute_b(args).map_err(|e| anyhow!("{what}: {e:?}"))?;
        Self::single_output(outs, what)
    }

    fn state_buffer<'s>(state: &'s TrainState, what: &str) -> Result<&'s PjRtBuffer> {
        match &state.buf {
            StateBuf::Pjrt(b) => Ok(b),
            StateBuf::Host(_) => anyhow::bail!("{what}: state is host-resident, not a PJRT buffer"),
        }
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>> {
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self.run1(model.program("init")?, &[&seed_buf], "init")?;
        self.download_f32_at(&out, 0, model.n_params)
    }

    fn init_lora(&self, model: &ModelInfo, seed: (u32, u32)) -> Result<Vec<f32>> {
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self.run1(model.program("init_lora")?, &[&seed_buf], "init_lora")?;
        self.download_f32_at(&out, 0, model.n_lora_params)
    }

    fn thresholds(&self, model: &ModelInfo, params: &[f32], sparsity: f32) -> Result<Vec<f32>> {
        if params.len() != model.n_params {
            anyhow::bail!("thresh: params len {} != {}", params.len(), model.n_params);
        }
        let p_buf = self.upload_f32(params, &[params.len()])?;
        let s_buf = self.upload_f32(&[sparsity], &[1])?;
        let out = self.run1(model.program("thresh")?, &[&p_buf, &s_buf], "thresh")?;
        self.download_f32_at(&out, 0, model.n_entries)
    }

    fn new_state(&self, host: Vec<f32>, p: usize, s: usize, k: usize) -> Result<TrainState> {
        if host.len() != p + s + k {
            anyhow::bail!("state vector len {} != {p}+{s}+{k}", host.len());
        }
        let buffer = self.upload_f32(&host, &[host.len()])?;
        Ok(TrainState { buf: StateBuf::Pjrt(buffer), p, s, k })
    }

    fn read_state(&self, state: &TrainState, offset: usize, len: usize) -> Result<Vec<f32>> {
        let buf = Self::state_buffer(state, "read_state")?;
        self.download_f32_at(buf, offset, len)
    }

    fn step(
        &self,
        model: &ModelInfo,
        optimizer: &str,
        hypers: &Hypers,
        thresholds: &[f32],
        state: &mut TrainState,
        tokens: &[i32],
        labels: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        let prog = model.step_program(optimizer)?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let lab_buf = self.upload_i32(labels, &[model.batch])?;
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let hyp_buf = self.upload_f32(&hypers.to_vec(), &[8])?;
        let thr_buf = self.upload_f32(thresholds, &[thresholds.len()])?;
        let out = {
            let state_buf = Self::state_buffer(state, "step")?;
            self.run1(
                prog,
                &[state_buf, &tok_buf, &lab_buf, &seed_buf, &hyp_buf, &thr_buf],
                &format!("step({optimizer})"),
            )?
        };
        state.buf = StateBuf::Pjrt(out);
        Ok(())
    }

    fn pretrain_step(
        &self,
        model: &ModelInfo,
        hypers: &Hypers,
        state: &mut TrainState,
        tokens: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        let prog = model.program("pretrain")?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let seed_buf = self.upload_u32(&[seed.0, seed.1], &[2])?;
        let hyp_buf = self.upload_f32(&hypers.to_vec(), &[8])?;
        let out = {
            let state_buf = Self::state_buffer(state, "pretrain")?;
            self.run1(prog, &[state_buf, &tok_buf, &seed_buf, &hyp_buf], "pretrain")?
        };
        state.buf = StateBuf::Pjrt(out);
        Ok(())
    }

    fn logits(&self, model: &ModelInfo, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let p_buf = self.upload_f32(params, &[params.len()])?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let out = self.run1(model.program("logits")?, &[&p_buf, &tok_buf], "logits")?;
        self.download_f32_at(&out, 0, model.batch * model.vocab)
    }

    fn logits_lora(
        &self,
        model: &ModelInfo,
        params: &[f32],
        adapters: &[f32],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let p_buf = self.upload_f32(params, &[params.len()])?;
        let a_buf = self.upload_f32(adapters, &[adapters.len()])?;
        let tok_buf = self.upload_i32(tokens, &[model.batch, model.seq_len])?;
        let out =
            self.run1(model.program("logits_lora")?, &[&p_buf, &a_buf, &tok_buf], "logits_lora")?;
        self.download_f32_at(&out, 0, model.batch * model.vocab)
    }

    fn compile_check(&self, model: &ModelInfo, program: &str) -> Result<()> {
        self.load(model.program(program)?).map(|_| ())
    }

    fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn total_compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }
}
