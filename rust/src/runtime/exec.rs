//! Typed program wrappers enforcing the packed-state ABI.
//!
//! Each wrapper pins the argument shapes of one program class so the
//! coordinator can't mis-call the backend: shape mismatches fail at the
//! call site with an actionable message, before any compute runs. The
//! wrappers are thin — all execution routes through the active
//! [`Backend`](super::backend::Backend), so the same coordinator code
//! drives the native model and the PJRT artifacts.

use anyhow::{bail, Result};

use super::manifest::ModelInfo;
use super::state::TrainState;
use super::Runtime;

/// The 8-slot hyperparameter vector (`Manifest::hyper_names` order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hypers {
    /// learning rate
    pub lr: f32,
    /// ZO perturbation scale (paper's epsilon, 1e-3 throughout)
    pub eps: f32,
    /// S-MeZO sparsity (fraction of matrix coordinates frozen)
    pub sparsity: f32,
    /// R-MeZO Bernoulli-mask seed (carried as f32 in the hyper vector)
    pub mask_seed: f32,
    /// Adam first-moment decay
    pub beta1: f32,
    /// Adam second-moment decay
    pub beta2: f32,
    /// Adam denominator epsilon
    pub adam_eps: f32,
    /// decoupled weight decay
    pub wd: f32,
}

impl Default for Hypers {
    fn default() -> Self {
        Hypers {
            lr: 1e-3,
            eps: 1e-3,
            sparsity: 0.75,
            mask_seed: 42.0,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            wd: 0.0,
        }
    }
}

impl Hypers {
    /// The vector form uploaded to step programs (hyper_names order).
    pub fn to_vec(self) -> Vec<f32> {
        vec![
            self.lr,
            self.eps,
            self.sparsity,
            self.mask_seed,
            self.beta1,
            self.beta2,
            self.adam_eps,
            self.wd,
        ]
    }
}

/// Per-step metrics decoded from the packed tail
/// (`Manifest::metric_names` order).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    /// loss at `theta + eps * m ⊙ z`
    pub l_plus: f32,
    /// loss at `theta - eps * m ⊙ z`
    pub l_minus: f32,
    /// projected gradient `(l_plus - l_minus) / (2 eps)`
    pub proj_grad: f32,
    /// fraction of coordinates the mask selected this step
    pub masked_frac: f32,
    /// squared L2 norm of the applied update
    pub update_norm_sq: f32,
    /// the step's training-loss proxy (divergence detection reads this)
    pub train_loss: f32,
    /// 1 if the update was applied (conservative variants may reject)
    pub accept: f32,
}

impl StepMetrics {
    /// Decode the metric tail read back from a [`TrainState`].
    pub fn from_tail(tail: &[f32]) -> Result<StepMetrics> {
        if tail.len() < 7 {
            bail!("metric tail too short: {}", tail.len());
        }
        Ok(StepMetrics {
            l_plus: tail[0],
            l_minus: tail[1],
            proj_grad: tail[2],
            masked_frac: tail[3],
            update_norm_sq: tail[4],
            train_loss: tail[5],
            accept: tail[6],
        })
    }
}

// ---------------------------------------------------------------------------
// init
// ---------------------------------------------------------------------------

/// `init(seed u32[2]) -> params f32[P]`
pub struct InitExec {
    model: ModelInfo,
    /// parameter count of the bound model
    pub n_params: usize,
}

impl InitExec {
    /// Bind the init program of `model`.
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<InitExec> {
        model.program("init")?;
        let _ = rt;
        Ok(InitExec { model: model.clone(), n_params: model.n_params })
    }

    /// Returns host params (they immediately get packed into a TrainState).
    pub fn run(&self, rt: &Runtime, seed: (u32, u32)) -> Result<Vec<f32>> {
        rt.backend().init(&self.model, seed)
    }
}

/// `init_lora(seed u32[2]) -> adapters f32[A]`
pub struct InitLoraExec {
    model: ModelInfo,
    /// adapter count of the bound model
    pub n_adapters: usize,
}

impl InitLoraExec {
    /// Bind the LoRA-init program of `model`.
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<InitLoraExec> {
        model.program("init_lora")?;
        let _ = rt;
        Ok(InitLoraExec { model: model.clone(), n_adapters: model.n_lora_params })
    }

    /// Deterministic adapter init.
    pub fn run(&self, rt: &Runtime, seed: (u32, u32)) -> Result<Vec<f32>> {
        rt.backend().init_lora(&self.model, seed)
    }
}

// ---------------------------------------------------------------------------
// thresholds
// ---------------------------------------------------------------------------

/// `thresh(params f32[P], sparsity f32[1]) -> f32[L]`
pub struct ThreshExec {
    model: ModelInfo,
}

impl ThreshExec {
    /// Bind the threshold program of `model`.
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<ThreshExec> {
        model.program("thresh")?;
        let _ = rt;
        Ok(ThreshExec { model: model.clone() })
    }

    /// Per-layout-entry §8.2 percentile thresholds at `sparsity`.
    pub fn run(&self, rt: &Runtime, params: &[f32], sparsity: f32) -> Result<Vec<f32>> {
        if params.len() != self.model.n_params {
            bail!("thresh: params len {} != {}", params.len(), self.model.n_params);
        }
        rt.backend().thresholds(&self.model, params, sparsity)
    }
}

// ---------------------------------------------------------------------------
// step
// ---------------------------------------------------------------------------

/// `step(state, tokens i32[B,T], labels i32[B], seed u32[2], hypers f32[8],
///  thresholds f32[L]) -> state'`
pub struct StepExec {
    model: ModelInfo,
    /// which optimizer's step program this wrapper drives
    pub optimizer: String,
    /// optimizer slot count `S` the packed state must carry
    pub slots: usize,
    /// batch size `B`
    pub batch: usize,
    /// sequence length `T`
    pub seq_len: usize,
    hypers: Hypers,
    thresholds: Vec<f32>,
}

impl StepExec {
    /// Bind `optimizer`'s step program with constant hypers + thresholds.
    pub fn load(
        rt: &Runtime,
        model: &ModelInfo,
        optimizer: &str,
        hypers: Hypers,
        thresholds: &[f32],
    ) -> Result<StepExec> {
        let prog = model.step_program(optimizer)?;
        if thresholds.len() != model.n_entries {
            bail!("thresholds len {} != n_entries {}", thresholds.len(), model.n_entries);
        }
        let _ = rt;
        Ok(StepExec {
            model: model.clone(),
            optimizer: optimizer.to_string(),
            slots: prog.slots.unwrap_or(0),
            batch: model.batch,
            seq_len: model.seq_len,
            hypers,
            thresholds: thresholds.to_vec(),
        })
    }

    /// Change hyperparameters mid-run (LR schedules / sweeps reuse the
    /// bound program).
    pub fn set_hypers(&mut self, rt: &Runtime, hypers: Hypers) -> Result<()> {
        let _ = rt;
        self.hypers = hypers;
        Ok(())
    }

    /// Replace the per-entry mask thresholds.
    pub fn set_thresholds(&mut self, rt: &Runtime, thresholds: &[f32]) -> Result<()> {
        if thresholds.len() != self.model.n_entries {
            bail!("thresholds len {} != n_entries {}", thresholds.len(), self.model.n_entries);
        }
        let _ = rt;
        self.thresholds = thresholds.to_vec();
        Ok(())
    }

    /// One optimizer step: chains the packed state through the backend.
    pub fn run(
        &self,
        rt: &Runtime,
        state: &mut TrainState,
        tokens: &[i32],
        labels: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        if labels.len() != self.batch {
            bail!("labels len {} != batch {}", labels.len(), self.batch);
        }
        if state.s != self.slots {
            bail!("state slots {} != optimizer '{}' slots {}", state.s, self.optimizer, self.slots);
        }
        rt.backend().step(
            &self.model,
            &self.optimizer,
            &self.hypers,
            &self.thresholds,
            state,
            tokens,
            labels,
            seed,
        )
    }
}

// ---------------------------------------------------------------------------
// logits (evaluation)
// ---------------------------------------------------------------------------

/// `logits(params f32[P], tokens i32[B,T]) -> f32[B,V]`
/// (last-position logits; candidate scoring happens host-side)
pub struct LogitsExec {
    model: ModelInfo,
    /// batch size `B`
    pub batch: usize,
    /// sequence length `T`
    pub seq_len: usize,
    /// vocabulary size `V`
    pub vocab: usize,
}

impl LogitsExec {
    /// Bind the logits program of `model`.
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<LogitsExec> {
        model.program("logits")?;
        let _ = rt;
        Ok(LogitsExec {
            model: model.clone(),
            batch: model.batch,
            seq_len: model.seq_len,
            vocab: model.vocab,
        })
    }

    /// Last-position logits for one batch, row-major `[B, V]`.
    pub fn run(&self, rt: &Runtime, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        if params.len() != self.model.n_params {
            bail!("logits: params len {} != {}", params.len(), self.model.n_params);
        }
        if tokens.len() != self.batch * self.seq_len {
            bail!("logits: tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        rt.backend().logits(&self.model, params, tokens)
    }
}

/// `logits_lora(params, adapters, tokens) -> f32[B,V]`
pub struct LogitsLoraExec {
    model: ModelInfo,
    /// batch size `B`
    pub batch: usize,
    /// sequence length `T`
    pub seq_len: usize,
    /// vocabulary size `V`
    pub vocab: usize,
}

impl LogitsLoraExec {
    /// Bind the LoRA logits program of `model`.
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<LogitsLoraExec> {
        model.program("logits_lora")?;
        let _ = rt;
        Ok(LogitsLoraExec {
            model: model.clone(),
            batch: model.batch,
            seq_len: model.seq_len,
            vocab: model.vocab,
        })
    }

    /// Last-position logits under frozen base params + adapters.
    pub fn run(
        &self,
        rt: &Runtime,
        params: &[f32],
        adapters: &[f32],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("logits_lora: tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        rt.backend().logits_lora(&self.model, params, adapters, tokens)
    }
}

// ---------------------------------------------------------------------------
// pretrain
// ---------------------------------------------------------------------------

/// `pretrain(state, tokens i32[B,T], seed u32[2], hypers f32[8]) -> state'`
pub struct PretrainExec {
    model: ModelInfo,
    /// optimizer slot count of the pretrain program
    pub slots: usize,
    /// batch size `B`
    pub batch: usize,
    /// sequence length `T`
    pub seq_len: usize,
    hypers: Hypers,
}

impl PretrainExec {
    /// Bind the pretrain program with constant hypers.
    pub fn load(rt: &Runtime, model: &ModelInfo, hypers: Hypers) -> Result<PretrainExec> {
        let prog = model.program("pretrain")?;
        let _ = rt;
        Ok(PretrainExec {
            model: model.clone(),
            slots: prog.slots.unwrap_or(0),
            batch: model.batch,
            seq_len: model.seq_len,
            hypers,
        })
    }

    /// One LM pretraining step on a corpus batch.
    pub fn run(
        &self,
        rt: &Runtime,
        state: &mut TrainState,
        tokens: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("pretrain: tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        rt.backend().pretrain_step(&self.model, &self.hypers, state, tokens, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hypers_vector_order_matches_manifest() {
        let h = Hypers {
            lr: 1.0,
            eps: 2.0,
            sparsity: 3.0,
            mask_seed: 4.0,
            beta1: 5.0,
            beta2: 6.0,
            adam_eps: 7.0,
            wd: 8.0,
        };
        assert_eq!(h.to_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn metrics_decode() {
        let m = StepMetrics::from_tail(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 0.0]).unwrap();
        assert_eq!(m.l_plus, 1.0);
        assert_eq!(m.accept, 7.0);
        assert!(StepMetrics::from_tail(&[1.0]).is_err());
    }
}
