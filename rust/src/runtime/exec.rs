//! Typed executable wrappers enforcing the packed-state ABI.
//!
//! Each wrapper pins the argument order/shapes of one exported program
//! class so the coordinator can't mis-call an artifact. Constant inputs
//! (hypers, thresholds) are uploaded once and reused across steps.

use std::rc::Rc;

use anyhow::{bail, Result};
use xla::{PjRtBuffer, PjRtLoadedExecutable};

use super::client::Runtime;
use super::manifest::ModelInfo;
use super::state::TrainState;

/// The 8-slot hyperparameter vector (manifest.hyper_names order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hypers {
    pub lr: f32,
    pub eps: f32,
    pub sparsity: f32,
    pub mask_seed: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub wd: f32,
}

impl Default for Hypers {
    fn default() -> Self {
        Hypers {
            lr: 1e-3,
            eps: 1e-3,
            sparsity: 0.75,
            mask_seed: 42.0,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            wd: 0.0,
        }
    }
}

impl Hypers {
    pub fn to_vec(self) -> Vec<f32> {
        vec![
            self.lr,
            self.eps,
            self.sparsity,
            self.mask_seed,
            self.beta1,
            self.beta2,
            self.adam_eps,
            self.wd,
        ]
    }
}

/// Per-step metrics decoded from the packed tail
/// (manifest.metric_names order).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub l_plus: f32,
    pub l_minus: f32,
    pub proj_grad: f32,
    pub masked_frac: f32,
    pub update_norm_sq: f32,
    pub train_loss: f32,
    pub accept: f32,
}

impl StepMetrics {
    pub fn from_tail(tail: &[f32]) -> Result<StepMetrics> {
        if tail.len() < 7 {
            bail!("metric tail too short: {}", tail.len());
        }
        Ok(StepMetrics {
            l_plus: tail[0],
            l_minus: tail[1],
            proj_grad: tail[2],
            masked_frac: tail[3],
            update_norm_sq: tail[4],
            train_loss: tail[5],
            accept: tail[6],
        })
    }
}

fn single_output(mut outs: Vec<Vec<PjRtBuffer>>, what: &str) -> Result<PjRtBuffer> {
    if outs.len() != 1 || outs[0].len() != 1 {
        bail!("{what}: expected 1 output buffer, got {}x{}", outs.len(),
            outs.first().map(|v| v.len()).unwrap_or(0));
    }
    Ok(outs.remove(0).remove(0))
}

// ---------------------------------------------------------------------------
// init
// ---------------------------------------------------------------------------

/// `init(seed u32[2]) -> params f32[P]`
pub struct InitExec {
    exe: Rc<PjRtLoadedExecutable>,
    pub n_params: usize,
}

impl InitExec {
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<InitExec> {
        let prog = model.program("init")?;
        Ok(InitExec { exe: rt.load(prog)?, n_params: model.n_params })
    }

    /// Returns host params (they immediately get packed into a TrainState).
    pub fn run(&self, rt: &Runtime, seed: (u32, u32)) -> Result<Vec<f32>> {
        let seed_buf = rt.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self.exe.execute_b(&[&seed_buf]).map_err(|e| anyhow::anyhow!("init: {e:?}"))?;
        let buf = single_output(out, "init")?;
        rt.download_f32(&buf, self.n_params)
    }
}

/// `init_lora(seed u32[2]) -> adapters f32[A]`
pub struct InitLoraExec {
    exe: Rc<PjRtLoadedExecutable>,
    pub n_adapters: usize,
}

impl InitLoraExec {
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<InitLoraExec> {
        let prog = model.program("init_lora")?;
        Ok(InitLoraExec { exe: rt.load(prog)?, n_adapters: model.n_lora_params })
    }

    pub fn run(&self, rt: &Runtime, seed: (u32, u32)) -> Result<Vec<f32>> {
        let seed_buf = rt.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self.exe.execute_b(&[&seed_buf]).map_err(|e| anyhow::anyhow!("init_lora: {e:?}"))?;
        let buf = single_output(out, "init_lora")?;
        rt.download_f32(&buf, self.n_adapters)
    }
}

// ---------------------------------------------------------------------------
// thresholds
// ---------------------------------------------------------------------------

/// `thresh(params f32[P], sparsity f32[1]) -> f32[L]`
pub struct ThreshExec {
    exe: Rc<PjRtLoadedExecutable>,
    n_entries: usize,
    n_params: usize,
}

impl ThreshExec {
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<ThreshExec> {
        let prog = model.program("thresh")?;
        Ok(ThreshExec { exe: rt.load(prog)?, n_entries: model.n_entries, n_params: model.n_params })
    }

    pub fn run(&self, rt: &Runtime, params: &[f32], sparsity: f32) -> Result<Vec<f32>> {
        if params.len() != self.n_params {
            bail!("thresh: params len {} != {}", params.len(), self.n_params);
        }
        let p_buf = rt.upload_f32(params, &[params.len()])?;
        let s_buf = rt.upload_f32(&[sparsity], &[1])?;
        let out = self.exe.execute_b(&[&p_buf, &s_buf]).map_err(|e| anyhow::anyhow!("thresh: {e:?}"))?;
        let buf = single_output(out, "thresh")?;
        rt.download_f32(&buf, self.n_entries)
    }
}

// ---------------------------------------------------------------------------
// step
// ---------------------------------------------------------------------------

/// `step(state, tokens i32[B,T], labels i32[B], seed u32[2], hypers f32[8],
///  thresholds f32[L]) -> state'`
pub struct StepExec {
    exe: Rc<PjRtLoadedExecutable>,
    pub optimizer: String,
    pub slots: usize,
    pub batch: usize,
    pub seq_len: usize,
    n_entries: usize,
    hypers_buf: PjRtBuffer,
    thresholds_buf: PjRtBuffer,
}

impl StepExec {
    pub fn load(
        rt: &Runtime,
        model: &ModelInfo,
        optimizer: &str,
        hypers: Hypers,
        thresholds: &[f32],
    ) -> Result<StepExec> {
        let prog = model.step_program(optimizer)?;
        if thresholds.len() != model.n_entries {
            bail!("thresholds len {} != n_entries {}", thresholds.len(), model.n_entries);
        }
        Ok(StepExec {
            exe: rt.load(prog)?,
            optimizer: optimizer.to_string(),
            slots: prog.slots.unwrap_or(0),
            batch: model.batch,
            seq_len: model.seq_len,
            n_entries: model.n_entries,
            hypers_buf: rt.upload_f32(&hypers.to_vec(), &[8])?,
            thresholds_buf: rt.upload_f32(thresholds, &[thresholds.len()])?,
        })
    }

    /// Change hyperparameters mid-run (LR schedules / sweeps reuse the
    /// compiled executable — re-upload is 32 bytes).
    pub fn set_hypers(&mut self, rt: &Runtime, hypers: Hypers) -> Result<()> {
        self.hypers_buf = rt.upload_f32(&hypers.to_vec(), &[8])?;
        Ok(())
    }

    pub fn set_thresholds(&mut self, rt: &Runtime, thresholds: &[f32]) -> Result<()> {
        if thresholds.len() != self.n_entries {
            bail!("thresholds len {} != n_entries {}", thresholds.len(), self.n_entries);
        }
        self.thresholds_buf = rt.upload_f32(thresholds, &[thresholds.len()])?;
        Ok(())
    }

    /// One optimizer step: chains the state buffer on device.
    pub fn run(
        &self,
        rt: &Runtime,
        state: &mut TrainState,
        tokens: &[i32],
        labels: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        if labels.len() != self.batch {
            bail!("labels len {} != batch {}", labels.len(), self.batch);
        }
        if state.s != self.slots {
            bail!("state slots {} != optimizer '{}' slots {}", state.s, self.optimizer, self.slots);
        }
        let tok_buf = rt.upload_i32(tokens, &[self.batch, self.seq_len])?;
        let lab_buf = rt.upload_i32(labels, &[self.batch])?;
        let seed_buf = rt.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self
            .exe
            .execute_b(&[
                &state.buffer,
                &tok_buf,
                &lab_buf,
                &seed_buf,
                &self.hypers_buf,
                &self.thresholds_buf,
            ])
            .map_err(|e| anyhow::anyhow!("step({}): {e:?}", self.optimizer))?;
        state.replace(single_output(out, "step")?);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// logits (evaluation)
// ---------------------------------------------------------------------------

/// `logits(params f32[P], tokens i32[B,T]) -> f32[B,V]`
/// (last-position logits; candidate scoring happens host-side)
pub struct LogitsExec {
    exe: Rc<PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    n_params: usize,
}

impl LogitsExec {
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<LogitsExec> {
        let prog = model.program("logits")?;
        Ok(LogitsExec {
            exe: rt.load(prog)?,
            batch: model.batch,
            seq_len: model.seq_len,
            vocab: model.vocab,
            n_params: model.n_params,
        })
    }

    /// Upload params once for a whole evaluation pass.
    pub fn upload_params(&self, rt: &Runtime, params: &[f32]) -> Result<PjRtBuffer> {
        if params.len() != self.n_params {
            bail!("logits: params len {} != {}", params.len(), self.n_params);
        }
        rt.upload_f32(params, &[params.len()])
    }

    /// Last-position logits for one batch, row-major [B, V].
    pub fn run(&self, rt: &Runtime, params_buf: &PjRtBuffer, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("logits: tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        let tok_buf = rt.upload_i32(tokens, &[self.batch, self.seq_len])?;
        let out = self
            .exe
            .execute_b(&[params_buf, &tok_buf])
            .map_err(|e| anyhow::anyhow!("logits: {e:?}"))?;
        let buf = single_output(out, "logits")?;
        rt.download_f32(&buf, self.batch * self.vocab)
    }
}

/// `logits_lora(params, adapters, tokens) -> f32[B,V]`
pub struct LogitsLoraExec {
    exe: Rc<PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl LogitsLoraExec {
    pub fn load(rt: &Runtime, model: &ModelInfo) -> Result<LogitsLoraExec> {
        let prog = model.program("logits_lora")?;
        Ok(LogitsLoraExec {
            exe: rt.load(prog)?,
            batch: model.batch,
            seq_len: model.seq_len,
            vocab: model.vocab,
        })
    }

    pub fn run(
        &self,
        rt: &Runtime,
        params_buf: &PjRtBuffer,
        adapters_buf: &PjRtBuffer,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let tok_buf = rt.upload_i32(tokens, &[self.batch, self.seq_len])?;
        let out = self
            .exe
            .execute_b(&[params_buf, adapters_buf, &tok_buf])
            .map_err(|e| anyhow::anyhow!("logits_lora: {e:?}"))?;
        let buf = single_output(out, "logits_lora")?;
        rt.download_f32(&buf, self.batch * self.vocab)
    }
}

// ---------------------------------------------------------------------------
// pretrain
// ---------------------------------------------------------------------------

/// `pretrain(state, tokens i32[B,T], seed u32[2], hypers f32[8]) -> state'`
pub struct PretrainExec {
    exe: Rc<PjRtLoadedExecutable>,
    pub slots: usize,
    pub batch: usize,
    pub seq_len: usize,
    hypers_buf: PjRtBuffer,
}

impl PretrainExec {
    pub fn load(rt: &Runtime, model: &ModelInfo, hypers: Hypers) -> Result<PretrainExec> {
        let prog = model.program("pretrain")?;
        Ok(PretrainExec {
            exe: rt.load(prog)?,
            slots: prog.slots.unwrap_or(0),
            batch: model.batch,
            seq_len: model.seq_len,
            hypers_buf: rt.upload_f32(&hypers.to_vec(), &[8])?,
        })
    }

    pub fn run(
        &self,
        rt: &Runtime,
        state: &mut TrainState,
        tokens: &[i32],
        seed: (u32, u32),
    ) -> Result<()> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("pretrain: tokens len {} != {}x{}", tokens.len(), self.batch, self.seq_len);
        }
        let tok_buf = rt.upload_i32(tokens, &[self.batch, self.seq_len])?;
        let seed_buf = rt.upload_u32(&[seed.0, seed.1], &[2])?;
        let out = self
            .exe
            .execute_b(&[&state.buffer, &tok_buf, &seed_buf, &self.hypers_buf])
            .map_err(|e| anyhow::anyhow!("pretrain: {e:?}"))?;
        state.replace(single_output(out, "pretrain")?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hypers_vector_order_matches_manifest() {
        let h = Hypers { lr: 1.0, eps: 2.0, sparsity: 3.0, mask_seed: 4.0, beta1: 5.0, beta2: 6.0, adam_eps: 7.0, wd: 8.0 };
        assert_eq!(h.to_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn metrics_decode() {
        let m = StepMetrics::from_tail(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 0.0]).unwrap();
        assert_eq!(m.l_plus, 1.0);
        assert_eq!(m.accept, 7.0);
        assert!(StepMetrics::from_tail(&[1.0]).is_err());
    }
}
