//! sparse-mezo CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   pretrain         LM-pretrain a model on the synthetic corpus
//!   train            fine-tune one (model, task, optimizer) run
//!   eval             zero-shot / ICL evaluation of a checkpoint
//!   sweep            LR or sparsity grid (Fig-2a harness)
//!   probe            half-batch generalization probe (Fig-2b/4)
//!   repro <exp>      regenerate a paper table/figure (or `all`)
//!   serve            multi-tenant sparse-adapter inference server
//!   jobs             fine-tuning job queue (submit/list/show/cancel/
//!                    resume/drain) — the train→serve orchestrator
//!   stats            pretty-print a running server's metrics snapshot
//!   worker           remote seed-sync replica: connect to a
//!                    coordinator and serve leased training shards
//!   memory-table     Table-4 memory model only (fast)
//!   mem-report       measured heap watermarks vs the analytic model
//!   inspect          print manifest/model/layout information
//!   check-artifacts  compile every artifact and run ABI smoke checks

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use sparse_mezo::config::{presets, ServeConfig, TrainConfig};
use sparse_mezo::coordinator::checkpoint::Checkpoint;
use sparse_mezo::coordinator::experiments::{self, Ctx};
use sparse_mezo::coordinator::lora::LoraTrainer;
use sparse_mezo::coordinator::pretrain::{self, PretrainConfig};
use sparse_mezo::coordinator::probe;
use sparse_mezo::coordinator::sweep::{self, SweepAxis};
use sparse_mezo::coordinator::trainer::{in_context, zero_shot, Trainer};
use sparse_mezo::coordinator::report::Table;
use sparse_mezo::data::tasks;
use sparse_mezo::info;
use sparse_mezo::jobs::{GridSpec, JobQueue, JobSpec, Scheduler};
use sparse_mezo::parallel::{run_worker, DpTrainer, WorkerHub, WorkerOpts, WorkerPool};
use sparse_mezo::runtime::Runtime;
use sparse_mezo::serve::{http, ServeEngine};
use sparse_mezo::util::cli::Args;
use sparse_mezo::util::json::Json;
use sparse_mezo::util::log;

/// The tracking allocator (PR: measured memory observability). Inert —
/// one relaxed load per hook — until `obs::mem::enable()` flips it on
/// in `main`, and only this binary installs it: the library and its
/// unit tests run on the system allocator untouched.
#[global_allocator]
static ALLOC: sparse_mezo::obs::mem::TrackingAlloc = sparse_mezo::obs::mem::TrackingAlloc;

const USAGE: &str = "\
sparse-mezo — Sparse MeZO reproduction (rust coordinator)

USAGE: sparse-mezo <command> [options]

COMMANDS
  pretrain        --model M --steps N --lr X --seed S
  train           --model M --task T --optimizer O [--steps N --lr X
                  --eps X --sparsity X --seed S --eval-every N
                  --init-from CKPT --save CKPT --config FILE.toml
                  --workers N --journal FILE --mask-refresh N
                  --mem-budget BYTES --page-cache-bytes BYTES]
                  (--workers > 1 routes ZO runs through the seed-sync
                  data-parallel engine; bit-identical to --workers 1)
  eval            --model M --task T [--ckpt CKPT --icl-shots K]
  sweep           --model M --task T --optimizer O --axis lr|sparsity
                  [--grid a,b,c --steps N --workers N --cell-workers K]
                  (--workers bounds concurrent cells; --cell-workers > 1
                  trains each cell through the seed-sync DP engine)
  probe           --model M --task T --optimizer O [--steps N]
  repro           <table1|table2|table3|table4|table5|table10|table11|
                   table13|fig1|fig2a|fig2b|fig2c|fig3|fig4|all>
                  [--model M --out DIR --zo-steps N --seeds a,b --fast
                  --via-queue DIR]
                  (--via-queue routes sweep-driven tables through the
                  persistent job queue in DIR: a killed table resumes
                  from its cells' step journals, bit-identical)
  serve           --model M [--port P --workers N --max-batch R
                  --flush-ms MS --max-adapters K --adapter-budget BYTES
                  --seed S --init-from CKPT --config FILE.toml
                  --jobs-dir DIR --slice-steps N --listen-workers ADDR
                  --mem-budget BYTES --page-cache-bytes BYTES]
                  (loopback HTTP: GET /healthz, GET|POST /v1/adapters,
                  POST /v1/classify; adapters materialize from step
                  journals relative to the server's base parameters.
                  With --jobs-dir, /v1/jobs accepts fine-tuning jobs
                  that train in the background and auto-publish.
                  With --listen-workers, remote `worker` processes may
                  connect and serve multi-shard job slices over TCP)
  jobs            <submit|submit-grid|list|show|cancel|resume|drain|top>
                  --jobs-dir DIR
                  submit: --name A [--task T --optimizer O --steps N
                          --workers W --priority P --slice-steps K
                          --mask-refresh R --seed S --data-seed D
                          --lr X --eps X --sparsity X]
                  submit-grid: --name G [--tasks a,b --optimizers x,y
                          --lrs a,b --epss a,b --sparsities a,b
                          + the submit knobs] — fan one spec out to
                  N queued cells; cancel/resume on the grid id fan out,
                  and grid-<id>.summary.json aggregates cell results
                  show|cancel|resume: --id N (job or grid id)
                  drain:  [--model M --workers N --seed S
                          --init-from CKPT --listen-workers ADDR
                          --min-workers N --page-cache-bytes BYTES]
                  — run queued jobs to completion in-process,
                  publishing adapters;
                  --listen-workers leases shards to remote workers,
                  --min-workers waits for that many before draining
                  top:    [--port P --watch SECS] live table of jobs on
                          a running server — state, step rate, loss,
                          sparsity, peak heap bytes, active alerts —
                          joined from /v1/jobs and /v1/jobs/{id}/timeline
  stats           [--port P --watch SECS]  fetch GET /statsz from a
                  running serve process on the loopback and pretty-print
                  counters, gauges and histogram quantiles (p50/p99);
                  --watch clears and re-renders every SECS seconds
  worker          --coordinator HOST:PORT [--seed S --init-from CKPT
                  --threads N --connect-timeout SECS --max-phase-a N]
                  (remote seed-sync replica: rebuilds the coordinator's
                  replica state from journal catch-up at every lease and
                  exchanges per-row losses + (seed, g) step records —
                  bit-identical to an in-process DP worker)
  memory-table    [--model M --out DIR]
  mem-report      [--model M --steps N --quick
                  --page-cache-bytes BYTES]  run matched
                  mezo/smezo/vanilla-smezo optimizer micro-arms under
                  the tracking allocator and print each arm's measured
                  heap peak next to the analytic Table-4 prediction;
                  exits nonzero unless measured S-MeZO-EI < vanilla.
                  Also runs matched resident-vs-paged arms (train.step
                  and serve.batch phases) at the page-cache budget
                  (default: a quarter of one parameter copy) and exits
                  nonzero unless every paged peak measures below its
                  resident twin with bit-identical results
  inspect         [--model M]
  check-artifacts

  --mem-budget BYTES (train/serve): process heap budget measured by the
                  tracking allocator; a job slice whose watermark
                  exceeds it fires the mem-budget-exceeded alert
                  (degraded /healthz until it clears)
  --page-cache-bytes BYTES (train/serve/jobs drain): page the parameter
                  base out to an unlinked scratch file behind an LRU
                  page cache of at most BYTES, instead of keeping one
                  resident f32 copy; bit-identical to resident. Train
                  side requires the stateless ZO family and --workers 1

COMMON
  --artifacts DIR   artifact directory (default: artifacts)
  --verbose         debug logging

ENVIRONMENT
  SMEZO_TRACE=FILE  stream every completed span (train.step, jobs.slice,
                    serve.batch_exec, ...) to FILE as JSONL trace events
";

fn main() {
    sparse_mezo::obs::mem::enable();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["verbose", "fast", "no-test-eval", "quick"])?;
    if args.flag("verbose") {
        log::set_level(log::DEBUG);
    }
    // SMEZO_TRACE=FILE streams completed spans as JSONL trace events;
    // purely additive (spans record whether or not the sink exists)
    if let Ok(path) = std::env::var("SMEZO_TRACE") {
        if !path.is_empty() {
            sparse_mezo::obs::trace_to(std::path::Path::new(&path))
                .with_context(|| format!("opening SMEZO_TRACE file {path}"))?;
        }
    }
    let command = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing command\n{USAGE}"))?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));

    match command {
        "pretrain" => cmd_pretrain(&args, &artifacts),
        "train" => cmd_train(&args, &artifacts),
        "eval" => cmd_eval(&args, &artifacts),
        "sweep" => cmd_sweep(&args, &artifacts),
        "probe" => cmd_probe(&args, &artifacts),
        "repro" => cmd_repro(&args, &artifacts),
        "serve" => cmd_serve(&args, &artifacts),
        "stats" => cmd_stats(&args),
        "jobs" => cmd_jobs(&args, &artifacts),
        "worker" => cmd_worker(&args, &artifacts),
        "memory-table" => cmd_memory(&args, &artifacts),
        "mem-report" => cmd_mem_report(&args, &artifacts),
        "inspect" => cmd_inspect(&args, &artifacts),
        "check-artifacts" => cmd_check(&artifacts),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_pretrain(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let cfg = PretrainConfig {
        model: args.str_or("model", "llama_tiny"),
        steps: args.usize_or("steps", 1500)?,
        lr: args.f32_or("lr", 3e-3)?,
        seed: args.u64_or("seed", 7)?,
        log_every: args.usize_or("log-every", 100)?,
    };
    let result = pretrain::pretrain(&rt, &cfg)?;
    // phase 2: multi-task tuning (skippable with --no-multitask 0 steps)
    let mt_steps = args.usize_or("multitask-steps", cfg.steps / 2)?;
    let params = if mt_steps > 0 {
        pretrain::multitask_tune(&rt, &cfg.model, result.params, mt_steps, cfg.seed)?
    } else {
        result.params
    };
    let path = PathBuf::from(args.str_or("save", &format!("checkpoints/{}_pretrained.bin", cfg.model)));
    Checkpoint {
        model: cfg.model.clone(),
        n_params: params.len(),
        step: cfg.steps + mt_steps,
        params,
        slots: vec![],
        meta: Json::obj(vec![
            ("kind", Json::Str("pretrain+multitask".into())),
            ("lm_loss_ema", Json::Num(result.final_loss_ema)),
            ("multitask_steps", Json::Num(mt_steps as f64)),
        ]),
    }
    .save(&path)?;
    info!(
        "pretrain done: lm loss ema {:.4}, {:.3}s/step, {mt_steps} multitask steps -> {}",
        result.final_loss_ema,
        result.sec_per_step,
        path.display()
    );
    Ok(())
}

fn cmd_train(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let model = args.str_or("model", "llama_tiny");
    let task = args.str_or("task", "rte");
    let optimizer = args.str_or("optimizer", "smezo");
    let toml_path = args.get("config").map(PathBuf::from);
    let mut cfg = TrainConfig::resolve(&model, &task, &optimizer, toml_path.as_deref())?;
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.hypers.lr = args.f32_or("lr", cfg.hypers.lr)?;
    cfg.hypers.eps = args.f32_or("eps", cfg.hypers.eps)?;
    cfg.hypers.sparsity = args.f32_or("sparsity", cfg.hypers.sparsity)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.eval_every = args.usize_or("eval-every", 200)?;
    cfg.eval_cap = args.usize_or("eval-cap", 200)?;
    cfg.workers = args.workers_or(cfg.workers)?;
    cfg.init_from = args.get("init-from").map(|s| s.to_string()).or(cfg.init_from);
    cfg.page_cache_bytes = args.usize_or("page-cache-bytes", cfg.page_cache_bytes)?;
    if cfg.page_cache_bytes > 0 && (cfg.workers > 1 || optimizer == "mezo_lora" || optimizer == "lora_fo")
    {
        bail!("--page-cache-bytes pages the serial ZO trainer only (use --workers 1 and a ZO optimizer)");
    }
    cfg.validate()?;
    let mem_budget = args.u64_or("mem-budget", 0)?;
    sparse_mezo::obs::mem::set_budget(mem_budget);

    let model_info = rt.model(&cfg.model)?.clone();
    let dataset = tasks::generate(&cfg.task, cfg.seed)?;
    info!(
        "train: {} | {} params | task {} (majority {:.3}) | workers {}",
        cfg.label(),
        model_info.n_params,
        cfg.task,
        dataset.majority_baseline(),
        cfg.workers
    );
    let result = if optimizer == "mezo_lora" || optimizer == "lora_fo" {
        let mut t = LoraTrainer::new(&rt, cfg.clone());
        if let Some(ckpt) = &cfg.init_from {
            t.base_params = Some(Checkpoint::load(&PathBuf::from(ckpt), &model_info)?.params);
        }
        t.run_on(&model_info, &dataset)?
    } else if cfg.workers > 1 {
        // seed-sync data-parallel engine: N replicas, scalar exchange,
        // step journal for crash recovery / audit
        let pool = WorkerPool::new(cfg.workers);
        let journal = PathBuf::from(
            args.str_or("journal", &format!("results/runs/{}.journal.jsonl", cfg.label())),
        );
        let mut t = DpTrainer::new(&rt, &pool, cfg.clone()).with_journal(&journal);
        t.eval_test = !args.flag("no-test-eval");
        t.mask_refresh = args.usize_or("mask-refresh", 0)?;
        t.run_on(&model_info, &dataset)?
    } else {
        let jsonl = PathBuf::from(format!("results/runs/{}.jsonl", cfg.label()));
        let mut t = Trainer::new(&rt, cfg.clone()).with_jsonl(&jsonl)?;
        t.eval_test = !args.flag("no-test-eval");
        t.run_on(&model_info, &dataset)?
    };

    if let Some(save) = args.get("save") {
        Checkpoint {
            model: cfg.model.clone(),
            n_params: result.params.len(),
            step: result.steps_run,
            params: result.params.clone(),
            slots: vec![],
            meta: Json::obj(vec![
                ("task", Json::Str(cfg.task.clone())),
                ("optimizer", Json::Str(cfg.optimizer.clone())),
            ]),
        }
        .save(&PathBuf::from(save))?;
    }
    info!(
        "done: steps {} | diverged {} | best dev {:.3} | test {} | {:.3}s/step",
        result.steps_run,
        result.diverged,
        result.best_dev_accuracy(),
        result.test.map(|t| format!("{:.3}", t.accuracy())).unwrap_or_else(|| "—".into()),
        result.sec_per_step
    );
    if mem_budget > 0 {
        let peak = sparse_mezo::obs::mem::peak_bytes();
        if peak > mem_budget {
            sparse_mezo::obs::alerts::fire(
                0,
                "mem-budget-exceeded",
                format!("train heap peak {peak} bytes > budget {mem_budget} bytes"),
            );
            info!("mem-budget-exceeded: heap peak {peak} bytes > budget {mem_budget} bytes");
        } else {
            info!("heap peak {peak} bytes within --mem-budget {mem_budget}");
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let model = args.str_or("model", "llama_tiny");
    let task = args.str_or("task", "rte");
    let model_info = rt.model(&model)?.clone();
    let dataset = tasks::generate(&task, args.u64_or("seed", 1234)?)?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(&PathBuf::from(p), &model_info)?.params,
        None => {
            let init = sparse_mezo::runtime::exec::InitExec::load(&rt, &model_info)?;
            init.run(&rt, (42, 0x1717))?
        }
    };
    let zs = zero_shot(&rt, &model, &dataset, &params, 0)?;
    println!("zero-shot: acc {:.3} loss {:.3} (n={})", zs.accuracy(), zs.mean_loss, zs.n);
    let shots = args.usize_or("icl-shots", 4)?;
    if shots > 0 {
        let icl = in_context(&rt, &model, &dataset, &params, shots, 0)?;
        println!("icl-{shots}:     acc {:.3} loss {:.3}", icl.accuracy(), icl.mean_loss);
    }
    Ok(())
}

fn cmd_sweep(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let model = args.str_or("model", "llama_tiny");
    let task = args.str_or("task", "rte");
    let optimizer = args.str_or("optimizer", "smezo");
    let axis = match args.str_or("axis", "lr").as_str() {
        "lr" => SweepAxis::LearningRate,
        "sparsity" => SweepAxis::Sparsity,
        other => bail!("unknown axis '{other}'"),
    };
    let grid: Vec<f64> = match args.get("grid") {
        Some(g) => g
            .split(',')
            .map(|s| s.trim().parse().context("parsing --grid"))
            .collect::<Result<_>>()?,
        None => match axis {
            SweepAxis::LearningRate => presets::ZO_LR_GRID.iter().map(|&x| x as f64).collect(),
            SweepAxis::Sparsity => vec![0.0, 0.5, 0.6, 0.7, 0.8],
        },
    };
    let mut cfg = TrainConfig::resolve(&model, &task, &optimizer, None)?;
    cfg.steps = args.usize_or("steps", 600)?;
    cfg.eval_every = args.usize_or("eval-every", 150)?;
    cfg.eval_cap = args.usize_or("eval-cap", 200)?;
    cfg.seed = args.u64_or("seed", 17)?;
    // --cell-workers > 1: every cell trains through the seed-sync DP
    // engine (bit-identical to serial) instead of the serial trainer
    cfg.workers = args.usize_or("cell-workers", cfg.workers)?;
    cfg.validate()?;
    let dataset = tasks::generate(&task, 1234)?;
    // pool sized to the grid by default (the pre-pool behavior: every
    // cell concurrent); --workers caps it
    let pool = WorkerPool::new(args.workers_or(grid.len().max(1))?);
    let cells = sweep::sweep(&rt, &pool, &cfg, &dataset, axis, &grid, None)?;
    let mut table = Table::new(
        &format!("sweep {axis:?} — {model}/{task}/{optimizer}"),
        &["value", "best dev", "test", "diverged"],
    );
    for c in &cells {
        table.row(vec![
            format!("{:.4}", c.value),
            format!("{:.3}", c.best_dev_accuracy),
            c.test_accuracy.map(|a| format!("{a:.3}")).unwrap_or_else(|| "—".into()),
            if c.diverged { "yes".into() } else { "".into() },
        ]);
    }
    print!("{}", table.render());
    if let Some(best) = sweep::best_cell(&cells) {
        println!("best: {} (dev {:.3})", best.value, best.best_dev_accuracy);
    }
    Ok(())
}

fn cmd_probe(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let model = args.str_or("model", "llama_tiny");
    let task = args.str_or("task", "rte");
    let optimizer = args.str_or("optimizer", "mezo");
    let steps = args.usize_or("steps", 120)?;
    let mut cfg = TrainConfig::resolve(&model, &task, &optimizer, None)?;
    cfg.seed = args.u64_or("seed", 17)?;
    let dataset = tasks::generate(&task, 1234)?;
    let init = sparse_mezo::runtime::exec::InitExec::load(&rt, rt.model(&model)?)?;
    let params = init.run(&rt, (cfg.seed as u32, 0x1717))?;
    let res = probe::half_batch_probe(&rt, &cfg, &dataset, &params, steps, (steps / 6).max(1))?;
    println!(
        "{}: P(up|same)={:.2} P(up|held)={:.2}",
        optimizer,
        res.overall_up_same(),
        res.overall_up_held()
    );
    Ok(())
}

fn cmd_repro(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let what = args
        .positionals
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("repro needs an experiment name (or 'all')"))?;
    let rt = Runtime::new(artifacts)?;
    let mut ctx = Ctx::new(&rt, PathBuf::from(args.str_or("out", "results")));
    ctx.zo_steps = args.usize_or("zo-steps", ctx.zo_steps)?;
    ctx.fo_steps = args.usize_or("fo-steps", ctx.fo_steps)?;
    ctx.eval_every = args.usize_or("eval-every", ctx.eval_every)?;
    ctx.eval_cap = args.usize_or("eval-cap", ctx.eval_cap)?;
    ctx.pretrain_steps = args.usize_or("pretrain-steps", ctx.pretrain_steps)?;
    ctx.seeds = args
        .list_or("seeds", &["17"])
        .iter()
        .map(|s| s.parse().context("parsing --seeds"))
        .collect::<Result<_>>()?;
    if args.flag("fast") {
        ctx.zo_steps = 300;
        ctx.fo_steps = 60;
        ctx.eval_every = 100;
        ctx.eval_cap = 100;
        ctx.pretrain_steps = 300;
    }
    ctx.via_queue = args.get("via-queue").map(PathBuf::from);
    ctx.artifacts = artifacts.clone();
    let model = args.str_or("model", "llama_tiny");
    let t0 = std::time::Instant::now();
    experiments::run(&ctx, what, &model)?;
    info!("repro {what} finished in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// The serve/jobs base parameters: a checkpoint when configured, else
/// the deterministic init for the config's seed.
fn resolve_serve_base(rt: &Runtime, cfg: &ServeConfig) -> Result<Vec<f32>> {
    let model_info = rt.model(&cfg.model)?.clone();
    match &cfg.init_from {
        Some(path) => Ok(Checkpoint::load(&PathBuf::from(path), &model_info)
            .with_context(|| format!("loading base checkpoint {path}"))?
            .params),
        None => {
            let init = sparse_mezo::runtime::exec::InitExec::load(rt, &model_info)?;
            init.run(rt, (cfg.seed as u32, 0x1717))
        }
    }
}

/// Build the serve engine for `cfg`: resident base by default, or —
/// with `--page-cache-bytes` — a file-backed paged base whose resident
/// footprint is the bounded LRU page cache rather than a full f32 copy.
fn build_engine(rt: Runtime, cfg: &ServeConfig, base: Vec<f32>) -> Result<ServeEngine> {
    if cfg.page_cache_bytes == 0 {
        return ServeEngine::new(rt, cfg, base);
    }
    let store = sparse_mezo::runtime::store::ParamStore::file_backed(&base, cfg.page_cache_bytes)?;
    drop(base);
    info!(
        "paged base: {} pages on scratch file, cache budget {} bytes",
        store.len().div_ceil(sparse_mezo::runtime::store::PAGE_FLOATS),
        cfg.page_cache_bytes
    );
    ServeEngine::with_store(rt, cfg, Arc::new(store))
}

fn cmd_serve(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let toml_path = args.get("config").map(PathBuf::from);
    let mut cfg = ServeConfig::resolve(toml_path.as_deref())?;
    cfg.model = args.str_or("model", &cfg.model);
    cfg.port = args.u16_or("port", cfg.port)?;
    cfg.workers = args.workers_or(cfg.workers)?;
    cfg.max_batch_rows = args.usize_or("max-batch", cfg.max_batch_rows)?;
    cfg.flush_ms = args.u64_or("flush-ms", cfg.flush_ms)?;
    cfg.max_adapters = args.usize_or("max-adapters", cfg.max_adapters)?;
    cfg.adapter_budget = args.usize_or("adapter-budget", cfg.adapter_budget)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.init_from = args.get("init-from").map(String::from).or(cfg.init_from);
    cfg.jobs_dir = args.get("jobs-dir").map(String::from).or(cfg.jobs_dir);
    cfg.slice_steps = args.usize_or("slice-steps", cfg.slice_steps)?;
    cfg.listen_workers = args.get("listen-workers").map(String::from).or(cfg.listen_workers);
    cfg.page_cache_bytes = args.usize_or("page-cache-bytes", cfg.page_cache_bytes)?;
    cfg.validate()?;
    let mem_budget = args.u64_or("mem-budget", 0)?;
    sparse_mezo::obs::mem::set_budget(mem_budget);
    if mem_budget > 0 {
        info!("mem budget: {mem_budget} bytes per job slice (alert rule mem-budget-exceeded)");
    }

    let model_info = rt.model(&cfg.model)?.clone();
    let base = resolve_serve_base(&rt, &cfg)?;
    info!(
        "serve: {} | {} params | {} pool threads | batch {} rows / {} ms | {} adapters / {} MB",
        cfg.model,
        model_info.n_params,
        cfg.workers,
        cfg.max_batch_rows,
        cfg.flush_ms,
        cfg.max_adapters,
        cfg.adapter_budget >> 20
    );
    let mut engine = build_engine(rt, &cfg, base)?;
    if let Some(dir) = &cfg.jobs_dir {
        let queue = Arc::new(JobQueue::open(&PathBuf::from(dir))?);
        info!("jobs: {} persisted under {dir} ({} active)", queue.list().len(), queue.active());
        engine = engine.with_jobs(queue, cfg.slice_steps);
    }
    if let Some(addr) = &cfg.listen_workers {
        let hub = WorkerHub::listen(addr)?;
        info!("worker hub listening on {} (TCP seed-sync leases)", hub.addr());
        engine = engine.with_worker_hub(hub);
    }
    let running = http::serve(Arc::new(engine), cfg.port)?;
    info!("listening on http://{} (loopback only)", running.addr);
    running.join();
    Ok(())
}

/// The loopback address for `--port P` (default: the serve config's).
fn loopback_addr(args: &Args) -> Result<std::net::SocketAddr> {
    let default_port = ServeConfig::resolve(None)?.port;
    let port = args.u16_or("port", default_port)?;
    format!("127.0.0.1:{port}").parse().context("building loopback address")
}

/// Run `render` once, or — when `watch_secs > 0` — forever on a
/// `watch(1)`-style refresh loop, clearing the terminal before each
/// frame. Shared by `stats --watch` and `jobs top`. A frame that fails
/// (server restarting between refreshes) prints the error and keeps
/// watching rather than exiting.
fn watch_loop(watch_secs: u64, mut render: impl FnMut() -> Result<()>) -> Result<()> {
    if watch_secs == 0 {
        return render();
    }
    loop {
        print!("\x1b[2J\x1b[H");
        if let Err(e) = render() {
            println!("error: {e:#}");
        }
        std::thread::sleep(std::time::Duration::from_secs(watch_secs));
    }
}

/// `stats`: fetch `/statsz` from a running loopback server and render
/// the registry snapshot — counters and gauges as name/value pairs,
/// histograms as count/mean/p50/p99 rows. `--watch SECS` re-renders on
/// a refresh loop.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = loopback_addr(args)?;
    let port = addr.port();
    watch_loop(args.u64_or("watch", 0)?, move || {
        let mut client = http::LoopbackClient::connect(addr)
            .with_context(|| format!("is a server running on port {port}? (serve --port)"))?;
        render_stats(&mut client)
    })
}

/// One `stats` frame over an established connection.
fn render_stats(client: &mut http::LoopbackClient) -> Result<()> {
    let (status, body) = client.request("GET", "/statsz", None)?;
    if status != 200 {
        bail!("GET /statsz answered {status}: {body}");
    }
    println!("COUNTERS");
    for (name, v) in body.req("counters")?.as_obj()? {
        println!("  {name:<52} {}", v.as_f64()? as u64);
    }
    println!("GAUGES");
    for (name, v) in body.req("gauges")?.as_obj()? {
        println!("  {name:<52} {}", v.as_f64()? as i64);
    }
    println!("HISTOGRAMS");
    println!("  {:<52} {:>8}  {:>12}  {:>12}  {:>12}", "series", "count", "mean", "p50", "p99");
    for (name, h) in body.req("histograms")?.as_obj()? {
        println!(
            "  {name:<52} {:>8}  {:>12.6}  {:>12.6}  {:>12.6}",
            h.req("count")?.as_f64()? as u64,
            h.req("mean")?.as_f64()?,
            h.req("p50")?.as_f64()?,
            h.req("p99")?.as_f64()?,
        );
    }
    Ok(())
}

/// One `jobs top` frame: every job from `GET /v1/jobs`, joined with
/// its flight-recorder timeline for the live rate/loss/sparsity/alert
/// columns.
fn render_jobs_top(client: &mut http::LoopbackClient) -> Result<()> {
    let (status, body) = client.request("GET", "/v1/jobs", None)?;
    if status != 200 {
        bail!("GET /v1/jobs answered {status}: {body}");
    }
    println!(
        "{:>4}  {:<10}  {:<20}  {:>12}  {:>8}  {:>9}  {:>8}  {:>10}  alerts",
        "id", "state", "name", "steps", "steps/s", "loss", "sparsity", "peak MiB"
    );
    for job in body.req("jobs")?.as_arr()? {
        let id = job.req("id")?.as_usize()?;
        let spec = job.req("spec")?;
        let alerts: Vec<String> = match job.get("alerts") {
            Some(Json::Arr(xs)) => {
                xs.iter().filter_map(|x| x.as_str().ok().map(str::to_string)).collect()
            }
            _ => Vec::new(),
        };
        // per-job timeline: live loss / sparsity / step-rate columns
        let (ts, tl) = client.request("GET", &format!("/v1/jobs/{id}/timeline"), None)?;
        let (mut rate, mut loss, mut sparsity) = (String::new(), String::new(), String::new());
        let mut peak = String::new();
        if ts == 200 {
            if let Ok(t) = tl.req("timings") {
                let median = t.req("median_step_seconds")?.as_f64()?;
                if median > 0.0 {
                    rate = format!("{:.1}", 1.0 / median);
                }
            }
            // per-job heap watermark (0 until a slice ran under the
            // tracking allocator — leave the column blank then)
            if let Some(m) = tl.get("mem") {
                let bytes = m.req("peak_bytes")?.as_f64()?;
                if bytes > 0.0 {
                    peak = format!("{:.1}", bytes / (1024.0 * 1024.0));
                }
            }
            if let Some(Json::Obj(latest)) = tl.get("latest") {
                if let Some(l) = latest.get("loss") {
                    loss = format!("{:.4}", l.as_f64()?);
                }
                let nz = latest.get("nonzero").map(|x| x.as_f64()).transpose()?;
                let total = latest.get("total").map(|x| x.as_f64()).transpose()?;
                if let (Some(nz), Some(total)) = (nz, total) {
                    if total > 0.0 {
                        sparsity = format!("{:.3}", 1.0 - nz / total);
                    }
                }
            }
        }
        println!(
            "{:>4}  {:<10}  {:<20}  {:>5}/{:<6}  {:>8}  {:>9}  {:>8}  {:>10}  {}",
            id,
            job.req("state")?.as_str()?,
            spec.req("name")?.as_str()?,
            job.req("steps_done")?.as_usize()?,
            spec.req("steps")?.as_usize()?,
            rate,
            loss,
            sparsity,
            peak,
            alerts.join(","),
        );
    }
    Ok(())
}

/// `jobs top`: the live-refresh job table, rendered over HTTP against a
/// running server (no local queue directory needed).
fn cmd_jobs_top(args: &Args) -> Result<()> {
    let addr = loopback_addr(args)?;
    let port = addr.port();
    watch_loop(args.u64_or("watch", 0)?, move || {
        let mut client = http::LoopbackClient::connect(addr).with_context(|| {
            format!("is a server running on port {port}? (serve --port --jobs-dir)")
        })?;
        render_jobs_top(&mut client)
    })
}

fn cmd_jobs(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let action = args
        .positionals
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "jobs needs an action: submit|submit-grid|list|show|cancel|resume|drain|top"
            )
        })?;
    // `top` talks to a running server over HTTP; it neither needs nor
    // should create a local queue directory
    if action == "top" {
        return cmd_jobs_top(args);
    }
    let dir = PathBuf::from(args.str_or("jobs-dir", "jobs"));
    let queue = Arc::new(JobQueue::open(&dir)?);
    match action {
        "submit" => {
            let spec = JobSpec {
                name: args
                    .get("name")
                    .map(String::from)
                    .ok_or_else(|| anyhow::anyhow!("jobs submit needs --name"))?,
                task: args.str_or("task", "rte"),
                optimizer: args.str_or("optimizer", "smezo"),
                steps: args.usize_or("steps", 100)?,
                workers: args.workers_or(1)?,
                priority: args.i64_or("priority", 0)?,
                slice_steps: args.usize_or("slice-steps", 0)?,
                mask_refresh: args.usize_or("mask-refresh", 0)?,
                seed: args.u64_or("seed", 42)?,
                data_seed: args.get("data-seed").map(|_| args.u64_or("data-seed", 0)).transpose()?,
                lr: args.get("lr").map(|_| args.f32_or("lr", 0.0)).transpose()?,
                eps: args.get("eps").map(|_| args.f32_or("eps", 0.0)).transpose()?,
                sparsity: args.get("sparsity").map(|_| args.f32_or("sparsity", 0.0)).transpose()?,
            };
            let id = queue.submit(spec)?;
            println!("{}", queue.get(id)?.to_json().to_string());
        }
        "submit-grid" => {
            let axis = |key: &str| -> Result<Vec<f64>> {
                args.list_or(key, &[])
                    .iter()
                    .map(|s| s.parse().with_context(|| format!("parsing --{key}")))
                    .collect()
            };
            let spec = GridSpec {
                name: args
                    .get("name")
                    .map(String::from)
                    .ok_or_else(|| anyhow::anyhow!("jobs submit-grid needs --name"))?,
                tasks: args.list_or("tasks", &["rte"]),
                optimizers: args.list_or("optimizers", &["smezo"]),
                lrs: axis("lrs")?,
                epss: axis("epss")?,
                sparsities: axis("sparsities")?,
                steps: args.usize_or("steps", 100)?,
                workers: args.workers_or(1)?,
                priority: args.i64_or("priority", 0)?,
                slice_steps: args.usize_or("slice-steps", 0)?,
                mask_refresh: args.usize_or("mask-refresh", 0)?,
                seed: args.u64_or("seed", 42)?,
                data_seed: args.get("data-seed").map(|_| args.u64_or("data-seed", 0)).transpose()?,
            };
            let grid = queue.submit_grid(spec)?;
            println!("{}", queue.grid_status(grid.id)?.to_string());
        }
        "list" => {
            println!("{:>4}  {:<10}  {:<24}  {:>12}  {:>8}", "id", "state", "name", "steps", "prio");
            for job in queue.list() {
                println!(
                    "{:>4}  {:<10}  {:<24}  {:>5}/{:<6}  {:>8}{}",
                    job.id,
                    job.state.as_str(),
                    job.spec.name,
                    job.steps_done,
                    job.spec.steps,
                    job.spec.priority,
                    job.error.as_ref().map(|e| format!("  ({e})")).unwrap_or_default()
                );
            }
            for grid in queue.grids() {
                let st = queue.grid_status(grid.id)?;
                println!(
                    "{:>4}  {:<10}  {:<24}  {:>5} cells  {:>8}  (grid)",
                    grid.id,
                    st.req("state")?.as_str()?,
                    grid.spec.name,
                    grid.children.len(),
                    grid.spec.priority,
                );
            }
        }
        "show" => {
            let id = args.u64_or("id", 0)?;
            if queue.has_grid(id) {
                println!("{}", queue.grid_status(id)?.to_string());
            } else {
                println!("{}", queue.get(id)?.to_json().to_string());
            }
        }
        "cancel" => {
            let id = args.u64_or("id", 0)?;
            if queue.has_grid(id) {
                let n = queue.cancel_grid(id)?;
                info!("grid {id}: cancel fanned out to {n} cell(s)");
            } else {
                let job = queue.cancel(id)?;
                info!(
                    "job {id} -> {} (cancel_requested {})",
                    job.state.as_str(),
                    job.cancel_requested
                );
            }
        }
        "resume" => {
            let id = args.u64_or("id", 0)?;
            if queue.has_grid(id) {
                let n = queue.resume_grid(id)?;
                info!("grid {id}: resumed {n} cell(s)");
            } else {
                let job = queue.resume(id)?;
                info!("job {id} -> {}", job.state.as_str());
            }
        }
        "drain" => {
            // run every queued job to completion in-process: the same
            // engine + scheduler the server hosts, minus the HTTP layer
            let rt = Runtime::new(artifacts)?;
            let mut cfg = ServeConfig::resolve(None)?;
            cfg.model = args.str_or("model", &cfg.model);
            cfg.workers = args.workers_or(cfg.workers)?;
            cfg.seed = args.u64_or("seed", cfg.seed)?;
            cfg.init_from = args.get("init-from").map(String::from).or(cfg.init_from);
            cfg.slice_steps = args.usize_or("slice-steps", cfg.slice_steps)?;
            cfg.listen_workers = args.get("listen-workers").map(String::from).or(cfg.listen_workers);
            cfg.min_workers = args.usize_or("min-workers", cfg.min_workers)?;
            cfg.page_cache_bytes = args.usize_or("page-cache-bytes", cfg.page_cache_bytes)?;
            cfg.validate()?;
            let base = resolve_serve_base(&rt, &cfg)?;
            let mut engine =
                build_engine(rt, &cfg, base)?.with_jobs(Arc::clone(&queue), cfg.slice_steps);
            if let Some(addr) = &cfg.listen_workers {
                let hub = WorkerHub::listen(addr)?;
                info!("worker hub listening on {} (TCP seed-sync leases)", hub.addr());
                if cfg.min_workers > 0 {
                    let deadline = std::time::Duration::from_secs(60);
                    if !hub.wait_for_workers(cfg.min_workers, deadline) {
                        bail!(
                            "only {}/{} remote workers connected within {deadline:?}",
                            hub.connected(),
                            cfg.min_workers
                        );
                    }
                    info!("{} remote worker(s) connected", hub.connected());
                }
                engine = engine.with_worker_hub(hub);
            }
            let scheduler = Scheduler::new(Arc::new(engine), Arc::clone(&queue), cfg.slice_steps);
            let slices = scheduler.run_until_idle();
            info!("drained {} scheduler slices", slices);
            for job in queue.list() {
                println!(
                    "{:>4}  {:<10}  {:<24}  {:>5}/{:<6}{}",
                    job.id,
                    job.state.as_str(),
                    job.spec.name,
                    job.steps_done,
                    job.spec.steps,
                    if job.published {
                        format!("  adapter -> {}", queue.adapter_path(&job.spec.name).display())
                    } else {
                        job.error.as_ref().map(|e| format!("  ({e})")).unwrap_or_default()
                    }
                );
            }
        }
        other => anyhow::bail!(
            "unknown jobs action '{other}' (submit|submit-grid|list|show|cancel|resume|drain|top)"
        ),
    }
    Ok(())
}

fn cmd_worker(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let addr = args
        .get("coordinator")
        .map(String::from)
        .ok_or_else(|| anyhow::anyhow!("worker needs --coordinator HOST:PORT"))?;
    let rt = Runtime::new(artifacts)?;
    let pool = WorkerPool::new(args.usize_or("threads", 1)?);
    // --max-phase-a N: die after N PhaseA frames without replying — the
    // deterministic mid-slice kill the CI stall-alert smoke relies on
    let max_phase_a = args.usize_or("max-phase-a", 0)?;
    let opts = WorkerOpts {
        seed: args.u64_or("seed", 42)?,
        init_from: args.get("init-from").map(String::from),
        connect_timeout: std::time::Duration::from_secs(args.u64_or("connect-timeout", 30)?),
        max_phase_a: if max_phase_a > 0 { Some(max_phase_a) } else { None },
    };
    info!("worker: connecting to coordinator at {addr}");
    let stats = run_worker(&rt, &pool, &addr, &opts)?;
    info!(
        "worker done: {} session(s) served, {} step(s) applied",
        stats.sessions, stats.steps
    );
    Ok(())
}

fn cmd_memory(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let ctx = Ctx::new(&rt, PathBuf::from(args.str_or("out", "results")));
    experiments::table4(&ctx, &args.str_or("model", "llama_tiny"))?;
    // also print the 7B table to stdout for quick reading
    let rows = sparse_mezo::coordinator::memory::table4_rows_7b();
    for (name, b) in rows {
        println!("{name:<22} {:>8.1} GB", b.gb());
    }
    Ok(())
}

/// `mem-report`: the measured side of the paper's memory table. Runs
/// the three matched optimizer micro-arms (MeZO, S-MeZO-EI, vanilla
/// S-MeZO) at the model's parameter count under this binary's tracking
/// allocator and prints each arm's heap watermark next to the analytic
/// `MemBreakdown` prediction; fails unless the efficient implementation
/// measures below vanilla (the §3.4 inference-level-memory claim).
fn cmd_mem_report(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let model = rt.model(&args.str_or("model", "llama_tiny"))?.clone();
    let steps = if args.flag("quick") { 2 } else { args.usize_or("steps", 6)? };
    info!(
        "mem-report: {} | {} params | {} probe steps per arm",
        model.name, model.n_params, steps
    );
    let rows = sparse_mezo::coordinator::memory::measured_rows(&model, steps);
    println!(
        "{:<18} {:>16} {:>18} {:>20}",
        "method", "measured peak B", "analytic total B", "analytic mask+copy B"
    );
    for r in &rows {
        println!(
            "{:<18} {:>16} {:>18} {:>20}",
            r.name,
            r.measured_peak,
            r.analytic.total(),
            r.analytic.mask + r.analytic.perturbed_copy
        );
    }
    let peak = |name: &str| -> Result<u64> {
        rows.iter()
            .find(|r| r.name == name)
            .map(|r| r.measured_peak)
            .ok_or_else(|| anyhow::anyhow!("missing row {name}"))
    };
    let ei = peak("S-MeZO-EI")?;
    let vanilla = peak("S-MeZO (vanilla)")?;
    if ei == 0 || vanilla == 0 {
        bail!("tracking allocator reported a zero watermark — is it installed and enabled?");
    }
    if ei >= vanilla {
        bail!(
            "check FAILED: measured S-MeZO-EI peak {ei} B >= vanilla {vanilla} B \
             (the stored-mask + perturbed-copy overhead should separate them)"
        );
    }
    println!(
        "check: measured S-MeZO-EI peak {ei} B < vanilla S-MeZO peak {vanilla} B \
         (saves {} B; analytic prediction {} B) OK",
        vanilla - ei,
        model.n_params / 8 + model.n_params * 4
    );

    // paged parameter tiering: matched resident-vs-paged twins under the
    // live train.step / serve.batch phases. Default cache budget is a
    // quarter of one full parameter copy so the paged twin must fault.
    let param_bytes = model.n_params * 4;
    let cache = args.usize_or("page-cache-bytes", (param_bytes / 4).max(1))?;
    let pairs = sparse_mezo::coordinator::memory::paged_pairs(&model, steps, cache)?;
    println!(
        "\npaged tiering (cache budget {cache} B, one param copy {param_bytes} B)\n\
         {:<12} {:>16} {:>14} {:>8} {:>10}",
        "phase", "resident peak B", "paged peak B", "faults", "evictions"
    );
    for p in &pairs {
        println!(
            "{:<12} {:>16} {:>14} {:>8} {:>10}",
            p.phase, p.resident_peak, p.paged_peak, p.faults, p.evictions
        );
    }
    for p in &pairs {
        if p.resident_loss.to_bits() != p.paged_loss.to_bits() {
            bail!(
                "check FAILED: {} paged probe loss {} != resident {} (tiering must be bit-identical)",
                p.phase,
                p.paged_loss,
                p.resident_loss
            );
        }
        if p.resident_peak == 0 || p.paged_peak == 0 {
            bail!("tracking allocator reported a zero watermark for {}", p.phase);
        }
        if p.faults == 0 {
            bail!(
                "check FAILED: {} paged twin took no page faults — the cache budget \
                 {cache} B held the whole store, proving nothing",
                p.phase
            );
        }
        if p.paged_peak >= p.resident_peak {
            bail!(
                "check FAILED: {} paged peak {} B >= resident peak {} B at cache budget {cache} B",
                p.phase,
                p.paged_peak,
                p.resident_peak
            );
        }
    }
    println!(
        "check: paged peaks below resident twins with bit-identical losses \
         (train.step {} < {} B, serve.batch {} < {} B) OK",
        pairs[0].paged_peak, pairs[0].resident_peak, pairs[1].paged_peak, pairs[1].resident_peak
    );
    Ok(())
}

fn cmd_inspect(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    match args.get("model") {
        None => {
            println!("models in manifest ({} backend):", rt.backend().platform());
            for (name, m) in &rt.manifest().models {
                println!(
                    "  {name:<16} {:>10} params  B={} T={} V={}  programs: {}",
                    m.n_params,
                    m.batch,
                    m.seq_len,
                    m.vocab,
                    m.programs.len()
                );
            }
        }
        Some(name) => {
            let m = rt.model(name)?;
            println!("{name}: {} params, {} layout entries", m.n_params, m.n_entries);
            for e in &m.layout {
                println!(
                    "  [{:>3}] {:<24} {:>12} {:?} @ {}",
                    e.layer_id,
                    e.name,
                    format!("{:?}", e.shape),
                    e.kind,
                    e.offset
                );
            }
            println!("programs:");
            for (p, info) in &m.programs {
                println!("  {p:<22} {}", info.file);
            }
        }
    }
    Ok(())
}

fn cmd_check(artifacts: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let names: Vec<String> = rt.manifest().models.keys().cloned().collect();
    for name in names {
        let model = rt.model(&name)?.clone();
        for pname in model.programs.keys() {
            rt.backend()
                .compile_check(&model, pname)
                .with_context(|| format!("{name}/{pname}"))?;
        }
        println!("{name}: {} programs check OK", model.programs.len());
    }
    println!(
        "all programs check out on the {} backend ({} executables, {:.1}s total compile time)",
        rt.backend().platform(),
        rt.cached_executables(),
        rt.total_compile_seconds()
    );
    Ok(())
}
