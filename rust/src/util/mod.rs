//! Hand-rolled utility substrates.
//!
//! The build environment vendors only the `xla` crate and `anyhow`, so the
//! conveniences a crates.io project would pull in (serde_json, toml, clap,
//! rand, env_logger, criterion) are implemented here, scoped to exactly
//! what the coordinator needs. Each is unit-tested in its own module.

pub mod bitset;
pub mod cli;
pub mod json;
pub mod log;
pub mod prng;
pub mod stats;
pub mod toml;

/// Best-effort human-readable message from a `catch_unwind` payload —
/// the shared dance of every isolation boundary in the crate (the
/// micro-batch dispatcher, the job scheduler): `&str` and `String`
/// payloads pass through, anything else becomes a placeholder.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".into())
}
