//! Hand-rolled utility substrates.
//!
//! The build environment vendors only the `xla` crate and `anyhow`, so the
//! conveniences a crates.io project would pull in (serde_json, toml, clap,
//! rand, env_logger, criterion) are implemented here, scoped to exactly
//! what the coordinator needs. Each is unit-tested in its own module.

pub mod bitset;
pub mod cli;
pub mod json;
pub mod log;
pub mod prng;
pub mod stats;
pub mod toml;
