//! Tiny CLI argument parser (clap is not in the vendored dependency set).
//!
//! Model: `binary <subcommand> [positionals] [--flag] [--key value]`.
//! Typed getters with defaults; unknown-flag detection; auto-generated
//! usage text assembled by the caller (main.rs).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
/// Parsed command-line arguments.
pub struct Args {
    /// positional arguments in order
    pub positionals: Vec<String>,
    /// `--key value` options
    pub options: BTreeMap<String, String>,
    /// boolean flags present
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `bool_flags` lists flags that
    /// take no value; everything else starting with `--` consumes one.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} requires a value"))?;
                    out.options.insert(name.to_string(), v.clone());
                }
            } else {
                out.positionals.push(a.clone());
            }
        }
        Ok(out)
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw option value, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Integer option with default (underscores allowed).
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("--{name}: expected integer, got '{v}' ({e})")),
        }
    }

    /// Float option with default.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: expected float, got '{v}' ({e})")),
        }
    }

    /// f32 option with default.
    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }

    /// i64 option with default (the jobs `--priority` knob; negatives
    /// deprioritize, underscores allowed).
    pub fn i64_or(&self, name: &str, default: i64) -> Result<i64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("--{name}: expected integer, got '{v}' ({e})")),
        }
    }

    /// u64 option with default (underscores allowed).
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("--{name}: expected u64, got '{v}' ({e})")),
        }
    }

    /// The `--workers N` knob (data-parallel worker count), validated:
    /// 0 is rejected at parse time so every downstream consumer (pool
    /// sizing, DP shard math) can rely on `workers >= 1`.
    pub fn workers_or(&self, default: usize) -> Result<usize> {
        let n = self.usize_or("workers", default)?;
        if n == 0 {
            bail!("--workers: must be >= 1 (1 = serial)");
        }
        Ok(n)
    }

    /// u16 option with default (the serve `--port` knob; 0 = ephemeral).
    pub fn u16_or(&self, name: &str, default: u16) -> Result<u16> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: expected u16, got '{v}' ({e})")),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        }
    }

    /// Error on options the command does not understand (typo guard).
    pub fn reject_unknown(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &sv(&["train", "--task", "rte", "--steps=100", "--verbose", "extra"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positionals, sv(&["train", "extra"]));
        assert_eq!(a.get("task"), Some("rte"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_and_types() {
        let a = Args::parse(&sv(&["--lr", "2e-6"]), &[]).unwrap();
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 2e-6);
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("task", "boolq"), "boolq");
        assert!(a.usize_or("lr", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--tasks", "rte, boolq,wic"]), &[]).unwrap();
        assert_eq!(a.list_or("tasks", &[]), sv(&["rte", "boolq", "wic"]));
        assert_eq!(a.list_or("absent", &["x"]), sv(&["x"]));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--task"]), &[]).is_err());
    }

    #[test]
    fn workers_flag_parses_and_rejects_zero() {
        let a = Args::parse(&sv(&["--workers", "4"]), &[]).unwrap();
        assert_eq!(a.workers_or(1).unwrap(), 4);
        assert_eq!(Args::parse(&sv(&[]), &[]).unwrap().workers_or(2).unwrap(), 2);
        assert!(Args::parse(&sv(&["--workers", "0"]), &[]).unwrap().workers_or(1).is_err());
    }

    #[test]
    fn i64_accepts_negatives() {
        let a = Args::parse(&sv(&["--priority", "-5"]), &[]).unwrap();
        assert_eq!(a.i64_or("priority", 0).unwrap(), -5);
        assert_eq!(a.i64_or("absent", 3).unwrap(), 3);
        assert!(Args::parse(&sv(&["--priority", "x"]), &[]).unwrap().i64_or("priority", 0).is_err());
    }

    #[test]
    fn u16_parses_and_rejects_out_of_range() {
        let a = Args::parse(&sv(&["--port", "8080"]), &[]).unwrap();
        assert_eq!(a.u16_or("port", 0).unwrap(), 8080);
        assert_eq!(a.u16_or("absent", 7).unwrap(), 7);
        assert!(Args::parse(&sv(&["--port", "70000"]), &[]).unwrap().u16_or("port", 0).is_err());
        assert!(Args::parse(&sv(&["--port", "-1"]), &[]).unwrap().u16_or("port", 0).is_err());
    }

    #[test]
    fn unknown_detection() {
        let a = Args::parse(&sv(&["--good", "1", "--bad", "2"]), &[]).unwrap();
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "bad"]).is_ok());
    }
}
