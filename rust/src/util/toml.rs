//! TOML-subset parser for experiment/preset config files.
//!
//! Supports the subset the configs actually use: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! bool / homogeneous-array values, `#` comments, and bare or quoted keys.
//! Values land in the same [`Json`] tree the JSON module uses, so config
//! plumbing is uniform. Unsupported TOML (dates, inline tables, multi-line
//! strings) errors loudly instead of mis-parsing.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

use super::json::Json;

/// Parse TOML text into a Json::Obj tree (sections become nested objects).
pub fn parse(input: &str) -> Result<Json> {
    let mut root = BTreeMap::new();
    let mut path: Vec<String> = Vec::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let end = rest.find(']').ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
            if rest[end + 1..].trim() != "" {
                bail!("line {}: garbage after section header", lineno + 1);
            }
            path = rest[..end].split('.').map(|s| s.trim().to_string()).collect();
            if path.iter().any(|s| s.is_empty()) {
                bail!("line {}: empty section name component", lineno + 1);
            }
            ensure_section(&mut root, &path, lineno + 1)?;
            continue;
        }
        let eq = line.find('=').ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = parse_key(line[..eq].trim(), lineno + 1)?;
        let val = parse_value(line[eq + 1..].trim(), lineno + 1)?;
        let section = section_mut(&mut root, &path, lineno + 1)?;
        if section.insert(key.clone(), val).is_some() {
            bail!("line {}: duplicate key '{key}'", lineno + 1);
        }
    }
    Ok(Json::Obj(root))
}

/// Parse a TOML-subset file into a [`Json`] tree.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_key(s: &str, lineno: usize) -> Result<String> {
    if s.is_empty() {
        bail!("line {lineno}: empty key");
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("line {lineno}: unterminated quoted key");
        }
        return Ok(s[1..s.len() - 1].to_string());
    }
    if !s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
        bail!("line {lineno}: invalid bare key '{s}'");
    }
    Ok(s.to_string())
}

fn parse_value(s: &str, lineno: usize) -> Result<Json> {
    if s.is_empty() {
        bail!("line {lineno}: missing value");
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("line {lineno}: unterminated string");
        }
        // escapes: only the basics
        let inner = &s[1..s.len() - 1];
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => bail!("line {lineno}: bad escape \\{other:?}"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Json::Str(out));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("line {lineno}: arrays must be single-line");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p, lineno)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    // number
    let cleaned = s.replace('_', "");
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| anyhow!("line {lineno}: cannot parse value '{s}'"))
}

/// Split an array body on top-level commas (no nested arrays in configs,
/// but strings may contain commas).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut depth = 0;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

fn ensure_section(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    lineno: usize,
) -> Result<()> {
    section_mut(root, path, lineno).map(|_| ())
}

fn section_mut<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Json>> {
    let mut cur = root;
    for comp in path {
        let entry = cur.entry(comp.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => bail!("line {lineno}: section '{comp}' collides with a value"),
        };
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_sections_and_types() {
        let src = r#"
# experiment preset
name = "rte"           # task
steps = 4_000
lr = 2e-6
use_sparse = true
sparsities = [0.5, 0.6, 0.7, 0.8]
tags = ["a", "b,c"]

[model]
family = "llama"
size = "small"

[model.extra]
window = 16
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req("name").unwrap().as_str().unwrap(), "rte");
        assert_eq!(v.req("steps").unwrap().as_usize().unwrap(), 4000);
        assert_eq!(v.req("lr").unwrap().as_f64().unwrap(), 2e-6);
        assert_eq!(v.req("use_sparse").unwrap(), &Json::Bool(true));
        assert_eq!(v.req("sparsities").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(
            v.req("tags").unwrap().as_arr().unwrap()[1].as_str().unwrap(),
            "b,c"
        );
        let model = v.req("model").unwrap();
        assert_eq!(model.req("family").unwrap().as_str().unwrap(), "llama");
        assert_eq!(
            model.req("extra").unwrap().req("window").unwrap().as_usize().unwrap(),
            16
        );
    }

    #[test]
    fn rejects_bad_syntax() {
        for s in [
            "key",
            "= 1",
            "[unterminated",
            "k = [1, 2",
            "k = \"open",
            "k = 2020-01-01",
            "a = 1\na = 2",
        ] {
            assert!(parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn comment_inside_string_kept() {
        let v = parse("k = \"a # b\"").unwrap();
        assert_eq!(v.req("k").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn empty_and_whitespace() {
        assert_eq!(parse("").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("\n\n# hi\n").unwrap(), Json::Obj(Default::default()));
    }
}
