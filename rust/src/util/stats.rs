//! Small statistics helpers: summaries, percentiles, EMA, binomial probe
//! math used by the Fig-2b/Fig-4 generalization experiments.

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// sample size
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// population standard deviation
    pub std: f64,
    /// minimum
    pub min: f64,
    /// maximum
    pub max: f64,
    /// median
    pub p50: f64,
    /// 95th percentile
    pub p95: f64,
    /// 99th percentile
    pub p99: f64,
}

/// Summary statistics of a sample.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p95: percentile_sorted(&sorted, 0.95),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Percentile of an already-sorted sample (nearest-rank with interpolation).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Exponential moving average with bias correction (for loss curves).
#[derive(Debug, Clone)]
pub struct Ema {
    beta: f64,
    value: f64,
    t: u64,
}

impl Ema {
    /// An EMA with decay `beta`.
    pub fn new(beta: f64) -> Ema {
        Ema { beta, value: 0.0, t: 0 }
    }

    /// Fold in one observation; returns the corrected mean.
    pub fn update(&mut self, x: f64) -> f64 {
        self.t += 1;
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.get()
    }

    /// Current bias-corrected value (NaN before any update).
    pub fn get(&self) -> f64 {
        if self.t == 0 {
            f64::NAN
        } else {
            self.value / (1.0 - self.beta.powi(self.t as i32))
        }
    }
}

/// Wilson score interval for a binomial proportion — used when reporting
/// P(loss increase) in the Fig-2b/Fig-4 probes so the paper-shape claims
/// ("~50% on held-out") carry uncertainty.
pub fn wilson_interval(successes: usize, n: usize, z: f64) -> (f64, f64) {
    if n == 0 {
        return (0.0, 1.0);
    }
    let p = successes as f64 / n as f64;
    let nf = n as f64;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = (z / denom) * ((p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt());
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Simple linear regression slope (loss-curve trend tests).
pub fn slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if var == 0.0 {
        0.0
    } else {
        cov / var
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.9);
        for _ in 0..200 {
            e.update(2.5);
        }
        assert!((e.get() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn ema_bias_corrected_early() {
        let mut e = Ema::new(0.99);
        e.update(4.0);
        assert!((e.get() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn wilson_sane() {
        let (lo, hi) = wilson_interval(50, 100, 1.96);
        assert!(lo < 0.5 && 0.5 < hi);
        assert!(hi - lo < 0.25);
        let (lo0, _) = wilson_interval(0, 100, 1.96);
        assert_eq!(lo0, 0.0);
    }

    #[test]
    fn slope_signs() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let down: Vec<f64> = xs.iter().map(|x| 5.0 - 0.3 * x).collect();
        assert!(slope(&xs, &down) < -0.29);
        let flat = vec![1.0; 10];
        assert!(slope(&xs, &flat).abs() < 1e-12);
    }
}
