//! Minimal u64-word bitset over flat coordinate indices.
//!
//! The serving layer stores each sparse adapter's *support* — which
//! parameter coordinates its delta touches — as 1 bit per parameter,
//! the same quantized-mask representation the paper's §3.3 memory
//! argument uses for the stored-mask ablation. The step-journal replay
//! uses the same words to accumulate the union of per-step masks, which
//! is exactly the invariant an exported delta is checked against
//! (support ⊆ mask union). Free functions over `&[u64]` rather than a
//! wrapper type: both producers already own plain vectors and the
//! serialized form is the word array itself.

/// Number of u64 words needed for `n` bits.
pub fn words(n: usize) -> usize {
    (n + 63) / 64
}

/// A zeroed bitset able to hold `n` bits.
pub fn new(n: usize) -> Vec<u64> {
    vec![0u64; words(n)]
}

/// Set bit `i`.
pub fn set(bits: &mut [u64], i: usize) {
    bits[i / 64] |= 1u64 << (i % 64);
}

/// Read bit `i`.
pub fn get(bits: &[u64], i: usize) -> bool {
    (bits[i / 64] >> (i % 64)) & 1 == 1
}

/// Number of set bits.
pub fn count(bits: &[u64]) -> usize {
    bits.iter().map(|w| w.count_ones() as usize).sum()
}

/// Set the first `n` bits (a dense mask); bits past `n` stay clear so
/// [`count`] and serialized comparisons stay exact.
pub fn set_all(bits: &mut [u64], n: usize) {
    for (w, word) in bits.iter_mut().enumerate() {
        let lo = w * 64;
        if lo + 64 <= n {
            *word = u64::MAX;
        } else if lo < n {
            *word = (1u64 << (n - lo)) - 1;
        } else {
            *word = 0;
        }
    }
}

/// `dst |= src` word-wise (accumulating a union of masks).
pub fn union_into(dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d |= s;
    }
}

/// Ascending indices of the set bits among the first `n`.
pub fn indices(bits: &[u64], n: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(count(bits));
    for i in 0..n {
        if get(bits, i) {
            out.push(i as u32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count_round_trip() {
        let mut b = new(130);
        assert_eq!(b.len(), 3);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            set(&mut b, i);
        }
        assert_eq!(count(&b), 8);
        assert!(get(&b, 64));
        assert!(!get(&b, 2));
        assert_eq!(indices(&b, 130), vec![0, 1, 63, 64, 65, 127, 128, 129]);
    }

    #[test]
    fn set_all_masks_the_tail_word() {
        let mut b = new(70);
        set_all(&mut b, 70);
        assert_eq!(count(&b), 70);
        assert!(get(&b, 69));
        // exact word boundary
        let mut c = new(128);
        set_all(&mut c, 128);
        assert_eq!(count(&c), 128);
    }

    #[test]
    fn union_accumulates() {
        let mut a = new(64);
        let mut b = new(64);
        set(&mut a, 1);
        set(&mut b, 2);
        union_into(&mut a, &b);
        assert!(get(&a, 1) && get(&a, 2));
        assert_eq!(count(&a), 2);
    }
}
