//! Leveled stderr logging + run-directory JSONL metric streams.
//!
//! The coordinator logs human-readable progress to stderr and appends
//! machine-readable metric records (one JSON object per line) to files
//! under the run directory — the format the repro harness and plotting
//! scripts consume.
//!
//! Wall-clock timing lives in [`crate::obs`]: `obs::span("...")` records
//! scoped timings into the process-wide metrics registry (and the
//! optional trace stream), replacing the ad-hoc `Timer` this module used
//! to carry.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::json::Json;

/// error level
pub const ERROR: u8 = 0;
/// info level (the default)
pub const INFO: u8 = 1;
/// debug level (`--verbose`)
pub const DEBUG: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

/// Set the global log level.
pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

/// Whether `level` is currently enabled.
pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

/// Log a formatted line to stderr at info level.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::INFO) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

/// Log a formatted line to stderr at debug level (`--verbose`).
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::DEBUG) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

/// Append-only JSONL metric stream.
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    /// Create/truncate the JSONL file (creating parent dirs).
    pub fn create(path: &Path) -> anyhow::Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok(JsonlWriter { out: BufWriter::new(file) })
    }

    /// Open an existing JSONL file for appending (creating it, and any
    /// parent dirs, if absent) — the slice-resume path of the job
    /// orchestrator's step journal.
    pub fn append(path: &Path) -> anyhow::Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlWriter { out: BufWriter::new(file) })
    }

    /// Append one JSON record as a line.
    pub fn write(&mut self, record: &Json) -> anyhow::Result<()> {
        writeln!(self.out, "{}", record.to_string())?;
        Ok(())
    }

    /// Flush buffered lines to disk.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Read a JSONL file back (used by the repro harness to aggregate runs).
pub fn read_jsonl(path: &Path) -> anyhow::Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(crate::util::json::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_round_trip() {
        let dir = std::env::temp_dir().join(format!("smz_log_test_{}", std::process::id()));
        let path = dir.join("metrics.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        for i in 0..3 {
            w.write(&Json::obj(vec![("step", Json::Num(i as f64))])).unwrap();
        }
        w.flush().unwrap();
        let rows = read_jsonl(&path).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2].req("step").unwrap().as_usize().unwrap(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
