//! Counter-based PRNG — the Rust mirror of `python/compile/kernels/prng.py`.
//!
//! Three independent implementations of this generator exist (jnp, Pallas
//! tile-local, and this one); MeZO's seed-replay trick requires them to be
//! bit-identical on the integer side and allclose on the Box–Muller side.
//! `python/tests/test_prng.py` writes golden vectors
//! (`python/tests/golden_prng.json`) that `tests/golden.rs` checks against
//! this module.
//!
//! Also hosts a small xoshiro-style generator (`Pcg32`) used for *local*
//! randomness (task data generation, property tests) where cross-language
//! agreement is needed between the data layer and nothing else.
//!
//! ## Bit-identity audit (data-parallel seed sync)
//!
//! The seed-sync DP engine (`crate::parallel::dp`) relies on every
//! worker regenerating the *same* `z` stream from the shared step seed.
//! The generators here are safe for that by construction, and must stay
//! so:
//!
//! * the counter PRNG is **pure**: `normal(key, idx)` is a function of
//!   `(seed, layer_id, idx)` only. No global state, no thread-locals, no
//!   per-call counters — which thread evaluates a stream can never
//!   change its values (`tests/parallel.rs` guards this);
//! * keys must always derive from the **step seed** `(cfg.seed, t)`,
//!   never from a worker index, pool-thread id, or iteration-order
//!   artifact. `Pcg32` (stateful, advance-order-dependent) is for data
//!   synthesis only and MUST NOT be used for perturbation replay.

/// Stream salts — must match prng.py.
pub const STREAM_A: u32 = 0x9E37_79B9;
/// second Box–Muller stream salt
pub const STREAM_B: u32 = 0x85EB_CA6B;
/// R-MeZO Bernoulli-mask stream salt
pub const STREAM_MASK: u32 = 0xC2B2_AE35;

const TWO_PI: f32 = 6.283_185_3;
const INV_2_24: f32 = 1.0 / 16_777_216.0;
const MIN_UNIT: f32 = 5.960_464_5e-8;

/// Well-mixed 32-bit finalizer ("lowbias32").
#[inline]
pub fn lowbias32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB_352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846C_A68B);
    x ^= x >> 16;
    x
}

/// Order-sensitive key folding (mirrors prng.fold).
#[inline]
pub fn fold(key: u32, data: u32) -> u32 {
    lowbias32(key ^ data.wrapping_add(STREAM_A).wrapping_add(key << 6).wrapping_add(key >> 2))
}

/// Per-(seed, layer) stream key.
#[inline]
pub fn layer_key(seed_lo: u32, seed_hi: u32, layer_id: u32) -> u32 {
    fold(fold(lowbias32(seed_lo), seed_hi), layer_id)
}

/// uint32 stream value for flat element index `idx`.
#[inline]
pub fn uniform_bits(key: u32, idx: u32, stream: u32) -> u32 {
    lowbias32(idx.wrapping_mul(2_654_435_761) ^ key ^ stream)
}

/// Top 24 bits -> (0, 1), never exactly 0.
#[inline]
pub fn bits_to_unit(bits: u32) -> f32 {
    ((bits >> 8) as f32 * INV_2_24).max(MIN_UNIT)
}

/// Standard normal via Box–Muller, matching the jnp implementation.
#[inline]
pub fn normal(key: u32, idx: u32) -> f32 {
    let u1 = bits_to_unit(uniform_bits(key, idx, STREAM_A));
    let u2 = bits_to_unit(uniform_bits(key, idx, STREAM_B));
    (-2.0 * u1.ln()).sqrt() * (TWO_PI * u2).cos()
}

/// Uniform (0,1) on the mask stream (R-MeZO masks).
#[inline]
pub fn uniform01(key: u32, idx: u32) -> f32 {
    bits_to_unit(uniform_bits(key, idx, STREAM_MASK))
}

/// z ~ N(0, I_n) for a parameter segment (layer_id = layout entry index).
pub fn segment_normal(seed_lo: u32, seed_hi: u32, layer_id: u32, offset: u32, n: usize) -> Vec<f32> {
    let key = layer_key(seed_lo, seed_hi, layer_id);
    (0..n as u32).map(|i| normal(key, offset + i)).collect()
}

// ---------------------------------------------------------------------------
// Local (non-cross-language) generator for data synthesis & property tests.
// PCG-XSH-RR 32, seeded deterministically; small, fast, well understood.
// ---------------------------------------------------------------------------

/// PCG32 generator for task data / shuffling / property tests.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seeded generator on an explicit stream.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut g = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        g.next_u32();
        g.state = g.state.wrapping_add(seed);
        g.next_u32();
        g
    }

    /// Convenience: one generator per (experiment, purpose) name.
    pub fn from_name(seed: u64, name: &str) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Self::new(seed, h)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, bound).
    pub fn below(&mut self, bound: u32) -> u32 {
        // Lemire's nearly-divisionless method would be overkill; simple
        // rejection keeps it unbiased.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform in [0, 1).
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * INV_2_24
    }

    /// Standard normal via Box–Muller.
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.unit_f32().max(MIN_UNIT);
        let u2 = self.unit_f32();
        (-2.0 * u1.ln()).sqrt() * (TWO_PI * u2).cos()
    }

    /// Bernoulli(p) draw.
    pub fn chance(&mut self, p: f64) -> bool {
        (self.unit_f32() as f64) < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowbias32_is_deterministic_and_mixing() {
        assert_eq!(lowbias32(1), lowbias32(1));
        assert_ne!(lowbias32(1), lowbias32(2));
        // avalanche smoke: flipping one bit flips many output bits
        let a = lowbias32(0x1234_5678);
        let b = lowbias32(0x1234_5679);
        assert!((a ^ b).count_ones() >= 8);
    }

    #[test]
    fn normal_moments() {
        let z = segment_normal(7, 9, 3, 0, 100_000);
        let mean: f32 = z.iter().sum::<f32>() / z.len() as f32;
        let var: f32 = z.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / z.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.02, "std {}", var.sqrt());
    }

    #[test]
    fn segment_offset_consistency() {
        let full = segment_normal(11, 22, 5, 0, 1000);
        let a = segment_normal(11, 22, 5, 0, 300);
        let b = segment_normal(11, 22, 5, 300, 700);
        assert_eq!(&full[..300], &a[..]);
        assert_eq!(&full[300..], &b[..]);
    }

    #[test]
    fn seed_replay_identical() {
        assert_eq!(segment_normal(123, 456, 7, 0, 512), segment_normal(123, 456, 7, 0, 512));
    }

    #[test]
    fn streams_are_thread_independent() {
        // the DP bit-identity contract: worker-local z-regeneration is a
        // pure function of the shared step seed — which thread runs it
        // (and how many run it concurrently) must be unobservable
        let reference = segment_normal(42, 3, 1, 0, 2048);
        let copies: Vec<Vec<f32>> = std::thread::scope(|scope| {
            (0..4)
                .map(|_| scope.spawn(|| segment_normal(42, 3, 1, 0, 2048)))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for c in &copies {
            assert_eq!(c, &reference);
        }
    }

    #[test]
    fn different_layers_differ() {
        assert_ne!(segment_normal(1, 2, 0, 0, 16), segment_normal(1, 2, 1, 0, 16));
    }

    #[test]
    fn pcg_bounded_unbiased_smoke() {
        let mut g = Pcg32::new(42, 1);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[g.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn pcg_shuffle_is_permutation() {
        let mut g = Pcg32::new(7, 3);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pcg_streams_independent() {
        let mut a = Pcg32::from_name(1, "alpha");
        let mut b = Pcg32::from_name(1, "beta");
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }
}
