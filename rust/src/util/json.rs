//! Minimal JSON: a recursive-descent parser + a writer.
//!
//! Scope: everything `artifacts/manifest.json`, checkpoints' sidecars and
//! results files need — objects, arrays, strings (with escapes), numbers,
//! bools, null. No serde in the vendored dependency set, so this is the
//! in-repo substrate. Error messages carry byte offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number (always carried as f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object with ordered keys
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------
    /// Object field, if present.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing reads nicer.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Numeric value or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// Non-negative integer value or a type error.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    /// String value or a type error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// Array items or a type error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Object map or a type error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // ---- constructors ----------------------------------------------------
    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Array of numbers from f32s.
    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    /// Array of strings.
    pub fn from_strs(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.to_string())).collect())
    }

    // ---- writer ------------------------------------------------------------
    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == 0.0 && n.is_sign_negative() {
                        // keep the sign bit: serving round-trips logits
                        // bit-exactly, and `-0.0 as i64` would print "0"
                        out.push_str("-0.0");
                    } else if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document (full input must be consumed).
pub fn parse(input: &str) -> Result<Json> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}' at {start}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (no surrogate-pair support: manifest is ASCII)
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i - 1),
                    }
                }
                _ => {
                    // re-decode UTF-8 from the byte stream
                    let start = self.i - 1;
                    let ch_len = utf8_len(c);
                    self.i = start + ch_len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                    self.ws();
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                    self.ws();
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = parse(s).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn round_trip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.25e-2}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -0.0225);
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""tab\there A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\there A");
        // round-trip preserves content
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":}", "nan"] {
            assert!(parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn accessors_report_key() {
        let v = parse(r#"{"x": 1}"#).unwrap();
        let err = v.req("zork").unwrap_err().to_string();
        assert!(err.contains("zork"));
        assert_eq!(v.req("x").unwrap().as_usize().unwrap(), 1);
        assert!(v.req("x").unwrap().as_str().is_err());
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn negative_zero_round_trips_with_its_sign_bit() {
        let v = parse(&Json::Num(-0.0).to_string()).unwrap();
        let back = v.as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // positive zero still prints as a plain integer
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
