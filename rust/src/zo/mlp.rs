//! Minimal MLP on flat `Vec<f32>` params — the loss surface used by the
//! property tests and the Fig-2 noise analysis. Two linear layers + tanh,
//! softmax cross-entropy, with an exact analytic gradient (so ZO estimates
//! can be compared against ground truth, something the 7B-scale paper can
//! only do implicitly).

use crate::util::prng::Pcg32;

#[derive(Debug, Clone)]
/// MLP shape specification.
pub struct MlpSpec {
    /// input features
    pub d_in: usize,
    /// hidden width
    pub d_hidden: usize,
    /// output classes
    pub n_classes: usize,
}

impl MlpSpec {
    /// Flat parameter count.
    pub fn n_params(&self) -> usize {
        self.d_in * self.d_hidden + self.d_hidden + self.d_hidden * self.n_classes + self.n_classes
    }

    /// Heavy-tailed-ish init: N(0, 0.5) on weights — gives the magnitude
    /// spread the S-MeZO mask needs — zero biases.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::from_name(seed, "mlp-init");
        let mut p = vec![0.0f32; self.n_params()];
        let w1 = self.d_in * self.d_hidden;
        let w2_off = w1 + self.d_hidden;
        let w2 = self.d_hidden * self.n_classes;
        for i in 0..w1 {
            p[i] = 0.5 * rng.normal_f32();
        }
        for i in 0..w2 {
            p[w2_off + i] = 0.5 * rng.normal_f32();
        }
        p
    }
}

/// A batch of (x, y) pairs.
#[derive(Debug, Clone)]
pub struct MlpBatch {
    /// inputs, row-major `[n, d_in]`
    pub xs: Vec<f32>, // [n, d_in]
    /// class labels
    pub ys: Vec<usize>,
}

/// Linearly-separable-with-noise synthetic classification data.
/// `proto_seed` fixes the class prototypes (the "task"); `sample_seed`
/// varies the drawn batch. Batches that should be i.i.d. from the SAME
/// distribution (the Fig-2b half-batch probe!) must share `proto_seed`.
pub fn make_data_with(spec: &MlpSpec, n: usize, proto_seed: u64, sample_seed: u64) -> MlpBatch {
    let mut prng = Pcg32::from_name(proto_seed, "mlp-protos");
    let protos: Vec<f32> =
        (0..spec.n_classes * spec.d_in).map(|_| prng.normal_f32()).collect();
    let mut rng = Pcg32::from_name(sample_seed, "mlp-data");
    let mut xs = Vec::with_capacity(n * spec.d_in);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(spec.n_classes as u32) as usize;
        for j in 0..spec.d_in {
            xs.push(protos[c * spec.d_in + j] + 0.4 * rng.normal_f32());
        }
        ys.push(c);
    }
    MlpBatch { xs, ys }
}

/// Single-seed convenience (prototypes and samples from one seed).
pub fn make_data(spec: &MlpSpec, n: usize, seed: u64) -> MlpBatch {
    make_data_with(spec, n, 0xA5A5, seed)
}

fn forward(spec: &MlpSpec, p: &[f32], x: &[f32], hidden: &mut [f32], logits: &mut [f32]) {
    let (din, dh, nc) = (spec.d_in, spec.d_hidden, spec.n_classes);
    let w1 = &p[..din * dh];
    let b1 = &p[din * dh..din * dh + dh];
    let w2 = &p[din * dh + dh..din * dh + dh + dh * nc];
    let b2 = &p[din * dh + dh + dh * nc..];
    for h in 0..dh {
        let mut acc = b1[h];
        for i in 0..din {
            acc += x[i] * w1[i * dh + h];
        }
        hidden[h] = acc.tanh();
    }
    for c in 0..nc {
        let mut acc = b2[c];
        for h in 0..dh {
            acc += hidden[h] * w2[h * nc + c];
        }
        logits[c] = acc;
    }
}

/// Mean cross-entropy over the batch.
pub fn loss(spec: &MlpSpec, p: &[f32], batch: &MlpBatch) -> f32 {
    let n = batch.ys.len();
    let mut hidden = vec![0.0f32; spec.d_hidden];
    let mut logits = vec![0.0f32; spec.n_classes];
    let mut total = 0.0f64;
    for ex in 0..n {
        let x = &batch.xs[ex * spec.d_in..(ex + 1) * spec.d_in];
        forward(spec, p, x, &mut hidden, &mut logits);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = logits.iter().map(|l| (l - max).exp()).sum::<f32>().ln() + max;
        total += (lse - logits[batch.ys[ex]]) as f64;
    }
    (total / n as f64) as f32
}

/// Mean accuracy over the batch.
pub fn accuracy(spec: &MlpSpec, p: &[f32], batch: &MlpBatch) -> f32 {
    let n = batch.ys.len();
    let mut hidden = vec![0.0f32; spec.d_hidden];
    let mut logits = vec![0.0f32; spec.n_classes];
    let mut correct = 0usize;
    for ex in 0..n {
        let x = &batch.xs[ex * spec.d_in..(ex + 1) * spec.d_in];
        forward(spec, p, x, &mut hidden, &mut logits);
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == batch.ys[ex] {
            correct += 1;
        }
    }
    correct as f32 / n as f32
}

/// Exact analytic gradient (backprop by hand) — ground truth for the
/// Fig-2 noise analysis and the SGD arm of the Fig-4 probe.
pub fn grad(spec: &MlpSpec, p: &[f32], batch: &MlpBatch) -> Vec<f32> {
    let (din, dh, nc) = (spec.d_in, spec.d_hidden, spec.n_classes);
    let n = batch.ys.len();
    let w1_off = 0;
    let b1_off = din * dh;
    let w2_off = b1_off + dh;
    let b2_off = w2_off + dh * nc;
    let mut g = vec![0.0f32; p.len()];
    let mut hidden = vec![0.0f32; dh];
    let mut logits = vec![0.0f32; nc];
    let scale = 1.0 / n as f32;
    for ex in 0..n {
        let x = &batch.xs[ex * din..(ex + 1) * din];
        forward(spec, p, x, &mut hidden, &mut logits);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|l| (l - max).exp()).collect();
        let z: f32 = exps.iter().sum();
        // dL/dlogit_c = softmax_c - 1[c == y]
        let mut dlogit = vec![0.0f32; nc];
        for c in 0..nc {
            dlogit[c] = exps[c] / z - if c == batch.ys[ex] { 1.0 } else { 0.0 };
        }
        // w2, b2
        for h in 0..dh {
            for c in 0..nc {
                g[w2_off + h * nc + c] += scale * hidden[h] * dlogit[c];
            }
        }
        for c in 0..nc {
            g[b2_off + c] += scale * dlogit[c];
        }
        // back through tanh
        let w2 = &p[w2_off..w2_off + dh * nc];
        for h in 0..dh {
            let mut dh_acc = 0.0f32;
            for c in 0..nc {
                dh_acc += dlogit[c] * w2[h * nc + c];
            }
            let dpre = dh_acc * (1.0 - hidden[h] * hidden[h]);
            for i in 0..din {
                g[w1_off + i * dh + h] += scale * x[i] * dpre;
            }
            g[b1_off + h] += scale * dpre;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> MlpSpec {
        MlpSpec { d_in: 8, d_hidden: 12, n_classes: 3 }
    }

    #[test]
    fn shapes() {
        let s = spec();
        assert_eq!(s.n_params(), 8 * 12 + 12 + 12 * 3 + 3);
        let p = s.init(1);
        assert_eq!(p.len(), s.n_params());
    }

    #[test]
    fn loss_finite_and_near_uniform_at_init() {
        let s = spec();
        let p = s.init(2);
        let b = make_data(&s, 64, 3);
        let l = loss(&s, &p, &b);
        assert!(l.is_finite());
        assert!(l > 0.05 && l < 5.0, "loss {l}");
    }

    #[test]
    fn analytic_grad_matches_finite_difference() {
        let s = spec();
        let mut p = s.init(4);
        let b = make_data(&s, 16, 5);
        let g = grad(&s, &p, &b);
        let mut rng = crate::util::prng::Pcg32::new(1, 1);
        for _ in 0..20 {
            let i = rng.below(p.len() as u32) as usize;
            let h = 1e-3f32;
            let orig = p[i];
            p[i] = orig + h;
            let lp = loss(&s, &p, &b);
            p[i] = orig - h;
            let lm = loss(&s, &p, &b);
            p[i] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - g[i]).abs() < 2e-2 * g[i].abs().max(0.1),
                "coord {i}: fd {fd} vs analytic {}",
                g[i]
            );
        }
    }

    #[test]
    fn gradient_descent_learns() {
        let s = spec();
        let mut p = s.init(6);
        let train = make_data(&s, 128, 7);
        for _ in 0..300 {
            let g = grad(&s, &p, &train);
            for (pi, gi) in p.iter_mut().zip(&g) {
                *pi -= 0.5 * gi;
            }
        }
        assert!(accuracy(&s, &p, &train) > 0.9);
    }
}
