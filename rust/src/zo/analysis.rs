//! The paper's §3.1 noise analysis, replicated where ground truth exists.
//!
//! On the MLP substrate the exact gradient is available, so we can measure
//! directly what the paper infers at 7B scale:
//!
//!   * [`half_batch_probe`] — estimate the ZO gradient on batch half B1,
//!     apply the update, and check whether the loss on B1 vs held-out B2
//!     went up (Fig. 2b / Fig. 4).
//!   * [`noise_by_magnitude`] — decompose the ZO gradient error
//!     delta = g_true - g_zo over small-weight vs large-weight coordinates
//!     (the observation motivating S-MeZO).

use crate::util::prng::Pcg32;
use crate::zo::mlp::{self, MlpBatch, MlpSpec};
use crate::zo::optim::{Variant, ZoStepper};
use crate::zo::MaskMode;

/// Outcome counts of the generalization probe.
#[derive(Debug, Clone, Default)]
pub struct ProbeResult {
    /// probe steps counted
    pub n: usize,
    /// loss increased on the SAME half-batch the gradient came from
    pub up_same: usize,
    /// loss increased on the HELD-OUT half-batch
    pub up_held: usize,
}

impl ProbeResult {
    /// P(loss increase | same half-batch).
    pub fn p_up_same(&self) -> f64 {
        self.up_same as f64 / self.n.max(1) as f64
    }
    /// P(loss increase | held-out half-batch).
    pub fn p_up_held(&self) -> f64 {
        self.up_held as f64 / self.n.max(1) as f64
    }
}

/// Which estimator drives the probe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Estimator {
    /// two-point ZO estimate (MeZO)
    Zo { eps: f32 },
    /// exact gradient (SGD arm of Fig. 4)
    Exact,
}

/// Run the Fig-2b probe for `steps` steps: at each step draw two disjoint
/// half-batches, estimate the update direction on B1, tentatively apply
/// it, record the sign of the loss change on both halves, then keep the
/// update (the probe trains as it measures, like the paper's Fig. 4
/// per-epoch curves).
pub fn half_batch_probe(
    spec: &MlpSpec,
    theta: &mut Vec<f32>,
    estimator: Estimator,
    mask: MaskMode,
    lr: f32,
    steps: usize,
    seed: u64,
) -> ProbeResult {
    let mut rng = Pcg32::from_name(seed, "probe");
    let mut result = ProbeResult::default();
    for t in 0..steps {
        // B1/B2 are disjoint i.i.d. draws from the SAME task distribution
        // (shared prototypes) — the paper's B_t = {B_t^1, B_t^2} split.
        let b1 = mlp::make_data_with(spec, 16, seed, rng.next_u32() as u64);
        let b2 = mlp::make_data_with(spec, 16, seed, rng.next_u32() as u64);
        let l1_before = mlp::loss(spec, theta, &b1);
        let l2_before = mlp::loss(spec, theta, &b2);
        let grad = match estimator {
            Estimator::Zo { eps } => {
                let stepper = ZoStepper::new(eps, lr, Variant::Sgd);
                let (g, _) =
                    stepper.estimate(theta, mask, (t as u32, seed as u32), |p| mlp::loss(spec, p, &b1));
                g
            }
            Estimator::Exact => mlp::grad(spec, theta, &b1),
        };
        for (p, g) in theta.iter_mut().zip(&grad) {
            *p -= lr * g;
        }
        let l1_after = mlp::loss(spec, theta, &b1);
        let l2_after = mlp::loss(spec, theta, &b2);
        result.n += 1;
        if l1_after > l1_before {
            result.up_same += 1;
        }
        if l2_after > l2_before {
            result.up_held += 1;
        }
    }
    result
}

/// Per-magnitude-group decomposition of the ZO gradient error.
#[derive(Debug, Clone)]
pub struct NoiseByMagnitude {
    /// mean |g_true - g_zo| over the bottom-20%-|theta| coordinates
    pub err_small: f64,
    /// ... over the top-20% coordinates
    pub err_large: f64,
    /// mean |g_true| over the same groups (for relative comparison)
    pub gmag_small: f64,
    /// mean |g_true| over the top-20% coordinates
    pub gmag_large: f64,
    /// cosine similarity of g_zo with g_true restricted to each group
    pub cos_small: f64,
    /// cosine similarity restricted to the top-20% group
    pub cos_large: f64,
}

/// Average the decomposition over `trials` independent z draws
/// (paper §3.1: "the top 20% largest weights are considered large, the
/// bottom 20% small").
pub fn noise_by_magnitude(
    spec: &MlpSpec,
    theta: &mut Vec<f32>,
    batch: &MlpBatch,
    eps: f32,
    trials: usize,
    seed: u64,
) -> NoiseByMagnitude {
    let n = theta.len();
    let g_true = mlp::grad(spec, theta, batch);
    // magnitude groups
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| theta[a].abs().partial_cmp(&theta[b].abs()).unwrap());
    let k = n / 5;
    let small: Vec<usize> = order[..k].to_vec();
    let large: Vec<usize> = order[n - k..].to_vec();

    let stepper = ZoStepper::new(eps, 0.0, Variant::Sgd);
    let mut acc = NoiseByMagnitude {
        err_small: 0.0,
        err_large: 0.0,
        gmag_small: 0.0,
        gmag_large: 0.0,
        cos_small: 0.0,
        cos_large: 0.0,
    };
    for t in 0..trials {
        let (g_zo, _) = stepper.estimate(theta, MaskMode::Dense, (seed as u32, t as u32), |p| {
            mlp::loss(spec, p, batch)
        });
        let group_stats = |idx: &[usize]| -> (f64, f64, f64) {
            let mut err = 0.0f64;
            let mut mag = 0.0f64;
            let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
            for &i in idx {
                err += (g_true[i] - g_zo[i]).abs() as f64;
                mag += g_true[i].abs() as f64;
                dot += (g_true[i] * g_zo[i]) as f64;
                na += (g_true[i] * g_true[i]) as f64;
                nb += (g_zo[i] * g_zo[i]) as f64;
            }
            let cos = if na > 0.0 && nb > 0.0 { dot / (na.sqrt() * nb.sqrt()) } else { 0.0 };
            (err / idx.len() as f64, mag / idx.len() as f64, cos)
        };
        let (es, ms, cs) = group_stats(&small);
        let (el, ml, cl) = group_stats(&large);
        acc.err_small += es;
        acc.err_large += el;
        acc.gmag_small += ms;
        acc.gmag_large += ml;
        acc.cos_small += cs;
        acc.cos_large += cl;
    }
    let tf = trials as f64;
    acc.err_small /= tf;
    acc.err_large /= tf;
    acc.gmag_small /= tf;
    acc.gmag_large /= tf;
    acc.cos_small /= tf;
    acc.cos_large /= tf;
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> MlpSpec {
        MlpSpec { d_in: 8, d_hidden: 16, n_classes: 3 }
    }

    #[test]
    fn exact_gradient_generalizes_better_than_zo() {
        // The paper's core Fig-4 claim: P(loss up | held-out) is near 0.5
        // for ZO but much lower for SGD.
        let s = spec();
        let mut theta_zo = s.init(1);
        let mut theta_fo = s.init(1);
        let zo = half_batch_probe(
            &s, &mut theta_zo, Estimator::Zo { eps: 1e-3 }, MaskMode::Dense, 0.05, 150, 42,
        );
        let fo = half_batch_probe(&s, &mut theta_fo, Estimator::Exact, MaskMode::Dense, 0.05, 150, 42);
        assert!(zo.p_up_held() > fo.p_up_held() + 0.1, "zo {zo:?} fo {fo:?}");
        // and ZO still mostly descends on its own batch
        assert!(zo.p_up_same() < 0.45, "zo same-batch {zo:?}");
    }

    #[test]
    fn probe_counts_bounded() {
        let s = spec();
        let mut theta = s.init(3);
        let r = half_batch_probe(
            &s, &mut theta, Estimator::Zo { eps: 1e-3 }, MaskMode::Dense, 0.02, 25, 7,
        );
        assert_eq!(r.n, 25);
        assert!(r.up_same <= r.n && r.up_held <= r.n);
    }

    #[test]
    fn noise_decomposition_runs() {
        let s = spec();
        let mut theta = s.init(5);
        let batch = mlp::make_data(&s, 32, 9);
        let d = noise_by_magnitude(&s, &mut theta, &batch, 1e-3, 8, 11);
        assert!(d.err_small.is_finite() && d.err_large.is_finite());
        assert!(d.err_small > 0.0 && d.err_large > 0.0);
    }
}
