//! The ZO optimizer family on plain vectors (paper Alg. 1–3).
//!
//! `ZoStepper` is written exactly like the L2 JAX step: regenerate
//! z from `(seed, layer_id=0, index)` with the shared counter PRNG,
//! evaluate the loss at theta ± eps * m⊙z, form the projected gradient,
//! and update only the masked coordinates. Nothing is ever stored per
//! coordinate beyond theta itself.

use crate::util::prng;
use crate::zo::MaskMode;

/// Percentile threshold over |theta| (paper §8.2): the bottom
/// (1 - sparsity) fraction by magnitude is selected.
///
/// Boundary behavior: `sparsity <= 0` returns the largest magnitude
/// (everything selected — the MeZO degeneracy), and `sparsity >= 1`
/// returns `f32::NEG_INFINITY` (nothing selected, not even exact zeros).
///
/// NaN safety: magnitudes are ordered with [`f32::total_cmp`], so a
/// NaN-poisoned theta (a diverging run mid-flight) cannot panic the
/// sort. `|NaN|` clears the sign bit, and total order places positive
/// NaNs above `+inf`, so poisoned coordinates land in the always-frozen
/// top tail and the percentile over the finite coordinates shifts by at
/// most the poison count.
///
/// # Examples
/// ```
/// use sparse_mezo::zo::optim::percentile_threshold;
/// let theta: Vec<f32> = (1..=100).map(|i| i as f32).collect();
/// let h = percentile_threshold(&theta, 0.8); // keep the smallest ~20%
/// assert_eq!(theta.iter().filter(|x| x.abs() <= h).count(), 21);
/// // boundary cases: keep-everything and keep-nothing
/// assert!(percentile_threshold(&theta, 0.0) >= 100.0);
/// assert_eq!(percentile_threshold(&theta, 1.0), f32::NEG_INFINITY);
/// ```
pub fn percentile_threshold(theta: &[f32], sparsity: f32) -> f32 {
    assert!(!theta.is_empty());
    if sparsity >= 1.0 {
        return f32::NEG_INFINITY;
    }
    let mut mags: Vec<f32> = theta.iter().map(|x| x.abs()).collect();
    mags.sort_by(f32::total_cmp);
    if sparsity <= 0.0 {
        return mags[mags.len() - 1];
    }
    let q = (((1.0 - sparsity) * mags.len() as f32).floor() as usize).min(mags.len() - 1);
    mags[q]
}

/// Result of one ZO step.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// loss at `theta + eps * m ⊙ z`
    pub l_plus: f32,
    /// loss at `theta - eps * m ⊙ z`
    pub l_minus: f32,
    /// projected gradient `(l_plus - l_minus) / (2 eps)`
    pub proj_grad: f32,
    /// fraction of coordinates the mask selected
    pub masked_frac: f32,
    /// squared L2 norm of the applied update
    pub update_norm_sq: f32,
}

/// Variants supported by the pure-Rust stepper.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Variant {
    /// theta -= lr * g * m⊙z (MeZO / S-MeZO / R-MeZO depending on mask)
    Sgd,
    /// theta -= lr * sign(g * m⊙z)
    Sign,
    /// accept the Sgd step only if it does not increase the batch loss
    Conservative,
    /// heavy-ball momentum on g * m⊙z (beta = 0.9)
    Momentum,
}

/// The seed-replay ZO stepper (paper Alg. 1–3 on plain vectors).
pub struct ZoStepper {
    /// perturbation scale
    pub eps: f32,
    /// learning rate
    pub lr: f32,
    /// update rule
    pub variant: Variant,
    /// momentum buffer (allocated lazily for Variant::Momentum)
    momentum: Vec<f32>,
    beta: f32,
}

impl ZoStepper {
    /// A stepper with zeroed momentum state.
    pub fn new(eps: f32, lr: f32, variant: Variant) -> ZoStepper {
        ZoStepper { eps, lr, variant, momentum: Vec::new(), beta: 0.9 }
    }

    /// One step of Algorithm 1. `loss` is the minibatch loss closure;
    /// the caller controls which batch it binds (the Fig-2b probe calls
    /// this with one batch and evaluates deltas on another).
    ///
    /// The walk is **fused and chunked**: the three loss-side traversals
    /// (`+eps`, `-2eps`, restore) plus the update would naively cost four
    /// z-regenerations per coordinate; here the restore and the update
    /// share one regeneration (`theta += (eps - lr·g)·m⊙z` for the SGD
    /// rule), and every traversal streams z through a small stack chunk.
    /// The mask is computed exactly once, from the unperturbed `theta` —
    /// per-walk recomputation would break seed replay for magnitude masks,
    /// whose support depends on the (perturbed) parameter values.
    ///
    /// # Examples
    /// ```
    /// use sparse_mezo::zo::{optim::{Variant, ZoStepper}, MaskMode};
    /// let quad = |x: &[f32]| x.iter().map(|v| (v - 1.0) * (v - 1.0)).sum::<f32>();
    /// let mut theta = vec![0.0f32; 8];
    /// let mut opt = ZoStepper::new(1e-3, 0.05, Variant::Sgd);
    /// let info = opt.step(&mut theta, MaskMode::Dense, (1, 2), quad);
    /// assert!(info.l_plus.is_finite() && info.masked_frac == 1.0);
    /// // seed replay: the same (seed, step) pair reproduces the step
    /// let mut theta2 = vec![0.0f32; 8];
    /// let mut opt2 = ZoStepper::new(1e-3, 0.05, Variant::Sgd);
    /// opt2.step(&mut theta2, MaskMode::Dense, (1, 2), quad);
    /// assert_eq!(theta, theta2);
    /// ```
    pub fn step<F: FnMut(&[f32]) -> f32>(
        &mut self,
        theta: &mut [f32],
        mask: MaskMode,
        seed: (u32, u32),
        mut loss: F,
    ) -> StepInfo {
        /// z-chunk size: big enough to amortize loop overhead, small
        /// enough to stay in L1.
        const CHUNK: usize = 512;
        let n = theta.len();
        let key = prng::layer_key(seed.0, seed.1, 0);
        // Mask support is decided ONCE, from the unperturbed theta.
        let m: Vec<f32> = mask.mask_vec(theta);
        let masked_frac = m.iter().sum::<f32>() / n as f32;
        let mut z = [0.0f32; CHUNK];
        let eps = self.eps;
        let lr = self.lr;

        // + eps perturb (Alg. 2 with seed replay), chunked
        let mut start = 0;
        while start < n {
            let len = CHUNK.min(n - start);
            for (j, zj) in z[..len].iter_mut().enumerate() {
                *zj = prng::normal(key, (start + j) as u32);
            }
            for j in 0..len {
                theta[start + j] += eps * m[start + j] * z[j];
            }
            start += len;
        }
        let l_plus = loss(theta);

        // -2 eps
        let mut start = 0;
        while start < n {
            let len = CHUNK.min(n - start);
            for (j, zj) in z[..len].iter_mut().enumerate() {
                *zj = prng::normal(key, (start + j) as u32);
            }
            for j in 0..len {
                theta[start + j] -= 2.0 * eps * m[start + j] * z[j];
            }
            start += len;
        }
        let l_minus = loss(theta);
        let g = (l_plus - l_minus) / (2.0 * eps);

        // fused restore (+eps) + update, one z-regeneration per coordinate
        let mut update_norm_sq = 0.0f32;
        match self.variant {
            Variant::Sgd => {
                let mut start = 0;
                while start < n {
                    let len = CHUNK.min(n - start);
                    for (j, zj) in z[..len].iter_mut().enumerate() {
                        *zj = prng::normal(key, (start + j) as u32);
                    }
                    for j in 0..len {
                        let i = start + j;
                        let u = lr * g * m[i] * z[j];
                        theta[i] += eps * m[i] * z[j] - u;
                        update_norm_sq += u * u;
                    }
                    start += len;
                }
            }
            Variant::Sign => {
                let mut start = 0;
                while start < n {
                    let len = CHUNK.min(n - start);
                    for (j, zj) in z[..len].iter_mut().enumerate() {
                        *zj = prng::normal(key, (start + j) as u32);
                    }
                    for j in 0..len {
                        let i = start + j;
                        theta[i] += eps * m[i] * z[j];
                        let gz = g * m[i] * z[j];
                        if gz != 0.0 {
                            let u = lr * gz.signum();
                            theta[i] -= u;
                            update_norm_sq += u * u;
                        }
                    }
                    start += len;
                }
            }
            Variant::Conservative => {
                // restore exactly, snapshot, then try the candidate step
                let mut start = 0;
                while start < n {
                    let len = CHUNK.min(n - start);
                    for (j, zj) in z[..len].iter_mut().enumerate() {
                        *zj = prng::normal(key, (start + j) as u32);
                    }
                    for j in 0..len {
                        theta[start + j] += eps * m[start + j] * z[j];
                    }
                    start += len;
                }
                let before: Vec<f32> = theta.to_vec();
                let l_base = 0.5 * (l_plus + l_minus);
                let mut start = 0;
                while start < n {
                    let len = CHUNK.min(n - start);
                    for (j, zj) in z[..len].iter_mut().enumerate() {
                        *zj = prng::normal(key, (start + j) as u32);
                    }
                    for j in 0..len {
                        theta[start + j] -= lr * g * m[start + j] * z[j];
                    }
                    start += len;
                }
                let l_cand = loss(theta);
                if l_cand > l_base {
                    theta.copy_from_slice(&before); // reject
                } else {
                    for i in 0..n {
                        let u = theta[i] - before[i];
                        update_norm_sq += u * u;
                    }
                }
            }
            Variant::Momentum => {
                if self.momentum.len() != n {
                    self.momentum = vec![0.0; n];
                }
                let mut start = 0;
                while start < n {
                    let len = CHUNK.min(n - start);
                    for (j, zj) in z[..len].iter_mut().enumerate() {
                        *zj = prng::normal(key, (start + j) as u32);
                    }
                    for j in 0..len {
                        let i = start + j;
                        let gz = g * m[i] * z[j];
                        self.momentum[i] = self.beta * self.momentum[i] + (1.0 - self.beta) * gz;
                        let u = lr * self.momentum[i];
                        theta[i] += eps * m[i] * z[j] - u;
                        update_norm_sq += u * u;
                    }
                    start += len;
                }
            }
        }

        StepInfo { l_plus, l_minus, proj_grad: g, masked_frac, update_norm_sq }
    }

    /// The ZO gradient estimate g * m⊙z WITHOUT applying it (probe use).
    pub fn estimate<F: FnMut(&[f32]) -> f32>(
        &self,
        theta: &mut [f32],
        mask: MaskMode,
        seed: (u32, u32),
        mut loss: F,
    ) -> (Vec<f32>, StepInfo) {
        let n = theta.len();
        let key = prng::layer_key(seed.0, seed.1, 0);
        let m = mask.mask_vec(theta);
        for i in 0..n {
            theta[i] += self.eps * m[i] * prng::normal(key, i as u32);
        }
        let l_plus = loss(theta);
        for i in 0..n {
            theta[i] -= 2.0 * self.eps * m[i] * prng::normal(key, i as u32);
        }
        let l_minus = loss(theta);
        for i in 0..n {
            theta[i] += self.eps * m[i] * prng::normal(key, i as u32);
        }
        let g = (l_plus - l_minus) / (2.0 * self.eps);
        let grad: Vec<f32> = (0..n).map(|i| g * m[i] * prng::normal(key, i as u32)).collect();
        let masked_frac = m.iter().sum::<f32>() / n as f32;
        (grad, StepInfo { l_plus, l_minus, proj_grad: g, masked_frac, update_norm_sq: 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic(center: &[f32]) -> impl FnMut(&[f32]) -> f32 + '_ {
        move |x| x.iter().zip(center).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
    }

    #[test]
    fn threshold_selects_expected_fraction() {
        let theta: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) / 100.0).collect();
        let h = percentile_threshold(&theta, 0.8);
        let kept = theta.iter().filter(|x| x.abs() <= h).count();
        assert!((kept as f32 / 1000.0 - 0.2).abs() < 0.01);
    }

    #[test]
    fn threshold_survives_nan_poisoned_theta() {
        // regression: the pre-fix partial_cmp(..).unwrap() sort panicked
        // on NaN input; total_cmp must order NaNs into the frozen tail
        let mut theta: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        theta[10] = f32::NAN;
        theta[50] = -f32::NAN;
        let h = percentile_threshold(&theta, 0.8);
        assert!(h.is_finite(), "threshold poisoned: {h}");
        // |x| <= h is false for NaN coords, so the kept set stays close
        // to the clean 20% (the poison shifts the percentile by at most
        // the poison count)
        let kept = theta.iter().filter(|x| x.abs() <= h).count();
        assert!((18..=23).contains(&kept), "kept {kept}");
        // boundary cases still exact under poison
        assert_eq!(percentile_threshold(&theta, 1.0), f32::NEG_INFINITY);
        let all = percentile_threshold(&theta, 0.0);
        // keep-everything returns the largest magnitude; with NaNs
        // sorted last that is NaN — every finite coordinate still fails
        // the |x| <= NaN test closed, so callers see "nothing selected"
        // rather than a crash. Either a finite max or NaN is acceptable;
        // what matters is no panic.
        assert!(all.is_nan() || all >= 100.0);
    }

    #[test]
    fn descends_quadratic_dense() {
        // Theorem-1 stable step size: eta ~ 1/(4 (d + 4) L), d = 64, L = 2
        let center = vec![1.0f32; 64];
        let mut theta = vec![0.0f32; 64];
        let mut opt = ZoStepper::new(1e-3, 1.0 / (4.0 * 68.0 * 2.0), Variant::Sgd);
        let mut loss = quadratic(&center);
        let l0 = loss(&theta);
        for t in 0..4000 {
            opt.step(&mut theta, MaskMode::Dense, (t, 1), &mut loss);
        }
        let l1 = loss(&theta);
        assert!(l1 < 0.2 * l0, "l0={l0} l1={l1}");
    }

    #[test]
    fn diverges_beyond_stable_lr_but_sparse_survives() {
        // The Fig-2a mechanism on a controlled objective: a step size that
        // blows up dense ZO is tamed by masking to a small subnetwork
        // (d_hat << d lowers the variance of g*z).
        let center = vec![1.0f32; 64];
        let lr = 0.02; // far above 1/(4(d+4)L)
        let mut dense = vec![0.0f32; 64];
        let mut opt = ZoStepper::new(1e-3, lr, Variant::Sgd);
        let mut loss = quadratic(&center);
        for t in 0..500 {
            opt.step(&mut dense, MaskMode::Dense, (t, 1), &mut loss);
        }
        let dense_loss = loss(&dense);

        // sparse: only 25% of coordinates active per step
        let mut sparse = vec![0.0f32; 64];
        let mut opt2 = ZoStepper::new(1e-3, lr, Variant::Sgd);
        for t in 0..500 {
            opt2.step(
                &mut sparse,
                MaskMode::Random { keep_prob: 0.25, mask_seed: t },
                (t, 2),
                &mut loss,
            );
        }
        let sparse_loss = loss(&sparse);
        assert!(
            sparse_loss < dense_loss,
            "sparse {sparse_loss} should beat dense {dense_loss} at lr {lr}"
        );
        assert!(sparse_loss < 64.0, "sparse arm should not diverge: {sparse_loss}");
    }

    #[test]
    fn sparse_only_moves_masked() {
        let mut theta: Vec<f32> = (0..100).map(|i| if i % 2 == 0 { 0.01 } else { 5.0 }).collect();
        let before = theta.clone();
        let mut opt = ZoStepper::new(1e-3, 0.01, Variant::Sgd);
        let center = vec![1.0f32; 100];
        let mut loss = quadratic(&center);
        opt.step(&mut theta, MaskMode::Magnitude { threshold: 1.0 }, (7, 7), &mut loss);
        for i in 0..100 {
            if before[i].abs() > 1.0 {
                assert_eq!(theta[i], before[i], "large coord {i} moved");
            }
        }
        assert_ne!(theta, before);
    }

    #[test]
    fn seed_replay_restores_exactly_on_zero_lr() {
        // with lr = 0 the step must leave theta EXACTLY unchanged:
        // the +eps / -2eps / +eps walk must cancel bit-for-bit
        let mut theta: Vec<f32> = (0..257).map(|i| (i as f32).sin()).collect();
        let before = theta.clone();
        let mut opt = ZoStepper::new(1e-3, 0.0, Variant::Sgd);
        let center = vec![0.0f32; 257];
        let mut loss = quadratic(&center);
        opt.step(&mut theta, MaskMode::Dense, (3, 9), &mut loss);
        for i in 0..theta.len() {
            assert!(
                (theta[i] - before[i]).abs() <= 2e-6 * before[i].abs().max(1.0),
                "coord {i}: {} vs {}",
                theta[i],
                before[i]
            );
        }
    }

    #[test]
    fn conservative_never_worsens() {
        let center = vec![2.0f32; 32];
        let mut theta = vec![0.0f32; 32];
        // absurd lr: plain SGD would explode, Conservative must survive
        let mut opt = ZoStepper::new(1e-3, 50.0, Variant::Conservative);
        let mut loss = quadratic(&center);
        let mut prev = loss(&theta);
        for t in 0..50 {
            opt.step(&mut theta, MaskMode::Dense, (t, 2), &mut loss);
            let cur = loss(&theta);
            assert!(cur <= prev * 1.001, "step {t}: {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn sign_moves_by_lr() {
        let center = vec![1.0f32; 16];
        let mut theta = vec![0.0f32; 16];
        let before = theta.clone();
        let mut opt = ZoStepper::new(1e-3, 0.01, Variant::Sign);
        let mut loss = quadratic(&center);
        opt.step(&mut theta, MaskMode::Dense, (1, 1), &mut loss);
        for i in 0..16 {
            let d = (theta[i] - before[i]).abs();
            assert!(d == 0.0 || (d - 0.01).abs() < 1e-6);
        }
    }

    #[test]
    fn random_mask_deterministic_in_seed() {
        let theta = vec![1.0f32; 1000];
        let m1 = MaskMode::Random { keep_prob: 0.3, mask_seed: 5 }.mask_vec(&theta);
        let m2 = MaskMode::Random { keep_prob: 0.3, mask_seed: 5 }.mask_vec(&theta);
        let m3 = MaskMode::Random { keep_prob: 0.3, mask_seed: 6 }.mask_vec(&theta);
        assert_eq!(m1, m2);
        assert_ne!(m1, m3);
        let frac = m1.iter().sum::<f32>() / 1000.0;
        assert!((frac - 0.3).abs() < 0.05);
    }

    #[test]
    fn estimate_matches_step_direction() {
        let center = vec![1.0f32; 32];
        let mut theta = vec![0.0f32; 32];
        let opt = ZoStepper::new(1e-3, 0.01, Variant::Sgd);
        let mut loss = quadratic(&center);
        let (grad, info) = opt.estimate(&mut theta, MaskMode::Dense, (9, 9), &mut loss);
        assert_eq!(grad.len(), 32);
        // gradient estimate should correlate with the true gradient 2(x-c)
        let true_grad: Vec<f32> = theta.iter().zip(&center).map(|(a, b)| 2.0 * (a - b)).collect();
        let dot: f32 = grad.iter().zip(&true_grad).map(|(a, b)| a * b).sum();
        assert!(dot > 0.0, "estimate anti-correlated: dot={dot}, info={info:?}");
    }
}
