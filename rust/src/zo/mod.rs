//! Pure-Rust ZO optimization substrate.
//!
//! A dependency-free mirror of the paper's optimizer family operating on
//! plain `Vec<f32>` parameters with caller-supplied loss functions. It
//! exists for three reasons:
//!
//! 1. **Property-based testing** — coordinator/optimizer invariants
//!    (mask support, seed replay, sparsity-0 degeneracy, descent on
//!    quadratics, Theorem-1 scaling) are checked over thousands of random
//!    instances without paying PJRT startup (see `tests/properties.rs`).
//! 2. **Cross-check** — the update rule here and the L2 JAX step use the
//!    *same counter PRNG*, so a rust-side step on a toy objective can be
//!    compared against golden values.
//! 3. **Baseline comparator substrate** — the paper's Fig. 2 noise
//!    analysis is replicated on a controlled quadratic where the true
//!    gradient is known exactly (`analysis` module).

pub mod analysis;
pub mod mlp;
pub mod optim;

/// Mask modes matching `python/compile/optimizers.py::flat_mask`.
///
/// # Examples
/// ```
/// use sparse_mezo::zo::MaskMode;
/// let theta = [0.1f32, 5.0, -0.2, -8.0];
/// // S-MeZO selects the small-magnitude coordinates...
/// let small = MaskMode::Magnitude { threshold: 1.0 };
/// assert_eq!(small.mask_vec(&theta), vec![1.0, 0.0, 1.0, 0.0]);
/// // ...the Fig-2c contrast arm selects the complement...
/// let large = MaskMode::LargeOnly { threshold: 1.0 };
/// assert_eq!(large.mask_vec(&theta), vec![0.0, 1.0, 0.0, 1.0]);
/// // ...and MeZO perturbs everything.
/// assert_eq!(MaskMode::Dense.mask_vec(&theta), vec![1.0; 4]);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MaskMode {
    /// MeZO: every coordinate perturbed.
    Dense,
    /// S-MeZO: |theta_i| <= h (small weights selected). Threshold from
    /// [`optim::percentile_threshold`].
    Magnitude { threshold: f32 },
    /// S-MeZO inverted (Fig. 2c "large weights" arm).
    LargeOnly { threshold: f32 },
    /// R-MeZO: Bernoulli(keep_prob) keyed on (mask_seed, index).
    Random { keep_prob: f32, mask_seed: u32 },
}

impl MaskMode {
    /// Mask value for coordinate `i` of `theta`.
    #[inline]
    pub fn mask(&self, theta: &[f32], i: usize) -> f32 {
        match self {
            MaskMode::Dense => 1.0,
            MaskMode::Magnitude { threshold } => {
                if theta[i].abs() <= *threshold {
                    1.0
                } else {
                    0.0
                }
            }
            MaskMode::LargeOnly { threshold } => {
                if theta[i].abs() > *threshold {
                    1.0
                } else {
                    0.0
                }
            }
            MaskMode::Random { keep_prob, mask_seed } => {
                let key = crate::util::prng::layer_key(*mask_seed, 0x52, 0);
                if crate::util::prng::uniform01(key, i as u32) < *keep_prob {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// The full mask vector for `theta` (1.0 = perturbed/updated).
    pub fn mask_vec(&self, theta: &[f32]) -> Vec<f32> {
        (0..theta.len()).map(|i| self.mask(theta, i)).collect()
    }
}
