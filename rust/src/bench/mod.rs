//! Bench harness (criterion replacement): warmup + timed iterations with
//! mean/p50/p99 reporting and JSON output. Used by the `rust/benches/*`
//! targets (all `harness = false`).

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// bench case name
    pub name: String,
    /// timed iterations
    pub iters: usize,
    /// per-iteration seconds
    pub summary: Summary,
}

impl BenchResult {
    /// One-line human-readable report row.
    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>6} iters  mean {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            self.iters,
            fmt_secs(self.summary.mean),
            fmt_secs(self.summary.p50),
            fmt_secs(self.summary.p99),
        )
    }

    /// JSON record for `bench_results/*.jsonl`.
    pub fn json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.summary.mean)),
            ("p50_s", Json::Num(self.summary.p50)),
            ("p99_s", Json::Num(self.summary.p99)),
            ("min_s", Json::Num(self.summary.min)),
            ("max_s", Json::Num(self.summary.max)),
        ])
    }
}

/// Human-readable seconds (ns/µs/ms/s autoscale).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult { name: name.to_string(), iters, summary: summarize(&samples) };
    println!("{}", r.report());
    r
}

/// Auto-select an iteration count targeting ~`budget_s` seconds total.
pub fn bench_auto<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // one probe call to estimate cost
    let t0 = Instant::now();
    f();
    let per = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / per) as usize).clamp(5, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Write a set of results to `bench_results/<file>.jsonl`.
pub fn write_results(file: &str, results: &[BenchResult]) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{file}.jsonl"));
    if let Ok(mut w) = crate::util::log::JsonlWriter::create(&path) {
        for r in results {
            let _ = w.write(&r.json());
        }
        let _ = w.flush();
    }
    println!("(bench results -> {})", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 2, 50, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 50);
        assert!(r.summary.mean >= 0.0);
        assert!(r.summary.p99 >= r.summary.p50);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(3e-9).contains("ns"));
        assert!(fmt_secs(3e-6).contains("µs"));
        assert!(fmt_secs(3e-3).contains("ms"));
        assert!(fmt_secs(3.0).contains(" s"));
    }
}
