//! The parallel execution layer: one scheduler, three workloads.
//!
//! * [`pool`] — the persistent [`WorkerPool`](pool::WorkerPool):
//!   crossbeam-style MPMC task queue over `std` primitives, scoped
//!   borrows, caller participation (nesting-safe), panic transparency.
//!   Sweep grid cells, DP replica phases and eval shards all schedule
//!   through it — there is no other thread fan-out in the crate.
//! * [`dp`] — the seed-sync data-parallel trainer
//!   ([`DpTrainer`](dp::DpTrainer)): N parameter replicas, two forward
//!   passes per microbatch shard, an all-reduce of *per-row losses* into
//!   one projected-gradient scalar, and the identical masked update
//!   applied locally from the shared seed. Bytes exchanged per step:
//!   one `(seed, g)` pair — never a parameter.
//! * [`eval`] — sharded evaluation over the pool, bit-identical to the
//!   serial evaluator by a canonical batch-order fold.
//! * [`protocol`] — the `(step, seed, g, mask_epoch)` step-exchange
//!   record, its JSONL journal, and the forward-pass-free
//!   [`replay`](protocol::replay) used for crash recovery, audit, and
//!   (via [`replay_full`](protocol::replay_full)'s mask-union
//!   certificate) sparse-adapter materialization in [`crate::serve`].
//! * [`transport`] — the same step exchange over TCP: a length-prefixed
//!   binary frame codec, a coordinator-side [`WorkerHub`](transport::WorkerHub)
//!   leasing parked `worker` processes per slice, and the
//!   [`run_worker`](transport::run_worker) remote-replica loop. The
//!   journal stays authoritative; remote state is rebuilt from catch-up
//!   replay at every lease.
//!
//! Why this shape works: MeZO's update is a rank-one function of a
//! scalar and a PRNG seed (paper Alg. 1–2), so the classic DP cost —
//! shipping gradients or averaged parameters — vanishes. The engine
//! exploits that to keep N workers bit-identical to the 1-worker (and
//! serial-trainer) trajectory, which `tests/parallel.rs` asserts
//! bit-for-bit — and [`transport`] extends the same bit-identity across
//! machine boundaries at a few dozen bytes per step.

pub mod dp;
pub mod eval;
pub mod pool;
pub mod protocol;
pub mod transport;

pub use dp::{DpTrainer, SliceReport, SliceState};
pub use pool::WorkerPool;
pub use transport::{
    is_worker_lost, run_worker, RemoteHandle, WorkerHub, WorkerOpts, WorkerStats,
};
