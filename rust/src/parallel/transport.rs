//! TCP transport for the seed-sync step-exchange protocol.
//!
//! A MeZO step crosses a machine boundary as a few dozen bytes: the
//! coordinator streams `(step, seed)` assignments, each remote worker
//! answers with its microbatch shard's per-row f64 losses, and the
//! committed [`StepRecord`] broadcast closes the step — no parameter or
//! gradient ever rides the wire (the paper's Alg.-2 shared-seed
//! structure made a network protocol). Placement is invisible to the
//! arithmetic: the coordinator folds local and remote row losses in
//! canonical shard order, so a run with any mix of local and TCP
//! replicas stays bit-identical to the serial trainer.
//!
//! Wire format: length-prefixed binary frames, `[u32 LE body_len]
//! [u8 tag][payload]` where `body_len` counts the tag byte. Scalars
//! travel as their IEEE-754 bits ([`f32::to_bits`]/[`f64::to_bits`],
//! little-endian), so `-0.0`, subnormals and extreme magnitudes
//! round-trip bit-exactly — the same exactness contract the JSON
//! journal keeps. [`decode_frame`] never panics on arbitrary bytes:
//! it returns `Ok(None)` when the buffer is a clean prefix (need more
//! bytes) and a hard error for anything malformed; a length prefix
//! above [`MAX_FRAME_BYTES`] is refused *before any allocation*,
//! mirroring the HTTP layer's `MAX_BODY_BYTES` 413 precedent.
//!
//! Fault model: the journal stays the single authoritative state. A
//! worker session carries no durable state — it is rebuilt from the
//! journal's catch-up stream at every lease — so a worker dying
//! mid-slice surfaces as a [`worker_lost`]-tagged error, the scheduler
//! re-queues the job, and the next slice resumes from journal replay
//! bit-identically (with the dead stream dropped from the hub, so a
//! shrinking worker set degrades to local compute, never to a wedge).
//! A mismatched parameter base is a hard error at connect time: the
//! handshake exchanges the journal header's `init_fnv` fingerprint in
//! both directions and either side refuses on mismatch.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::batcher::TrainLoader;
use crate::data::tasks;
use crate::runtime::Runtime;
use crate::util::json::Json;

use super::dp::{apply_update, dp_rule, dp_slot_len, perturb_in_place};
use super::pool::WorkerPool;
use super::protocol::{self, params_fingerprint, StepRecord};

/// Wire protocol version; bumped on any frame-layout change (the
/// golden fixture in `tests/golden.rs` makes a silent change impossible).
///
/// v2 appended a trailing `trace` u64 to `Welcome` and `Step` frames.
/// The field is version-gated at decode: a body that ends where v1
/// ended still parses (trace = 0), so pre-v2 fixture bytes stay
/// decode-clean (`tests/golden.rs::pre_v2_fixture_bytes_still_decode`).
pub const PROTOCOL_VERSION: u32 = 2;

/// Hard cap on one frame's body. The largest legitimate frame is a
/// `Losses` pair for a full batch (a few KiB) or a `Config` header line;
/// 1 MiB is orders of magnitude above both. A length prefix beyond this
/// is refused before any buffer is sized from it — attacker-controlled
/// bytes must not pick our allocation size (the `MAX_BODY_BYTES`
/// precedent from `serve/http.rs`).
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Marker every transport-failure error message carries, so the jobs
/// scheduler can classify "the remote died, re-queue and resume from
/// the journal" apart from real training errors. String-matched through
/// the error chain (the vendored `anyhow` has no downcasting).
pub const WORKER_LOST: &str = "remote worker lost";

/// Marker for worker-side errors that must kill the worker process
/// (base-fingerprint mismatch, protocol violation, injected kill) —
/// as opposed to a coordinator-side slice failure, which the worker
/// survives by reconnecting.
const WORKER_FATAL: &str = "worker hard error";

/// Read timeout on a leased session: a hung remote must surface as a
/// re-queueable [`worker_lost`] error, not wedge the scheduler forever.
const SESSION_TIMEOUT: Duration = Duration::from_secs(120);

/// Wrap a transport failure in the [`WORKER_LOST`] marker.
pub fn worker_lost(detail: impl std::fmt::Display) -> anyhow::Error {
    crate::obs::counter("transport_worker_lost_total", &[]).inc();
    anyhow!("{WORKER_LOST}: {detail}")
}

/// Whether an error (anywhere in its context chain) is a lost-worker
/// transport failure — the scheduler's re-queue trigger.
pub fn is_worker_lost(e: &anyhow::Error) -> bool {
    e.chain().any(|s| s.contains(WORKER_LOST))
}

fn fatal(detail: impl std::fmt::Display) -> anyhow::Error {
    anyhow!("{WORKER_FATAL}: {detail}")
}

fn is_fatal(e: &anyhow::Error) -> bool {
    e.chain().any(|s| s.contains(WORKER_FATAL))
}

/// FNV-1a fingerprint of a training split (prompt tokens, label,
/// candidates — the bytes the loader's batches are built from), matching
/// [`params_fingerprint`]'s hash and hex shape. Both handshake sides
/// compute this over their own copy so a worker that regenerated a
/// different dataset is refused at connect time.
pub fn train_fingerprint(train: &[crate::data::Example]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: i32| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    for ex in train {
        eat(ex.prompt.len() as i32);
        for &t in &ex.prompt {
            eat(t);
        }
        eat(ex.label);
        eat(ex.candidates.len() as i32);
        for &c in &ex.candidates {
            eat(c);
        }
    }
    format!("{h:016x}")
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// One protocol frame. Lifecycle of a session (one journal lease):
///
/// ```text
/// coordinator                                worker
///   Config{header, data_seed}  ->
///                              <-  Hello{init_fnv}      (or Abort)
///   Welcome{rank, n, resume}   ->
///   Step x resume (catch-up)   ->
///   [ Refresh{epoch}?          ->
///     PhaseA{step, seed}       ->
///                              <-  Losses{plus, minus}
///     Step{record}             ->                        ] x steps
///   Finish{steps, final_fnv}   ->
///                              <-  FinishAck{final_fnv}
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Session start (coordinator → worker): the run's journal header
    /// (self-describing config + `init_fnv`) plus the dataset seed the
    /// header does not carry.
    Config {
        /// wire protocol version (mismatch aborts the handshake)
        version: u32,
        /// the journal header line, verbatim JSON
        header: String,
        /// seed the worker regenerates the task dataset from
        data_seed: u64,
    },
    /// Worker's reply: its locally-resolved base and dataset
    /// fingerprints. The coordinator cross-checks both — the base
    /// against the header's `init_fnv`, the dataset against its own
    /// training split. The dataset check matters because a worker on a
    /// different dataset would *not* drift (every replica applies the
    /// same committed records): it would silently bend the trajectory
    /// away from the serial run instead, so it must be refused up front.
    Hello {
        /// wire protocol version
        version: u32,
        /// FNV-1a fingerprint of the worker's base parameters (hex)
        init_fnv: String,
        /// FNV-1a fingerprint of the regenerated training split (hex)
        ds_fnv: String,
    },
    /// Handshake accepted: the worker owns microbatch shard `rank` of
    /// `workers`, and must first replay `resume` catch-up [`Frame::Step`]s.
    Welcome {
        /// this worker's shard index
        rank: u32,
        /// total shard count (the run's `workers`)
        workers: u32,
        /// catch-up step records that follow immediately
        resume: u32,
        /// the job's trace id (0 = none): the worker adopts it as its
        /// [`crate::obs::trace_scope`] so both processes' `SMEZO_TRACE`
        /// streams stitch on one value. Version-gated (absent on v1
        /// bytes, decoded as 0).
        trace: u64,
    },
    /// Threshold refresh (coordinator → worker): recompute §8.2
    /// magnitude thresholds from the current (unperturbed) params.
    Refresh {
        /// the new threshold generation
        mask_epoch: u32,
    },
    /// Phase A assignment: score microbatch shard `rank` at `+eps` and
    /// `-eps` for this step's shared seed.
    PhaseA {
        /// optimizer step index
        step: u32,
        /// the step's shared perturbation seed
        seed: (u32, u32),
        /// threshold generation the mask must be computed under
        /// (sanity-checked against the worker's — a skew here would
        /// silently compute a wrong mask)
        mask_epoch: u32,
    },
    /// The worker's shard row losses for both phases (worker → coordinator).
    Losses {
        /// the step these losses belong to
        step: u32,
        /// per-row f64 losses at `+eps`, shard row order
        plus: Vec<f64>,
        /// per-row f64 losses at `-eps`, shard row order
        minus: Vec<f64>,
    },
    /// A committed step record: catch-up replay during the handshake,
    /// phase-B commit during the live loop. The second field is the
    /// job's trace id (0 = none), version-gated like
    /// [`Frame::Welcome`]'s — the [`StepRecord`] itself is untouched,
    /// so journal bytes stay byte-identical to pre-v2 runs.
    Step(StepRecord, u64),
    /// Session end (coordinator → worker) with the final parameter
    /// fingerprint — the cross-machine drift check.
    Finish {
        /// total steps the session's state now reflects
        steps: u32,
        /// coordinator-side fingerprint of the final params
        final_fnv: String,
    },
    /// Worker's drift-check echo.
    FinishAck {
        /// worker-side fingerprint of its final params
        final_fnv: String,
    },
    /// Hard protocol error, either direction; the connection closes.
    Abort {
        /// human-readable reason
        reason: String,
    },
}

const TAG_CONFIG: u8 = 1;
const TAG_HELLO: u8 = 2;
const TAG_WELCOME: u8 = 3;
const TAG_REFRESH: u8 = 4;
const TAG_PHASE_A: u8 = 5;
const TAG_LOSSES: u8 = 6;
const TAG_STEP: u8 = 7;
const TAG_FINISH: u8 = 8;
const TAG_FINISH_ACK: u8 = 9;
const TAG_ABORT: u8 = 10;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Encode one frame: `[u32 LE body_len][u8 tag][payload]`.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::with_capacity(32);
    match frame {
        Frame::Config { version, header, data_seed } => {
            body.push(TAG_CONFIG);
            put_u32(&mut body, *version);
            put_str(&mut body, header);
            put_u64(&mut body, *data_seed);
        }
        Frame::Hello { version, init_fnv, ds_fnv } => {
            body.push(TAG_HELLO);
            put_u32(&mut body, *version);
            put_str(&mut body, init_fnv);
            put_str(&mut body, ds_fnv);
        }
        Frame::Welcome { rank, workers, resume, trace } => {
            body.push(TAG_WELCOME);
            put_u32(&mut body, *rank);
            put_u32(&mut body, *workers);
            put_u32(&mut body, *resume);
            put_u64(&mut body, *trace);
        }
        Frame::Refresh { mask_epoch } => {
            body.push(TAG_REFRESH);
            put_u32(&mut body, *mask_epoch);
        }
        Frame::PhaseA { step, seed, mask_epoch } => {
            body.push(TAG_PHASE_A);
            put_u32(&mut body, *step);
            put_u32(&mut body, seed.0);
            put_u32(&mut body, seed.1);
            put_u32(&mut body, *mask_epoch);
        }
        Frame::Losses { step, plus, minus } => {
            body.push(TAG_LOSSES);
            put_u32(&mut body, *step);
            put_f64s(&mut body, plus);
            put_f64s(&mut body, minus);
        }
        Frame::Step(rec, trace) => {
            body.push(TAG_STEP);
            put_u32(&mut body, rec.step);
            put_u32(&mut body, rec.seed.0);
            put_u32(&mut body, rec.seed.1);
            put_u32(&mut body, rec.scalar.to_bits());
            put_u32(&mut body, rec.mask_epoch);
            put_u64(&mut body, *trace);
        }
        Frame::Finish { steps, final_fnv } => {
            body.push(TAG_FINISH);
            put_u32(&mut body, *steps);
            put_str(&mut body, final_fnv);
        }
        Frame::FinishAck { final_fnv } => {
            body.push(TAG_FINISH_ACK);
            put_str(&mut body, final_fnv);
        }
        Frame::Abort { reason } => {
            body.push(TAG_ABORT);
            put_str(&mut body, reason);
        }
    }
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Bounds-checked reader over a frame body. Every `take_*` is a clean
/// error past the end — decoding arbitrary bytes must never panic.
struct BodyReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BodyReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("frame body truncated: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn take_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn take_str(&mut self) -> Result<String> {
        let len = self.take_u32()? as usize;
        // the frame cap already bounds len transitively, but check
        // against the remaining body before trusting it
        let bytes = self.take(len).context("string field")?;
        Ok(std::str::from_utf8(bytes).context("string field not UTF-8")?.to_string())
    }

    fn take_f64s(&mut self) -> Result<Vec<f64>> {
        let count = self.take_u32()? as usize;
        // refuse a count that cannot fit in the remaining body before
        // allocating for it
        if self.buf.len() - self.pos < count.saturating_mul(8) {
            bail!("f64 array count {count} exceeds frame body");
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(f64::from_bits(u64::from_le_bytes(self.take(8)?.try_into().unwrap())));
        }
        Ok(out)
    }

    /// Bytes left in the body — the version gate for trailing fields
    /// appended after v1 (present: read them; absent: default).
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("frame has {} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

/// Decode one frame from the front of `buf`.
///
/// * `Ok(Some((frame, consumed)))` — a complete frame; the caller drops
///   `consumed` bytes.
/// * `Ok(None)` — `buf` is a clean prefix; read more bytes.
/// * `Err(_)` — malformed or hostile input (oversized length prefix,
///   unknown tag, truncated or over-long body, bad UTF-8). Never panics.
pub fn decode_frame(buf: &[u8]) -> Result<Option<(Frame, usize)>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let body_len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if body_len > MAX_FRAME_BYTES {
        // refused before any allocation is sized from it
        bail!("frame length {body_len} exceeds cap {MAX_FRAME_BYTES}");
    }
    if body_len == 0 {
        bail!("empty frame (no tag byte)");
    }
    if buf.len() < 4 + body_len {
        return Ok(None);
    }
    let body = &buf[4..4 + body_len];
    let tag = body[0];
    let mut r = BodyReader { buf: &body[1..], pos: 0 };
    let frame = match tag {
        TAG_CONFIG => Frame::Config {
            version: r.take_u32()?,
            header: r.take_str()?,
            data_seed: r.take_u64()?,
        },
        TAG_HELLO => Frame::Hello {
            version: r.take_u32()?,
            init_fnv: r.take_str()?,
            ds_fnv: r.take_str()?,
        },
        TAG_WELCOME => Frame::Welcome {
            rank: r.take_u32()?,
            workers: r.take_u32()?,
            resume: r.take_u32()?,
            // v2 field: absent on v1 bytes (a body ending here), so old
            // frames keep decoding — 1..7 trailing bytes still error
            trace: if r.remaining() > 0 { r.take_u64()? } else { 0 },
        },
        TAG_REFRESH => Frame::Refresh { mask_epoch: r.take_u32()? },
        TAG_PHASE_A => Frame::PhaseA {
            step: r.take_u32()?,
            seed: (r.take_u32()?, r.take_u32()?),
            mask_epoch: r.take_u32()?,
        },
        TAG_LOSSES => Frame::Losses {
            step: r.take_u32()?,
            plus: r.take_f64s()?,
            minus: r.take_f64s()?,
        },
        TAG_STEP => {
            let rec = StepRecord {
                step: r.take_u32()?,
                seed: (r.take_u32()?, r.take_u32()?),
                scalar: f32::from_bits(r.take_u32()?),
                mask_epoch: r.take_u32()?,
            };
            // v2 trace id, version-gated exactly like Welcome's
            let trace = if r.remaining() > 0 { r.take_u64()? } else { 0 };
            Frame::Step(rec, trace)
        }
        TAG_FINISH => Frame::Finish { steps: r.take_u32()?, final_fnv: r.take_str()? },
        TAG_FINISH_ACK => Frame::FinishAck { final_fnv: r.take_str()? },
        TAG_ABORT => Frame::Abort { reason: r.take_str()? },
        other => bail!("unknown frame tag {other}"),
    };
    r.finish()?;
    Ok(Some((frame, 4 + body_len)))
}

// ---------------------------------------------------------------------------
// framed connection
// ---------------------------------------------------------------------------

/// A TCP stream with frame-level send/recv and a decode buffer.
///
/// Every conn caches its `transport_frames_total` / `transport_bytes_total`
/// counter handles at construction so the per-frame accounting touches
/// only lock-free atomics, never the registry lock.
pub struct FrameConn {
    stream: TcpStream,
    pending: Vec<u8>,
    sent_frames: Arc<crate::obs::Counter>,
    sent_bytes: Arc<crate::obs::Counter>,
    recv_frames: Arc<crate::obs::Counter>,
    recv_bytes: Arc<crate::obs::Counter>,
}

impl FrameConn {
    /// Wrap a connected stream (Nagle off: frames are latency-bound).
    pub fn new(stream: TcpStream) -> FrameConn {
        let _ = stream.set_nodelay(true);
        FrameConn {
            stream,
            pending: Vec::new(),
            sent_frames: crate::obs::counter("transport_frames_total", &[("dir", "sent")]),
            sent_bytes: crate::obs::counter("transport_bytes_total", &[("dir", "sent")]),
            recv_frames: crate::obs::counter("transport_frames_total", &[("dir", "recv")]),
            recv_bytes: crate::obs::counter("transport_bytes_total", &[("dir", "recv")]),
        }
    }

    /// Apply a read timeout (leased coordinator-side sessions; `None`
    /// blocks forever, the idle worker's default).
    fn set_timeout(&self, t: Option<Duration>) {
        let _ = self.stream.set_read_timeout(t);
    }

    /// Send one frame.
    pub fn send(&mut self, frame: &Frame) -> Result<()> {
        let buf = encode_frame(frame);
        self.stream.write_all(&buf).context("writing frame")?;
        self.sent_frames.inc();
        self.sent_bytes.add(buf.len() as u64);
        Ok(())
    }

    /// Receive one frame; `Ok(None)` is a clean EOF *between* frames
    /// (the peer closed an idle connection).
    pub fn recv_opt(&mut self) -> Result<Option<Frame>> {
        loop {
            if let Some((frame, used)) = decode_frame(&self.pending)? {
                self.pending.drain(..used);
                self.recv_frames.inc();
                self.recv_bytes.add(used as u64);
                return Ok(Some(frame));
            }
            let mut chunk = [0u8; 16 * 1024];
            let n = self.stream.read(&mut chunk).context("reading frame")?;
            if n == 0 {
                if self.pending.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-frame ({} buffered bytes)", self.pending.len());
            }
            self.pending.extend_from_slice(&chunk[..n]);
        }
    }

    /// Receive one frame; any EOF is an error.
    pub fn recv(&mut self) -> Result<Frame> {
        self.recv_opt()?.ok_or_else(|| anyhow!("connection closed"))
    }
}

// ---------------------------------------------------------------------------
// coordinator side: hub + leased sessions
// ---------------------------------------------------------------------------

struct HubInner {
    parked: Mutex<Vec<FrameConn>>,
    leased: AtomicUsize,
    sessions_served: AtomicUsize,
    stop: AtomicBool,
}

/// The coordinator's worker pool: a TCP listener parking connected
/// `worker` processes until a slice leases them. Connections carry no
/// state between leases — every lease re-handshakes and streams journal
/// catch-up, so the journal stays the only authority.
pub struct WorkerHub {
    inner: Arc<HubInner>,
    addr: SocketAddr,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl WorkerHub {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and start parking workers.
    pub fn listen(addr: &str) -> Result<Arc<WorkerHub>> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding worker listener on {addr}"))?;
        let local = listener.local_addr()?;
        let inner = Arc::new(HubInner {
            parked: Mutex::new(Vec::new()),
            leased: AtomicUsize::new(0),
            sessions_served: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
        });
        let accept = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new().name("smz-worker-accept".into()).spawn(move || {
                for stream in listener.incoming() {
                    if inner.stop.load(Ordering::Acquire) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let peer =
                                s.peer_addr().map(|a| a.to_string()).unwrap_or_default();
                            crate::info!("[transport] worker connected from {peer}");
                            inner.parked.lock().unwrap().push(FrameConn::new(s));
                        }
                        Err(e) => crate::debug!("[transport] accept error: {e}"),
                    }
                }
            })?
        };
        Ok(Arc::new(WorkerHub { inner, addr: local, accept: Mutex::new(Some(accept)) }))
    }

    /// The bound listener address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Workers currently attached (parked + mid-lease) — the healthz
    /// `workers_connected` gauge.
    pub fn connected(&self) -> usize {
        self.inner.parked.lock().unwrap().len() + self.inner.leased.load(Ordering::Acquire)
    }

    /// Successful session handshakes served so far (tests assert remote
    /// participation with this, not by trusting placement).
    pub fn sessions_served(&self) -> usize {
        self.inner.sessions_served.load(Ordering::Acquire)
    }

    /// Block until at least `n` workers are attached (the deterministic
    /// start for CI smokes and `--min-workers`); false on timeout.
    pub fn wait_for_workers(&self, n: usize, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.connected() < n {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        true
    }

    /// Lease up to `want` remote sessions for one slice of the run
    /// described by `header`: handshake each parked connection, verify
    /// the base and dataset fingerprints, assign descending shard
    /// ranks `workers-1, workers-2, ..` and stream the journal catch-up.
    ///
    /// Infallible by design: a connection that fails the handshake
    /// (died while parked, version or fingerprint mismatch) is logged
    /// and dropped, never fatal — the slice proceeds with fewer (or
    /// zero) remotes and stays bit-identical either way.
    #[allow(clippy::too_many_arguments)]
    pub fn lease(
        self: &Arc<Self>,
        want: usize,
        workers: usize,
        header: &Json,
        data_seed: u64,
        ds_fnv: &str,
        records: &[StepRecord],
        trace: u64,
    ) -> Vec<RemoteWorker> {
        let header_line = header.to_string();
        let want_fnv = header.get("init_fnv").and_then(|v| v.as_str().ok()).unwrap_or("");
        let mut sessions: Vec<RemoteWorker> = Vec::new();
        while sessions.len() < want.min(workers) {
            let Some(mut conn) = self.inner.parked.lock().unwrap().pop() else {
                break;
            };
            conn.set_timeout(Some(SESSION_TIMEOUT));
            let rank = workers - 1 - sessions.len();
            match handshake(
                &mut conn, &header_line, want_fnv, data_seed, ds_fnv, rank, workers, records,
                trace,
            ) {
                Ok(()) => {
                    self.inner.leased.fetch_add(1, Ordering::AcqRel);
                    self.inner.sessions_served.fetch_add(1, Ordering::AcqRel);
                    crate::obs::counter("transport_handshakes_total", &[]).inc();
                    crate::obs::counter("transport_leases_total", &[]).inc();
                    sessions.push(RemoteWorker {
                        conn: Some(conn),
                        rank,
                        hub: Arc::clone(&self.inner),
                    });
                }
                Err(e) => {
                    // the worker may still be readable enough to see why
                    let _ = conn.send(&Frame::Abort { reason: format!("{e:#}") });
                    crate::info!("[transport] dropping worker (handshake failed: {e:#})");
                }
            }
        }
        sessions
    }

    /// Stop accepting and drop every parked connection (workers see a
    /// clean EOF and exit).
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Release);
        // poke the blocking accept loop awake
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
        self.inner.parked.lock().unwrap().clear();
    }
}

impl Drop for WorkerHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Coordinator side of one leased handshake (see [`Frame`] lifecycle).
#[allow(clippy::too_many_arguments)]
fn handshake(
    conn: &mut FrameConn,
    header_line: &str,
    want_fnv: &str,
    data_seed: u64,
    want_ds: &str,
    rank: usize,
    workers: usize,
    records: &[StepRecord],
    trace: u64,
) -> Result<()> {
    conn.send(&Frame::Config {
        version: PROTOCOL_VERSION,
        header: header_line.to_string(),
        data_seed,
    })?;
    match conn.recv()? {
        Frame::Hello { version, init_fnv, ds_fnv } => {
            if version != PROTOCOL_VERSION {
                bail!("worker speaks protocol v{version}, coordinator v{PROTOCOL_VERSION}");
            }
            if !want_fnv.is_empty() && init_fnv != want_fnv {
                bail!(
                    "worker base fingerprint {init_fnv} does not match the run's \
                     init_fnv {want_fnv} — its --seed/--init-from resolve a different base"
                );
            }
            if !want_ds.is_empty() && ds_fnv != want_ds {
                bail!(
                    "worker dataset fingerprint {ds_fnv} does not match the \
                     coordinator's training split {want_ds} — the worker would not \
                     drift, it would silently bend the trajectory, so it is refused"
                );
            }
        }
        Frame::Abort { reason } => bail!("worker refused the session: {reason}"),
        other => bail!("expected Hello, got {other:?}"),
    }
    conn.send(&Frame::Welcome {
        rank: rank as u32,
        workers: workers as u32,
        resume: records.len() as u32,
        trace,
    })?;
    for rec in records {
        conn.send(&Frame::Step(*rec, trace))?;
    }
    Ok(())
}

/// One leased remote session: a handshaken worker holding shard `rank`,
/// caught up to the journal. Dropping it without [`release`] severs the
/// connection (the failure path); releasing parks it for the next lease.
///
/// [`release`]: RemoteWorker::release
pub struct RemoteWorker {
    conn: Option<FrameConn>,
    /// the microbatch shard this session scores
    pub rank: usize,
    hub: Arc<HubInner>,
}

impl RemoteWorker {
    fn conn(&mut self) -> &mut FrameConn {
        self.conn.as_mut().expect("RemoteWorker used after release")
    }

    /// Send one frame (wrapped as a lost-worker error on failure).
    pub fn send(&mut self, frame: &Frame) -> Result<()> {
        let rank = self.rank;
        self.conn().send(frame).map_err(|e| worker_lost(format!("rank {rank}: {e:#}")))
    }

    /// Await this session's `Losses` for `step`, validating the shard
    /// row count. Any other frame, a short read, or a timeout is a
    /// lost-worker error (the journal makes the retry exact, so the
    /// caller re-queues rather than guessing).
    pub fn recv_losses(&mut self, step: u32, rows: usize) -> Result<(Vec<f64>, Vec<f64>)> {
        // the all-reduce wait: time from asking for shard losses to
        // having them in hand
        let _sp = crate::obs::span("dp.allreduce_wait");
        crate::obs::counter("dp_allreduce_waits_total", &[]).inc();
        let rank = self.rank;
        let lost = |d: String| worker_lost(format!("rank {rank}: {d}"));
        match self.conn().recv().map_err(|e| lost(format!("{e:#}")))? {
            Frame::Losses { step: s, plus, minus } => {
                if s != step {
                    return Err(lost(format!("losses for step {s}, expected {step}")));
                }
                if plus.len() != rows || minus.len() != rows {
                    return Err(lost(format!(
                        "losses carry {}+{} rows, expected {rows}",
                        plus.len(),
                        minus.len()
                    )));
                }
                Ok((plus, minus))
            }
            Frame::Abort { reason } => Err(lost(format!("worker aborted: {reason}"))),
            other => Err(lost(format!("expected Losses, got {other:?}"))),
        }
    }

    /// End the session with the drift check: exchange `Finish` /
    /// `FinishAck` fingerprints of the final parameters. A mismatch is
    /// a **hard** error (seed-sync invariant broken — re-running would
    /// not help); an I/O failure is a plain lost worker (training
    /// already committed, so the slice result stands).
    pub fn finish(mut self, steps: u32, final_fnv: &str) -> Result<()> {
        self.send(&Frame::Finish { steps, final_fnv: final_fnv.to_string() })?;
        let rank = self.rank;
        match self.conn().recv().map_err(|e| worker_lost(format!("rank {rank}: {e:#}")))? {
            Frame::FinishAck { final_fnv: theirs } => {
                if theirs != final_fnv {
                    bail!(
                        "remote replica rank {rank} drifted: final fingerprint {theirs} \
                         vs coordinator {final_fnv} — seed-sync invariant broken"
                    );
                }
            }
            Frame::Abort { reason } => {
                bail!("remote replica rank {rank} refused finish: {reason}")
            }
            other => return Err(worker_lost(format!("rank {rank}: expected FinishAck, got {other:?}"))),
        }
        self.release();
        Ok(())
    }

    /// Park the connection back in the hub for the next lease.
    pub fn release(mut self) {
        if let Some(conn) = self.conn.take() {
            conn.set_timeout(None);
            self.hub.parked.lock().unwrap().push(conn);
        }
        // Drop decrements `leased`
    }
}

impl Drop for RemoteWorker {
    fn drop(&mut self) {
        self.hub.leased.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The coordinator-side knobs [`DpTrainer`](super::DpTrainer) needs to
/// farm shards out: the hub and the dataset seed (the journal header
/// does not carry it — grid cells may train on a different data seed
/// than the run seed).
#[derive(Clone)]
pub struct RemoteHandle {
    /// the hub remote workers are parked in
    pub hub: Arc<WorkerHub>,
    /// seed the workers regenerate the task dataset from; **must**
    /// match the dataset the coordinator trains on (the end-of-slice
    /// fingerprint check catches a mismatch, but loudly and late)
    pub data_seed: u64,
    /// the job's trace id (0 = none), threaded into `Welcome` and
    /// `Step` frames so the worker's trace stream stitches with the
    /// coordinator's
    pub trace_id: u64,
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// `worker` subcommand policy.
pub struct WorkerOpts {
    /// base-init seed — must match the coordinator's serve/drain
    /// `--seed` (the handshake fingerprint makes a mismatch a hard
    /// error, not silent divergence)
    pub seed: u64,
    /// base checkpoint path (takes precedence over `seed`, mirroring
    /// the serve layer's `resolve_serve_base`)
    pub init_from: Option<String>,
    /// how long to retry the initial connect (the coordinator may not
    /// be listening yet)
    pub connect_timeout: Duration,
    /// fault-injection hook: process at most this many `PhaseA` frames,
    /// then die without replying — deterministically simulates a worker
    /// killed mid-slice (tests only; `None` in production)
    pub max_phase_a: Option<usize>,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            seed: 42,
            init_from: None,
            connect_timeout: Duration::from_secs(30),
            max_phase_a: None,
        }
    }
}

/// What a worker run accomplished (logging + test assertions).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerStats {
    /// completed (Finish-acked) sessions
    pub sessions: usize,
    /// live optimizer steps participated in (catch-up replay excluded)
    pub steps: usize,
}

/// How one session ended (worker side).
enum SessionEnd {
    /// clean `Finish`/`FinishAck` exchange; the connection is reusable
    Finished,
    /// the coordinator discarded the slice (cancel, divergence, or a
    /// lost sibling worker); the socket may hold half-exchanged frames,
    /// so the worker reconnects fresh
    Discarded,
}

/// Run a remote DP replica against the coordinator at `addr`
/// (`host:port`): connect (with retry), then serve sessions until the
/// coordinator closes an idle connection. A discarded session (the
/// coordinator cancelled the slice or lost a sibling worker) is
/// survived by reconnecting with a fresh socket — only worker-side hard
/// errors (mismatched base, protocol violation) kill the process, as
/// does losing the coordinator entirely.
pub fn run_worker(
    rt: &Runtime,
    pool: &WorkerPool,
    addr: &str,
    opts: &WorkerOpts,
) -> Result<WorkerStats> {
    let mut bases: BTreeMap<String, Arc<Vec<f32>>> = BTreeMap::new();
    let mut stats = WorkerStats::default();
    let mut phase_a_budget = opts.max_phase_a;
    // a deterministic local failure must not reconnect-loop forever; a
    // session that finishes (or that the coordinator discards on its own
    // initiative) resets the strike count
    let mut strikes = 0usize;
    let mut connects = 0usize;
    'reconnect: loop {
        if connects > 0 {
            crate::obs::counter("transport_reconnects_total", &[]).inc();
        }
        connects += 1;
        let stream = connect_retry(addr, opts.connect_timeout)?;
        let mut conn = FrameConn::new(stream);
        crate::info!("[worker] connected to coordinator {addr}");
        loop {
            // idle between sessions: a clean close here is the
            // coordinator shutting down, not a failure
            let frame = match conn.recv_opt() {
                Ok(None) => return Ok(stats),
                Ok(Some(f)) => f,
                Err(e) => {
                    crate::info!("[worker] connection lost while idle ({e:#}); reconnecting");
                    continue 'reconnect;
                }
            };
            match frame {
                Frame::Config { version, header, data_seed } => {
                    if version != PROTOCOL_VERSION {
                        let reason = format!(
                            "coordinator speaks protocol v{version}, worker v{PROTOCOL_VERSION}"
                        );
                        let _ = conn.send(&Frame::Abort { reason: reason.clone() });
                        return Err(fatal(reason));
                    }
                    match run_session(
                        rt,
                        pool,
                        &mut conn,
                        &header,
                        data_seed,
                        opts,
                        &mut bases,
                        &mut stats,
                        &mut phase_a_budget,
                    ) {
                        Ok(SessionEnd::Finished) => strikes = 0, // socket clean; stay parked
                        Ok(SessionEnd::Discarded) => {
                            strikes = 0;
                            crate::info!("[worker] session discarded; reconnecting");
                            continue 'reconnect;
                        }
                        Err(e) if is_fatal(&e) => return Err(e),
                        Err(e) if strikes >= 7 => {
                            return Err(e.context("8 consecutive failed sessions"));
                        }
                        Err(e) => {
                            // transport failure mid-session: the slice is
                            // the coordinator's to retry from the journal
                            strikes += 1;
                            crate::info!("[worker] session dropped ({e:#}); reconnecting");
                            continue 'reconnect;
                        }
                    }
                }
                Frame::Abort { reason } => {
                    // an idle-time Abort is the handshake rejection path —
                    // hard by design (e.g. the coordinator refused our base)
                    return Err(fatal(format!("coordinator rejected worker: {reason}")));
                }
                other => {
                    let reason = format!("expected Config, got {other:?}");
                    let _ = conn.send(&Frame::Abort { reason: reason.clone() });
                    return Err(fatal(reason));
                }
            }
        }
    }
}

fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if t0.elapsed() < timeout => {
                crate::debug!("[worker] connect {addr}: {e}; retrying");
                std::thread::sleep(Duration::from_millis(200));
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("connecting to coordinator {addr} (waited {timeout:?})")
                })
            }
        }
    }
}

/// One session: handshake (base fingerprint check), catch-up replay,
/// then the live PhaseA/Losses/Step loop until `Finish`. The session's
/// replica state lives only on this stack frame — the journal on the
/// coordinator side stays the single authority.
#[allow(clippy::too_many_arguments)]
fn run_session(
    rt: &Runtime,
    pool: &WorkerPool,
    conn: &mut FrameConn,
    header_line: &str,
    data_seed: u64,
    opts: &WorkerOpts,
    bases: &mut BTreeMap<String, Arc<Vec<f32>>>,
    stats: &mut WorkerStats,
    phase_a_budget: &mut Option<usize>,
) -> Result<SessionEnd> {
    // a protocol violation aborts loudly in both directions and is a
    // hard (process-killing) error on this side
    macro_rules! abort {
        ($($arg:tt)*) => {{
            let reason = format!($($arg)*);
            let _ = conn.send(&Frame::Abort { reason: reason.clone() });
            return Err(fatal(reason));
        }};
    }

    let header = crate::util::json::parse(header_line).context("parsing Config header")?;
    let cfg = protocol::config_from_header(&header)?;
    let Some(rule) = dp_rule(&cfg.optimizer) else {
        abort!("optimizer '{}' is not DP-capable", cfg.optimizer);
    };
    let model = rt.model(&cfg.model)?.clone();
    let backend = rt.backend();

    // resolve the base exactly like the serve layer (checkpoint or the
    // deterministic init for the *worker's* seed), cached per model —
    // only fingerprints cross the wire
    let base = match bases.get(&cfg.model) {
        Some(b) => Arc::clone(b),
        None => {
            let b = Arc::new(resolve_worker_base(rt, &model, opts)?);
            bases.insert(cfg.model.clone(), Arc::clone(&b));
            b
        }
    };
    let my_fnv = params_fingerprint(&base);
    let want_fnv = header.req("init_fnv")?.as_str()?;
    if my_fnv != want_fnv {
        abort!(
            "base fingerprint mismatch: run wants init_fnv {want_fnv}, this worker's \
             --seed/--init-from resolve {my_fnv} — start the worker with the \
             coordinator's base"
        );
    }
    // regenerate the training data deterministically from (task, seed)
    // before Hello so its fingerprint rides the handshake
    let dataset = tasks::generate(&cfg.task, data_seed)?;
    conn.send(&Frame::Hello {
        version: PROTOCOL_VERSION,
        init_fnv: my_fnv,
        ds_fnv: train_fingerprint(&dataset.train),
    })?;

    let (rank, workers, resume, trace) = match conn.recv()? {
        Frame::Welcome { rank, workers, resume, trace } => {
            (rank as usize, workers as usize, resume as usize, trace)
        }
        Frame::Abort { reason } => {
            return Err(fatal(format!("coordinator rejected hello: {reason}")))
        }
        other => abort!("expected Welcome, got {other:?}"),
    };
    if workers == 0 || rank >= workers || model.batch % workers != 0 {
        abort!("bad shard assignment rank {rank} of {workers} (batch {})", model.batch);
    }
    // adopt the coordinator's trace context for the whole session: every
    // span this thread finishes (including worker.session below) lands
    // in the worker's SMEZO_TRACE stream stamped with the same trace id
    // the coordinator's slice spans carry — the cross-process join key
    let _trace_scope = crate::obs::trace_scope(trace);
    let _session_span = crate::obs::span("worker.session");
    let _session_mem = crate::obs::mem_scope("transport.session");

    // replica state, rebuilt fresh every session
    let p = model.n_params;
    let mut params = base.as_ref().clone();
    let mut slots = vec![0.0f32; dp_slot_len(&cfg.optimizer, p)];
    let mut thresholds = backend.thresholds(&model, &params, cfg.hypers.sparsity)?;
    let mut mask_epoch = 0u32;
    let eps = cfg.hypers.eps;

    let zo_noise_sharded = |seed: (u32, u32), params_dst: &mut Vec<f32>| -> Result<()> {
        // identical bits to any other chunking by the counter-PRNG
        // contract; reuse the caller's buffer to skip an alloc per step
        let chunks = pool.parallelism().min(p).max(1);
        let chunk_len = (p + chunks - 1) / chunks;
        let parts = pool.scatter(chunks, |c| {
            let lo = (c * chunk_len).min(p);
            let hi = ((c + 1) * chunk_len).min(p);
            if lo >= hi {
                Ok(Vec::new())
            } else {
                backend.zo_noise(&model, seed, lo, hi)
            }
        });
        params_dst.clear();
        for part in parts {
            params_dst.extend(part?);
        }
        Ok(())
    };

    // catch-up: replay the journal's committed records (the exact
    // per-record arithmetic of protocol::replay_full — no forward passes)
    let mut z = Vec::with_capacity(p);
    for _ in 0..resume {
        match conn.recv()? {
            Frame::Step(rec, _) => {
                if rec.mask_epoch != mask_epoch {
                    thresholds = backend.thresholds(&model, &params, cfg.hypers.sparsity)?;
                    mask_epoch = rec.mask_epoch;
                }
                let mask =
                    backend.zo_mask(&model, &cfg.optimizer, &cfg.hypers, &thresholds, &params)?;
                zo_noise_sharded(rec.seed, &mut z)?;
                perturb_in_place(&mut params, &z, mask.as_deref(), eps);
                perturb_in_place(&mut params, &z, mask.as_deref(), -2.0 * eps);
                apply_update(
                    &mut params,
                    &mut slots,
                    &z,
                    mask.as_deref(),
                    &cfg.hypers,
                    rec.scalar,
                    rule,
                );
            }
            other => abort!("expected catch-up Step, got {other:?}"),
        }
    }

    // the loader walks the same shuffled order as the coordinator's
    let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;
    loader.skip(resume);
    let rows_per = model.batch / workers;
    let shard_tok = rows_per * model.seq_len;
    let mut expected_step = resume as u32;
    // phase-A context the commit Step consumes: (step, z, mask)
    let mut pending: Option<(u32, Vec<f32>, Option<Vec<u8>>)> = None;

    crate::info!(
        "[worker] session: {} rank {rank}/{workers}, resume {resume} ({} live steps max)",
        cfg.label(),
        cfg.steps.saturating_sub(resume)
    );

    loop {
        match conn.recv()? {
            Frame::Refresh { mask_epoch: e } => {
                thresholds = backend.thresholds(&model, &params, cfg.hypers.sparsity)?;
                mask_epoch = e;
            }
            Frame::PhaseA { step, seed, mask_epoch: e } => {
                if let Some(budget) = phase_a_budget {
                    if *budget == 0 {
                        // fault-injection hook: die without replying,
                        // exactly like a worker killed mid-step (fatal:
                        // the simulated process must not auto-recover)
                        return Err(fatal(format!("injected worker kill before step {step}")));
                    }
                    *budget -= 1;
                }
                if step != expected_step || e != mask_epoch {
                    abort!(
                        "lockstep broken: PhaseA step {step} epoch {e}, \
                         worker at step {expected_step} epoch {mask_epoch}"
                    );
                }
                let batch = loader.next_batch();
                let mask =
                    backend.zo_mask(&model, &cfg.optimizer, &cfg.hypers, &thresholds, &params)?;
                zo_noise_sharded(seed, &mut z)?;
                let tokens = &batch.tokens[rank * shard_tok..(rank + 1) * shard_tok];
                let labels = &batch.labels[rank * rows_per..(rank + 1) * rows_per];
                perturb_in_place(&mut params, &z, mask.as_deref(), eps);
                let plus = backend.row_losses(&model, &params, tokens, labels)?;
                perturb_in_place(&mut params, &z, mask.as_deref(), -2.0 * eps);
                let minus = backend.row_losses(&model, &params, tokens, labels)?;
                conn.send(&Frame::Losses { step, plus, minus })?;
                pending = Some((step, std::mem::take(&mut z), mask));
            }
            Frame::Step(rec, _) => {
                let Some((step, pz, mask)) = pending.take() else {
                    abort!("Step {} outside a phase-A exchange", rec.step);
                };
                if rec.step != step {
                    abort!("commit for step {}, expected {step}", rec.step);
                }
                apply_update(
                    &mut params,
                    &mut slots,
                    &pz,
                    mask.as_deref(),
                    &cfg.hypers,
                    rec.scalar,
                    rule,
                );
                z = pz; // reclaim the buffer
                expected_step += 1;
                stats.steps += 1;
            }
            Frame::Finish { steps, final_fnv } => {
                if pending.is_some() || steps != expected_step {
                    abort!(
                        "Finish at step {steps} but worker is at {expected_step} \
                         (mid-exchange: {})",
                        pending.is_some()
                    );
                }
                let my_final = params_fingerprint(&params);
                if my_final != final_fnv {
                    abort!(
                        "final fingerprint mismatch after {steps} steps: worker {my_final}, \
                         coordinator {final_fnv} — replica drifted"
                    );
                }
                conn.send(&Frame::FinishAck { final_fnv: my_final })?;
                stats.sessions += 1;
                crate::info!(
                    "[worker] session done: {steps} steps, fingerprint {my_final}"
                );
                return Ok(SessionEnd::Finished);
            }
            Frame::Abort { reason } => {
                // coordinator-side cancel/divergence/lost-sibling: the
                // session's state is discarded
                crate::info!("[worker] session aborted by coordinator: {reason}");
                return Ok(SessionEnd::Discarded);
            }
            other => abort!("unexpected frame in live loop: {other:?}"),
        }
    }
}

/// The worker's base parameters: a checkpoint when configured, else the
/// deterministic init stream for `opts.seed` — byte-identical policy to
/// the serve layer's `resolve_serve_base`.
fn resolve_worker_base(
    rt: &Runtime,
    model: &crate::runtime::ModelInfo,
    opts: &WorkerOpts,
) -> Result<Vec<f32>> {
    use crate::coordinator::checkpoint::Checkpoint;
    use crate::runtime::exec::InitExec;
    match &opts.init_from {
        Some(path) => Ok(Checkpoint::load(std::path::Path::new(path), model)
            .with_context(|| format!("loading base checkpoint {path}"))?
            .params),
        None => InitExec::load(rt, model)?.run(rt, (opts.seed as u32, 0x1717)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Config {
                version: PROTOCOL_VERSION,
                header: "{\"kind\":\"dp-journal\"}".into(),
                data_seed: u64::MAX,
            },
            Frame::Hello {
                version: 1,
                init_fnv: "00ff00ff00ff00ff".into(),
                ds_fnv: "123456789abcdef0".into(),
            },
            Frame::Welcome { rank: 1, workers: 2, resume: 3, trace: 0xdead_beef_cafe_f00d },
            Frame::Refresh { mask_epoch: u32::MAX },
            Frame::PhaseA { step: 7, seed: (11, 7), mask_epoch: 1 },
            Frame::Losses { step: 7, plus: vec![0.5, -0.0, f64::MIN_POSITIVE], minus: vec![] },
            Frame::Step(
                StepRecord { step: 7, seed: (u32::MAX, 0), scalar: -0.0, mask_epoch: 2 },
                u64::MAX,
            ),
            Frame::Finish { steps: 8, final_fnv: "cbf29ce484222325".into() },
            Frame::FinishAck { final_fnv: "cbf29ce484222325".into() },
            Frame::Abort { reason: "because".into() },
        ]
    }

    #[test]
    fn frames_round_trip() {
        for f in sample_frames() {
            let bytes = encode_frame(&f);
            let (back, used) = decode_frame(&bytes).unwrap().unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(encode_frame(&back), bytes, "{f:?}");
        }
    }

    #[test]
    fn partial_frames_request_more_bytes() {
        let bytes = encode_frame(&Frame::Refresh { mask_epoch: 9 });
        for cut in 0..bytes.len() {
            assert!(decode_frame(&bytes[..cut]).unwrap().is_none(), "cut {cut}");
        }
    }

    #[test]
    fn oversized_and_malformed_frames_error_cleanly() {
        // oversized length prefix: refused with 4 bytes in hand (i.e.
        // before any allocation could be sized from it)
        let mut huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        huge.push(TAG_REFRESH);
        assert!(decode_frame(&huge).unwrap_err().to_string().contains("exceeds cap"));
        assert!(decode_frame(&u32::MAX.to_le_bytes()).is_err());
        // zero-length body (no tag)
        assert!(decode_frame(&0u32.to_le_bytes()).is_err());
        // unknown tag
        let unk = [1u32.to_le_bytes().to_vec(), vec![99u8]].concat();
        assert!(decode_frame(&unk).unwrap_err().to_string().contains("unknown frame tag"));
        // trailing garbage inside a frame body
        let mut tr = encode_frame(&Frame::Refresh { mask_epoch: 1 });
        tr.splice(0..4, ((tr.len() - 4 + 1) as u32).to_le_bytes());
        tr.push(0xAA);
        assert!(decode_frame(&tr).unwrap_err().to_string().contains("trailing"));
        // f64 count larger than the body: refused before allocation
        let mut body = vec![TAG_LOSSES];
        put_u32(&mut body, 3);
        put_u32(&mut body, u32::MAX); // plus-count lies
        let mut buf = (body.len() as u32).to_le_bytes().to_vec();
        buf.extend(body);
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("exceeds frame body"));
    }

    #[test]
    fn pre_v2_welcome_and_step_bodies_decode_with_zero_trace() {
        // hand-built v1 bodies: no trailing trace u64. The decoder's
        // version gate must default the field, not reject the frame.
        let mut body = vec![TAG_WELCOME];
        put_u32(&mut body, 1);
        put_u32(&mut body, 2);
        put_u32(&mut body, 3);
        let mut buf = (body.len() as u32).to_le_bytes().to_vec();
        buf.extend(body);
        match decode_frame(&buf).unwrap().unwrap().0 {
            Frame::Welcome { rank: 1, workers: 2, resume: 3, trace: 0 } => {}
            other => panic!("v1 Welcome decoded as {other:?}"),
        }

        let mut body = vec![TAG_STEP];
        for v in [7u32, 11, 0x1717, 0x8000_0000, 2] {
            put_u32(&mut body, v);
        }
        let mut buf = (body.len() as u32).to_le_bytes().to_vec();
        buf.extend(body);
        match decode_frame(&buf).unwrap().unwrap().0 {
            Frame::Step(rec, 0) => {
                assert_eq!(rec.step, 7);
                assert_eq!(rec.seed, (11, 0x1717));
                assert_eq!(rec.scalar.to_bits(), 0x8000_0000);
                assert_eq!(rec.mask_epoch, 2);
            }
            other => panic!("v1 Step decoded as {other:?}"),
        }

        // a torn trace field (1..7 trailing bytes) is still malformed
        let mut body = vec![TAG_WELCOME];
        put_u32(&mut body, 1);
        put_u32(&mut body, 2);
        put_u32(&mut body, 3);
        body.extend_from_slice(&[0xAA, 0xBB, 0xCC]);
        let mut buf = (body.len() as u32).to_le_bytes().to_vec();
        buf.extend(body);
        assert!(decode_frame(&buf).is_err(), "torn trace field must not decode");
    }

    #[test]
    fn worker_lost_marker_survives_context() {
        let e = worker_lost("rank 1: connection reset").context("slice 3");
        assert!(is_worker_lost(&e));
        assert!(!is_worker_lost(&anyhow!("some other error")));
    }
}
