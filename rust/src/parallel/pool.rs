//! The persistent worker pool — one scheduler for every parallel axis.
//!
//! A fixed set of threads drain a shared FIFO of closures; `scatter` is
//! the single entry point: fan an indexed closure out over the pool and
//! block until every piece has finished. Three properties make it safe
//! to use as the *only* scheduler in the crate (sweep cells, DP replica
//! phases and eval shards all go through it, replacing the ad-hoc
//! `std::thread::scope` fan-out of the pre-parallel sweep driver):
//!
//! * **Caller participation** — the calling thread drains the queue too,
//!   so `scatter` from inside a pool task (a sweep cell sharding its
//!   evaluation, say) can never deadlock: every `scatter` contributes at
//!   least its own thread to the work it enqueued. A zero-thread pool
//!   degenerates to serial in-line execution.
//! * **Scoped borrows** — closures may borrow from the caller's stack
//!   (`&Runtime`, datasets, replicas). `scatter` guarantees the borrow
//!   outlives every task by not returning until the completion count
//!   hits zero; the lifetime erasure this needs is confined to
//!   [`erase`], the one `unsafe` block in the crate.
//! * **Panic transparency** — a panicking task never kills a pool
//!   thread; the payload is carried back and re-thrown on the calling
//!   thread, matching `std::thread::scope` semantics.
//!
//! Channels are crossbeam-style in shape (MPMC queue + blocking pop)
//! but built on `std` primitives only: a `Mutex<VecDeque>` plus a
//! `Condvar`, which at this workload's task granularity (milliseconds
//! of compute per task) is nowhere near contention.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// A queued unit of work.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Queue + wakeup shared between the pool handle and its threads.
struct Inner {
    queue: Mutex<VecDeque<Task>>,
    ready: Condvar,
    shutdown: AtomicBool,
}

impl Inner {
    /// Non-blocking pop; the queue lock is released before returning so
    /// the popped task can itself touch the queue (nested `scatter`).
    fn try_pop(&self) -> Option<Task> {
        self.queue.lock().unwrap().pop_front()
    }
}

/// Erase a scoped task's lifetime so it can ride the `'static` queue.
///
/// SAFETY contract (upheld by [`WorkerPool::scatter`], the only caller):
/// the closure borrows only from a stack frame that blocks until the
/// task has *finished running* — tasks are never dropped unexecuted
/// while a scatter is pending (workers and the scatter caller pop until
/// the queue is empty, and `WorkerPool` can't be dropped mid-call
/// because `scatter` holds `&self`).
fn erase<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    // SAFETY: see above — the borrow checker can't see that `scatter`
    // joins on completion before its frame unwinds, exactly the
    // obligation `std::thread::scope` discharges the same way.
    unsafe { std::mem::transmute(task) }
}

/// Per-scatter completion state: one result slot per task plus a latch.
struct Scatter<T> {
    slots: Vec<Mutex<Option<thread::Result<T>>>>,
    remaining: Mutex<usize>,
    done: Condvar,
}

/// The persistent worker pool. See the module docs for the contract.
pub struct WorkerPool {
    inner: Arc<Inner>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool with `threads` worker threads. `0` is valid: every
    /// `scatter` then runs entirely on the calling thread, which is the
    /// serial baseline the DP bit-identity tests compare against.
    pub fn new(threads: usize) -> WorkerPool {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("smz-pool-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { inner, handles }
    }

    /// Number of pool threads (excluding participating callers).
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Machine-sized default thread count: one per available core (the
    /// participating caller rides on top). Used where no `--workers`
    /// knob reaches, e.g. the repro harness's sweeps.
    pub fn default_size() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Executors available to one `scatter`: pool threads + the caller.
    pub fn parallelism(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f(0), f(1), …, f(n-1)` across the pool and the calling
    /// thread, returning results in index order. Blocks until all `n`
    /// complete; re-throws the first task panic on the caller.
    pub fn scatter<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.handles.is_empty() {
            // no workers: plain serial map, no queue traffic
            return (0..n).map(f).collect();
        }
        let state = Scatter::<T> {
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: Mutex::new(n),
            done: Condvar::new(),
        };
        {
            let mut q = self.inner.queue.lock().unwrap();
            for i in 0..n {
                let state = &state;
                let f = &f;
                q.push_back(erase(Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(|| f(i)));
                    *state.slots[i].lock().unwrap() = Some(result);
                    let mut remaining = state.remaining.lock().unwrap();
                    *remaining -= 1;
                    if *remaining == 0 {
                        state.done.notify_all();
                    }
                })));
            }
        }
        self.inner.ready.notify_all();

        // caller participation: drain the queue (our tasks and, under
        // nesting, anyone else's) until it runs dry …
        while let Some(task) = self.inner.try_pop() {
            task();
        }
        // … then wait out stragglers still running on pool threads
        let mut remaining = state.remaining.lock().unwrap();
        while *remaining > 0 {
            remaining = state.done.wait(remaining).unwrap();
        }
        drop(remaining);

        state
            .slots
            .into_iter()
            .map(|slot| match slot.into_inner().unwrap() {
                Some(Ok(v)) => v,
                Some(Err(payload)) => resume_unwind(payload),
                None => unreachable!("scatter latch released with an unfilled slot"),
            })
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker thread body: pop-and-run until shutdown.
fn worker_loop(inner: &Inner) {
    loop {
        let mut guard = inner.queue.lock().unwrap();
        let task = loop {
            if let Some(t) = guard.pop_front() {
                break t;
            }
            if inner.shutdown.load(Ordering::Acquire) {
                return;
            }
            guard = inner.ready.wait(guard).unwrap();
        };
        drop(guard);
        // scatter's closure already catch_unwinds the user payload; this
        // outer guard is belt-and-braces so a slot/latch bug can never
        // take a pool thread down with it.
        let _ = catch_unwind(AssertUnwindSafe(task));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scatter_returns_in_index_order() {
        let pool = WorkerPool::new(3);
        let out = pool.scatter(17, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_thread_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.parallelism(), 1);
        assert_eq!(pool.scatter(4, |i| i + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn scatter_runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        let out = pool.scatter(100, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
            1usize
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(out.iter().sum::<usize>(), 100);
    }

    #[test]
    fn nested_scatter_does_not_deadlock() {
        // every cell shards inner work through the same pool — the shared
        // scheduler the sweep/DP/eval stack relies on
        let pool = WorkerPool::new(2);
        let out = pool.scatter(4, |i| pool.scatter(3, |j| i * 10 + j).iter().sum::<usize>());
        assert_eq!(out, vec![3, 33, 63, 93]);
    }

    #[test]
    fn scatter_borrows_caller_stack() {
        let pool = WorkerPool::new(2);
        let data: Vec<u64> = (0..64).collect();
        let sum = pool.scatter(8, |i| data[i * 8..(i + 1) * 8].iter().sum::<u64>());
        assert_eq!(sum.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scatter(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(caught.is_err());
        // the pool is still serviceable afterwards
        assert_eq!(pool.scatter(3, |i| i), vec![0, 1, 2]);
    }
}
