//! Sharded evaluation: split eval batches across the worker pool.
//!
//! Evaluation batches are embarrassingly parallel — every batch is an
//! independent `logits` call plus host-side candidate scoring — so the
//! pool turns an eval pass's wall-clock into roughly
//! `ceil(batches / parallelism)` batch latencies. Per-batch results are
//! *folded in batch order with the exact running-mean formula of the
//! serial evaluator*, so a sharded pass returns bit-identical numbers
//! to [`evaluator::evaluate`](crate::coordinator::evaluator::evaluate)
//! regardless of worker count or completion order (asserted in
//! `tests/parallel.rs`).

use anyhow::Result;

use crate::coordinator::evaluator::{score_batch, EvalResult};
use crate::data::batcher::eval_batches;
use crate::data::Example;
use crate::runtime::exec::LogitsExec;
use crate::runtime::Runtime;

use super::pool::WorkerPool;

/// Evaluate `params` over `examples`, sharding batches across `pool`.
/// Semantics (cap, candidate scoring, running-mean fold) are identical
/// to the serial evaluator; only the schedule differs.
pub fn evaluate_sharded(
    rt: &Runtime,
    pool: &WorkerPool,
    logits: &LogitsExec,
    params: &[f32],
    examples: &[Example],
    cap: usize,
) -> Result<EvalResult> {
    let slice = if cap > 0 && cap < examples.len() { &examples[..cap] } else { examples };
    let batches = eval_batches(slice, logits.batch, logits.seq_len);
    let shards = pool.scatter(batches.len(), |i| -> Result<EvalResult> {
        let lg = logits.run(rt, params, &batches[i].tokens)?;
        Ok(score_batch(&lg, logits.vocab, &batches[i]))
    });
    // fold in batch order with the serial evaluator's exact formula
    let mut total = EvalResult { n: 0, correct: 0, mean_loss: 0.0 };
    for shard in shards {
        let r = shard?;
        total.mean_loss = (total.mean_loss * total.n as f64 + r.mean_loss * r.n as f64)
            / (total.n + r.n).max(1) as f64;
        total.n += r.n;
        total.correct += r.correct;
    }
    Ok(total)
}
