//! Seed-sync data-parallel ZO training.
//!
//! ZO training has a property no first-order method shares: a full
//! MeZO/Sparse-MeZO step is completely described by its `(seed, g)`
//! pair, so data-parallel workers stay bit-identical by exchanging a
//! few bytes per step instead of gradients. The engine:
//!
//! 1. generates the step's perturbation noise `z` **once** from the
//!    shared step seed (sharded across the pool — chunk-invariant by
//!    the counter-PRNG contract) and the step mask once from the
//!    (identical) unperturbed replicas;
//! 2. **phase A** — each of the N workers perturbs its own parameter
//!    replica `+eps`/`-2eps` in place and scores the two forward passes
//!    on its `B/N`-row microbatch shard, returning *per-row* f64 losses;
//! 3. **all-reduce** — the per-row losses are folded in canonical row
//!    order into `l_plus`/`l_minus` and the projected-gradient scalar
//!    `g = (l+ - l-)/(2 eps)`. The canonical fold is what makes every
//!    worker count produce the same bits as a serial
//!    [`Trainer`](crate::coordinator::trainer::Trainer) step — means of
//!    shard-means would not;
//! 4. **phase B** — every replica applies the identical fused
//!    restore+update `theta += eps*z - lr*g*(m (.) z)` locally. No
//!    parameter ever crosses a worker boundary.
//!
//! Each step's `(step, seed, g, mask_epoch)` record goes to the
//! [`protocol`](super::protocol) journal, which replays to bit-identical
//! final parameters without forward passes (crash recovery / audit).
//!
//! Scope: the stateless-mask ZO family (`mezo`, `smezo`, `smezo_large`,
//! `rmezo`) with a constant learning rate — the paper's methods.
//! Slot-stateful optimizers (momentum/Adam/stored-mask) would need
//! replicated slot blocks and are left on the serial trainer.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::trainer::{self, CurvePoint, TrainResult, DIVERGENCE_LOSS};
use crate::data::batcher::TrainLoader;
use crate::data::{tasks, Dataset};
use crate::runtime::exec::LogitsExec;
use crate::runtime::{ModelInfo, Runtime};
use crate::util::json::Json;
use crate::util::stats::Ema;

use super::eval;
use super::pool::WorkerPool;
use super::protocol::{JournalWriter, StepRecord};

/// Optimizers the DP engine supports (stateless step masks only).
pub fn dp_supported(optimizer: &str) -> bool {
    matches!(optimizer, "mezo" | "smezo" | "smezo_large" | "rmezo")
}

/// `params[i] += scale * z[i]` over unmasked coordinates — the Alg.-2
/// seed-replay perturbation, expression-for-expression identical to the
/// serial walk so DP trajectories match serial ones bit-for-bit.
pub(crate) fn perturb_in_place(params: &mut [f32], z: &[f32], mask: Option<&[u8]>, scale: f32) {
    match mask {
        Some(m) => {
            for ((pv, &zv), &mv) in params.iter_mut().zip(z).zip(m) {
                if mv != 0 {
                    *pv += scale * zv;
                }
            }
        }
        None => {
            for (pv, &zv) in params.iter_mut().zip(z) {
                *pv += scale * zv;
            }
        }
    }
}

/// The fused restore+update from the minus-perturbed point (`Rule::Sgd`
/// of the serial walk): `u = lr*g*z; params += eps*z - u` on unmasked
/// coordinates. Returns the squared L2 norm of the applied update.
pub(crate) fn apply_sgd_update(
    params: &mut [f32],
    z: &[f32],
    mask: Option<&[u8]>,
    eps: f32,
    lr: f32,
    g: f32,
) -> f32 {
    let mut norm = 0.0f32;
    match mask {
        Some(m) => {
            for ((pv, &zv), &mv) in params.iter_mut().zip(z).zip(m) {
                if mv != 0 {
                    let u = lr * g * zv;
                    *pv += eps * zv - u;
                    norm += u * u;
                }
            }
        }
        None => {
            for (pv, &zv) in params.iter_mut().zip(z) {
                let u = lr * g * zv;
                *pv += eps * zv - u;
                norm += u * u;
            }
        }
    }
    norm
}

/// Driver for one seed-sync data-parallel training run. Mirrors
/// [`Trainer`](crate::coordinator::trainer::Trainer)'s policy surface
/// (initial override, test eval, curve/divergence handling) and returns
/// the same [`TrainResult`] so reports and sweeps are interchangeable.
pub struct DpTrainer<'rt> {
    /// the runtime (and through it, the compute backend) to train on
    pub rt: &'rt Runtime,
    /// shared scheduler for DP phases and sharded evaluation
    pub pool: &'rt WorkerPool,
    /// fully-resolved run configuration (`cfg.workers` = replica count)
    pub cfg: TrainConfig,
    /// write the step-exchange journal here if set
    pub journal_path: Option<PathBuf>,
    /// evaluate on test at the end
    pub eval_test: bool,
    /// explicit initial parameters (takes precedence over cfg.init_from)
    pub initial_override: Option<Vec<f32>>,
    /// recompute §8.2 thresholds from live params every N steps
    /// (0 = never, matching the serial trainer); each refresh bumps the
    /// journal's `mask_epoch`
    pub mask_refresh: usize,
}

impl<'rt> DpTrainer<'rt> {
    /// A DP trainer with default policy: no journal, test eval at the
    /// end, thresholds fixed at init (serial-trainer parity).
    pub fn new(rt: &'rt Runtime, pool: &'rt WorkerPool, cfg: TrainConfig) -> DpTrainer<'rt> {
        DpTrainer {
            rt,
            pool,
            cfg,
            journal_path: None,
            eval_test: true,
            initial_override: None,
            mask_refresh: 0,
        }
    }

    /// Stream `(step, seed, g, mask_epoch)` records to a journal file.
    pub fn with_journal(mut self, path: &std::path::Path) -> DpTrainer<'rt> {
        self.journal_path = Some(path.to_path_buf());
        self
    }

    /// Resolve the model + dataset from the config and run.
    pub fn run(&mut self) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        cfg.validate()?;
        let model = self.rt.model(&cfg.model)?.clone();
        let dataset = tasks::generate(&cfg.task, cfg.seed)?;
        self.run_on(&model, &dataset)
    }

    /// Run against an explicit dataset (paired-comparison harnesses
    /// share one dataset across methods and worker counts).
    pub fn run_on(&mut self, model: &ModelInfo, dataset: &Dataset) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        cfg.validate()?;
        let n = cfg.workers.max(1);
        if !dp_supported(&cfg.optimizer) {
            bail!(
                "data-parallel training supports the mezo/smezo/smezo_large/rmezo family, \
                 not '{}' (use the serial trainer)",
                cfg.optimizer
            );
        }
        if model.batch % n != 0 {
            bail!("workers {n} must divide the model batch size {}", model.batch);
        }
        let backend = self.rt.backend();
        let t_total = Instant::now();

        // ---- setup ---------------------------------------------------------
        let params = trainer::resolve_initial_params(self.rt, &cfg, &self.initial_override, model)?;
        let mut thresholds = backend.thresholds(model, &params, cfg.hypers.sparsity)?;
        let logits = LogitsExec::load(self.rt, model)?;
        let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;
        let p = model.n_params;
        let rows_per = model.batch / n;
        let shard_tok = rows_per * model.seq_len;
        let eps = cfg.hypers.eps;
        let lr = cfg.hypers.lr;

        // N full parameter replicas; seed-sync keeps them bit-identical
        // forever, which the end-of-run drift check asserts
        let replicas: Vec<Mutex<Vec<f32>>> = (0..n).map(|_| Mutex::new(params.clone())).collect();

        let mut journal = match &self.journal_path {
            Some(path) => Some(JournalWriter::create(
                path,
                vec![
                    ("label", Json::Str(cfg.label())),
                    ("model", Json::Str(cfg.model.clone())),
                    ("task", Json::Str(cfg.task.clone())),
                    ("optimizer", Json::Str(cfg.optimizer.clone())),
                    ("workers", Json::Num(n as f64)),
                    ("seed", Json::Num(cfg.seed as f64)),
                    ("steps", Json::Num(cfg.steps as f64)),
                    ("mask_refresh", Json::Num(self.mask_refresh as f64)),
                    // the hypers replay needs; check_compatible() verifies
                    // them against the replaying config
                    ("lr", Json::Num(cfg.hypers.lr as f64)),
                    ("eps", Json::Num(cfg.hypers.eps as f64)),
                    ("sparsity", Json::Num(cfg.hypers.sparsity as f64)),
                ],
            )?),
            None => None,
        };

        // ---- loop ----------------------------------------------------------
        let mut curve = Vec::new();
        let mut train_losses = Vec::with_capacity(cfg.steps);
        let mut ema = Ema::new(0.95);
        let mut diverged = false;
        let mut step_seconds = 0.0f64;
        let mut mask_epoch = 0u32;

        for t in 0..cfg.steps {
            let batch = loader.next_batch();
            let seed = (cfg.seed as u32, t as u32);
            let t0 = Instant::now();

            if self.mask_refresh > 0 && t > 0 && t % self.mask_refresh == 0 {
                let master = replicas[0].lock().unwrap();
                thresholds = backend.thresholds(model, &master, cfg.hypers.sparsity)?;
                mask_epoch += 1;
            }

            // shared step noise, generated once and sharded across the
            // pool (chunk boundaries are free to vary: zo_noise is
            // chunk-invariant by the counter-PRNG offset contract)
            let chunks = self.pool.parallelism().min(p).max(1);
            let chunk_len = (p + chunks - 1) / chunks;
            let parts = self.pool.scatter(chunks, |c| {
                let lo = (c * chunk_len).min(p);
                let hi = ((c + 1) * chunk_len).min(p);
                if lo >= hi {
                    Ok(Vec::new())
                } else {
                    backend.zo_noise(model, seed, lo, hi)
                }
            });
            let mut z = Vec::with_capacity(p);
            for part in parts {
                z.extend(part?);
            }

            // step mask from the unperturbed (identical) replicas
            let mask = {
                let master = replicas[0].lock().unwrap();
                backend.zo_mask(model, &cfg.optimizer, &cfg.hypers, &thresholds, &master)?
            };
            let masked_frac = match &mask {
                Some(m) => m.iter().map(|&x| x as usize).sum::<usize>() as f32 / p as f32,
                None => 1.0,
            };

            // phase A: perturb replicas +eps/-2eps, score microbatch shards
            let shard_losses = self.pool.scatter(n, |j| -> Result<(Vec<f64>, Vec<f64>)> {
                let mut replica = replicas[j].lock().unwrap();
                let tokens = &batch.tokens[j * shard_tok..(j + 1) * shard_tok];
                let labels = &batch.labels[j * rows_per..(j + 1) * rows_per];
                perturb_in_place(&mut replica, &z, mask.as_deref(), eps);
                let rows_plus = backend.row_losses(model, &replica, tokens, labels)?;
                perturb_in_place(&mut replica, &z, mask.as_deref(), -2.0 * eps);
                let rows_minus = backend.row_losses(model, &replica, tokens, labels)?;
                Ok((rows_plus, rows_minus))
            });

            // all-reduce: canonical row-order f64 fold, then the same f32
            // casts a serial step performs — worker-count-invariant bits
            let mut sum_plus = 0.0f64;
            let mut sum_minus = 0.0f64;
            let mut rows = 0usize;
            for shard in shard_losses {
                let (rp, rm) = shard?;
                for &v in &rp {
                    sum_plus += v;
                }
                for &v in &rm {
                    sum_minus += v;
                }
                rows += rp.len();
            }
            let l_plus = (sum_plus / rows.max(1) as f64) as f32;
            let l_minus = (sum_minus / rows.max(1) as f64) as f32;
            let g = (l_plus - l_minus) / (2.0 * eps);
            let train_loss = 0.5 * (l_plus + l_minus);

            if !g.is_finite() {
                // a NaN scalar would both poison every replica and break
                // the JSON journal; stop before exchanging it
                crate::info!("[{}] DIVERGED at step {t} (non-finite g)", cfg.label());
                diverged = true;
                break;
            }
            if let Some(w) = &mut journal {
                w.record(&StepRecord { step: t as u32, seed, scalar: g, mask_epoch })?;
            }

            // phase B: identical masked update on every replica — the
            // whole exchange was the scalar g
            let norms = self.pool.scatter(n, |j| {
                let mut replica = replicas[j].lock().unwrap();
                apply_sgd_update(&mut replica, &z, mask.as_deref(), eps, lr, g)
            });
            let update_norm_sq = norms.first().copied().unwrap_or(0.0);
            step_seconds += t0.elapsed().as_secs_f64();

            train_losses.push(train_loss);
            let smoothed = ema.update(train_loss as f64);
            if cfg.log_every > 0 && t % (cfg.log_every * 10) == 0 {
                crate::debug!(
                    "[{} dp{n}] step {t}/{} loss {train_loss:.4} (ema {smoothed:.4}) g {g:.3} \
                     masked {masked_frac:.3} |u|^2 {update_norm_sq:.3e}",
                    cfg.label(),
                    cfg.steps,
                );
            }

            // divergence detection (Fig. 2a), after the update like the
            // serial trainer
            if !train_loss.is_finite() || train_loss > DIVERGENCE_LOSS {
                crate::info!("[{}] DIVERGED at step {t} (loss {train_loss})", cfg.label());
                diverged = true;
                break;
            }

            // periodic dev evaluation, sharded over the same pool
            let is_last = t + 1 == cfg.steps;
            if (cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0) || is_last {
                let p_host = replicas[0].lock().unwrap().clone();
                let dev = eval::evaluate_sharded(
                    self.rt,
                    self.pool,
                    &logits,
                    &p_host,
                    &dataset.dev,
                    cfg.eval_cap,
                )?;
                curve.push(CurvePoint {
                    step: t + 1,
                    dev_accuracy: dev.accuracy(),
                    dev_loss: dev.mean_loss,
                    train_loss_ema: smoothed,
                });
                if let Some(w) = &mut journal {
                    w.flush()?;
                }
                crate::info!(
                    "[{} dp{n}] step {}/{} dev acc {:.3} loss {:.3}",
                    cfg.label(),
                    t + 1,
                    cfg.steps,
                    dev.accuracy(),
                    dev.mean_loss
                );
            }
        }

        // ---- final check + evaluation --------------------------------------
        let params = replicas[0].lock().unwrap().clone();
        for (j, replica) in replicas.iter().enumerate().skip(1) {
            let replica = replica.lock().unwrap();
            let drifted = replica.iter().zip(&params).any(|(a, b)| a.to_bits() != b.to_bits());
            if drifted {
                bail!("replica {j} drifted from replica 0 — seed-sync invariant broken");
            }
        }
        let final_dev = curve.last().map(|c| EvalResult { n: 0, correct: 0, mean_loss: c.dev_loss });
        let test = if self.eval_test && !diverged {
            Some(eval::evaluate_sharded(self.rt, self.pool, &logits, &params, &dataset.test, 0)?)
        } else {
            None
        };
        if let Some(w) = &mut journal {
            w.flush()?;
        }
        let steps_run = train_losses.len();
        Ok(TrainResult {
            config_label: cfg.label(),
            steps_run,
            curve,
            final_dev,
            test,
            diverged,
            wallclock_s: t_total.elapsed().as_secs_f64(),
            sec_per_step: step_seconds / steps_run.max(1) as f64,
            params,
            train_losses,
        })
    }
}
