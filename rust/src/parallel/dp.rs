//! Seed-sync data-parallel ZO training.
//!
//! ZO training has a property no first-order method shares: a full
//! MeZO/Sparse-MeZO step is completely described by its `(seed, g)`
//! pair, so data-parallel workers stay bit-identical by exchanging a
//! few bytes per step instead of gradients. The engine:
//!
//! 1. generates the step's perturbation noise `z` **once** from the
//!    shared step seed (sharded across the pool — chunk-invariant by
//!    the counter-PRNG contract) and the step mask once from the
//!    (identical) unperturbed replicas;
//! 2. **phase A** — each of the N workers perturbs its own parameter
//!    replica `+eps`/`-2eps` in place and scores the two forward passes
//!    on its `B/N`-row microbatch shard, returning *per-row* f64 losses;
//! 3. **all-reduce** — the per-row losses are folded in canonical row
//!    order into `l_plus`/`l_minus` and the projected-gradient scalar
//!    `g = (l+ - l-)/(2 eps)`. The canonical fold is what makes every
//!    worker count produce the same bits as a serial
//!    [`Trainer`](crate::coordinator::trainer::Trainer) step — means of
//!    shard-means would not;
//! 4. **phase B** — every replica applies the identical fused
//!    restore+update `theta += eps*z - lr*g*(m (.) z)` locally. No
//!    parameter ever crosses a worker boundary.
//!
//! Each step's `(step, seed, g, mask_epoch)` record goes to the
//! [`protocol`](super::protocol) journal, which replays to bit-identical
//! final parameters without forward passes (crash recovery / audit).
//!
//! Scope: the stateless-mask ZO family (`mezo`, `smezo`, `smezo_large`,
//! `rmezo`) with a constant learning rate — the paper's methods — plus
//! the dense slot-stateful optimizers `zo_mom`/`zo_adam`/`zo_adamu`.
//! The slot-stateful extension costs nothing on the wire: optimizer
//! slots are a deterministic function of the shared `(seed, g)` stream,
//! so each replica carries its own slot block and updates it from the
//! same scalar — slots stay bit-identical forever, exactly like the
//! parameters (the end-of-run drift check covers both). Only the
//! stored-mask ablation `smezo_const` stays on the serial trainer (its
//! mask lives in slots *and* feeds back into perturbation support).

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::trainer::{self, CurvePoint, TrainResult, DIVERGENCE_LOSS};
use crate::data::batcher::TrainLoader;
use crate::data::{tasks, Dataset};
use crate::obs::recorder::FlightRecorder;
use crate::runtime::exec::{Hypers, LogitsExec};
use crate::runtime::{ModelInfo, Runtime};
use crate::util::json::Json;
use crate::util::stats::Ema;

use super::eval;
use super::pool::WorkerPool;
use super::protocol::{self, params_fingerprint, JournalWriter, StepRecord};
use super::transport::{is_worker_lost, train_fingerprint, Frame, RemoteHandle, RemoteWorker};

/// Which phase-B update rule the DP engine applies for an optimizer —
/// each mirrors the corresponding `Rule` arm of the native backend's
/// fused serial walk expression-for-expression, which is what keeps DP
/// trajectories bit-identical to serial ones.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum DpRule {
    /// `theta -= lr * g * m ⊙ z` (MeZO / S-MeZO / R-MeZO)
    Sgd,
    /// heavy-ball momentum on `g * z`; slot block `[m (P)]`
    Momentum,
    /// Adam moments on `g * z`; slot block `[m (P) | v (P) | t (1)]`.
    /// `clamp` bounds each coordinate update to ±lr (zo_adamu).
    Adam {
        /// bound each coordinate update to ±lr (the AdaMU variant)
        clamp: bool,
    },
}

/// The update rule the DP engine would use for `optimizer`
/// (`None` = not DP-supported; use the serial trainer).
pub(crate) fn dp_rule(optimizer: &str) -> Option<DpRule> {
    match optimizer {
        "mezo" | "smezo" | "smezo_large" | "rmezo" => Some(DpRule::Sgd),
        "zo_mom" => Some(DpRule::Momentum),
        "zo_adam" => Some(DpRule::Adam { clamp: false }),
        "zo_adamu" => Some(DpRule::Adam { clamp: true }),
        _ => None,
    }
}

/// Optimizer-slot floats each DP replica carries for `optimizer` (the
/// same slot geometry the serial trainer's packed state uses).
pub(crate) fn dp_slot_len(optimizer: &str, p: usize) -> usize {
    match dp_rule(optimizer) {
        Some(DpRule::Momentum) => p,
        Some(DpRule::Adam { .. }) => 2 * p + 1,
        _ => 0,
    }
}

/// Optimizers the DP engine supports: stateless step masks plus the
/// dense slot-stateful family (slots replay from the shared scalar).
pub fn dp_supported(optimizer: &str) -> bool {
    dp_rule(optimizer).is_some()
}

/// `params[i] += scale * z[i]` over unmasked coordinates — the Alg.-2
/// seed-replay perturbation, expression-for-expression identical to the
/// serial walk so DP trajectories match serial ones bit-for-bit.
pub(crate) fn perturb_in_place(params: &mut [f32], z: &[f32], mask: Option<&[u8]>, scale: f32) {
    match mask {
        Some(m) => {
            for ((pv, &zv), &mv) in params.iter_mut().zip(z).zip(m) {
                if mv != 0 {
                    *pv += scale * zv;
                }
            }
        }
        None => {
            for (pv, &zv) in params.iter_mut().zip(z) {
                *pv += scale * zv;
            }
        }
    }
}

/// The fused restore+update from the minus-perturbed point (`Rule::Sgd`
/// of the serial walk): `u = lr*g*z; params += eps*z - u` on unmasked
/// coordinates. Returns the squared L2 norm of the applied update.
pub(crate) fn apply_sgd_update(
    params: &mut [f32],
    z: &[f32],
    mask: Option<&[u8]>,
    eps: f32,
    lr: f32,
    g: f32,
) -> f32 {
    let mut norm = 0.0f32;
    match mask {
        Some(m) => {
            for ((pv, &zv), &mv) in params.iter_mut().zip(z).zip(m) {
                if mv != 0 {
                    let u = lr * g * zv;
                    *pv += eps * zv - u;
                    norm += u * u;
                }
            }
        }
        None => {
            for (pv, &zv) in params.iter_mut().zip(z) {
                let u = lr * g * zv;
                *pv += eps * zv - u;
                norm += u * u;
            }
        }
    }
    norm
}

/// The fused restore+update for heavy-ball momentum (`Rule::Momentum`
/// of the serial walk): `m = beta1*m + (1-beta1)*g*z; u = lr*m;
/// params += eps*z - u` on unmasked coordinates. `slots` is the
/// P-element momentum buffer; masked-out coordinates leave their slot
/// untouched, exactly like the serial walk.
pub(crate) fn apply_mom_update(
    params: &mut [f32],
    slots: &mut [f32],
    z: &[f32],
    mask: Option<&[u8]>,
    hypers: &Hypers,
    g: f32,
) -> f32 {
    let (eps, lr, beta) = (hypers.eps, hypers.lr, hypers.beta1);
    let mut norm = 0.0f32;
    for i in 0..params.len() {
        if let Some(m) = mask {
            if m[i] == 0 {
                continue;
            }
        }
        let zv = z[i];
        let gz = g * zv;
        slots[i] = beta * slots[i] + (1.0 - beta) * gz;
        let u = lr * slots[i];
        params[i] += eps * zv - u;
        norm += u * u;
    }
    norm
}

/// The fused restore+update for Adam moments (`Rule::Adam` of the serial
/// walk). Slot layout `[m (P) | v (P) | t (1)]`; the step counter at
/// `slots[2P]` increments once per call before the coordinate loop, and
/// `clamp` bounds each coordinate update to ±lr (zo_adamu).
pub(crate) fn apply_adam_update(
    params: &mut [f32],
    slots: &mut [f32],
    z: &[f32],
    mask: Option<&[u8]>,
    hypers: &Hypers,
    g: f32,
    clamp: bool,
) -> f32 {
    let p = params.len();
    let (eps, lr) = (hypers.eps, hypers.lr);
    slots[2 * p] += 1.0;
    let t = slots[2 * p];
    let bc1 = 1.0 - hypers.beta1.powf(t);
    let bc2 = 1.0 - hypers.beta2.powf(t);
    let mut norm = 0.0f32;
    for i in 0..p {
        if let Some(m) = mask {
            if m[i] == 0 {
                continue;
            }
        }
        let zv = z[i];
        let gz = g * zv;
        slots[i] = hypers.beta1 * slots[i] + (1.0 - hypers.beta1) * gz;
        slots[p + i] = hypers.beta2 * slots[p + i] + (1.0 - hypers.beta2) * gz * gz;
        let mhat = slots[i] / bc1;
        let vhat = slots[p + i] / bc2;
        let mut u = lr * mhat / (vhat.sqrt() + hypers.adam_eps);
        if clamp {
            u = u.clamp(-lr, lr);
        }
        params[i] += eps * zv - u;
        norm += u * u;
    }
    norm
}

/// Dispatch one phase-B update by rule. `slots` must be sized by
/// [`dp_slot_len`] for the rule's optimizer (empty for `Sgd`).
pub(crate) fn apply_update(
    params: &mut [f32],
    slots: &mut [f32],
    z: &[f32],
    mask: Option<&[u8]>,
    hypers: &Hypers,
    g: f32,
    rule: DpRule,
) -> f32 {
    match rule {
        DpRule::Sgd => apply_sgd_update(params, z, mask, hypers.eps, hypers.lr, g),
        DpRule::Momentum => apply_mom_update(params, slots, z, mask, hypers, g),
        DpRule::Adam { clamp } => apply_adam_update(params, slots, z, mask, hypers, g, clamp),
    }
}

/// Driver for one seed-sync data-parallel training run. Mirrors
/// [`Trainer`](crate::coordinator::trainer::Trainer)'s policy surface
/// (initial override, test eval, curve/divergence handling) and returns
/// the same [`TrainResult`] so reports and sweeps are interchangeable.
pub struct DpTrainer<'rt> {
    /// the runtime (and through it, the compute backend) to train on
    pub rt: &'rt Runtime,
    /// shared scheduler for DP phases and sharded evaluation
    pub pool: &'rt WorkerPool,
    /// fully-resolved run configuration (`cfg.workers` = replica count)
    pub cfg: TrainConfig,
    /// write the step-exchange journal here if set
    pub journal_path: Option<PathBuf>,
    /// evaluate on test at the end
    pub eval_test: bool,
    /// explicit initial parameters (takes precedence over cfg.init_from)
    pub initial_override: Option<Vec<f32>>,
    /// recompute §8.2 thresholds from live params every N steps
    /// (0 = never, matching the serial trainer); each refresh bumps the
    /// journal's `mask_epoch`
    pub mask_refresh: usize,
    /// lease TCP worker sessions from this hub for each
    /// [`run_slice`](DpTrainer::run_slice) call — remote replicas take
    /// the top microbatch shard ranks and the local pool keeps the rest,
    /// with the canonical loss fold unchanged (bit-identity preserved).
    /// `None` (the default) keeps every shard local.
    pub remote: Option<RemoteHandle>,
    /// stream per-step telemetry (loss, `g`, mask stats) into this
    /// flight recorder. Read-only taps on values the step already
    /// computes — consumes no PRNG state, never touches the journal.
    pub recorder: Option<Arc<FlightRecorder>>,
}

impl<'rt> DpTrainer<'rt> {
    /// A DP trainer with default policy: no journal, test eval at the
    /// end, thresholds fixed at init (serial-trainer parity).
    pub fn new(rt: &'rt Runtime, pool: &'rt WorkerPool, cfg: TrainConfig) -> DpTrainer<'rt> {
        DpTrainer {
            rt,
            pool,
            cfg,
            journal_path: None,
            eval_test: true,
            initial_override: None,
            mask_refresh: 0,
            remote: None,
            recorder: None,
        }
    }

    /// Stream `(step, seed, g, mask_epoch)` records to a journal file.
    pub fn with_journal(mut self, path: &std::path::Path) -> DpTrainer<'rt> {
        self.journal_path = Some(path.to_path_buf());
        self
    }

    /// Resolve the model + dataset from the config and run.
    pub fn run(&mut self) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        cfg.validate()?;
        let model = self.rt.model(&cfg.model)?.clone();
        let dataset = tasks::generate(&cfg.task, cfg.seed)?;
        self.run_on(&model, &dataset)
    }

    /// Run against an explicit dataset (paired-comparison harnesses
    /// share one dataset across methods and worker counts).
    ///
    /// NOTE: [`run_slice`](DpTrainer::run_slice) re-implements this
    /// loop's per-step arithmetic on a single representative replica;
    /// any change to the step math here (noise chunking, fold order,
    /// refresh/divergence policy) must be mirrored there — the
    /// bit-identity between the two is asserted by `tests/jobs.rs`
    /// against this method directly, so a one-sided edit fails CI.
    pub fn run_on(&mut self, model: &ModelInfo, dataset: &Dataset) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        cfg.validate()?;
        let n = cfg.workers.max(1);
        let Some(rule) = dp_rule(&cfg.optimizer) else {
            bail!(
                "data-parallel training supports the mezo/smezo/smezo_large/rmezo/\
                 zo_mom/zo_adam/zo_adamu family, not '{}' (use the serial trainer)",
                cfg.optimizer
            );
        };
        if model.batch % n != 0 {
            bail!("workers {n} must divide the model batch size {}", model.batch);
        }
        let backend = self.rt.backend();
        let t_total = Instant::now();

        // ---- setup ---------------------------------------------------------
        let params = trainer::resolve_initial_params(self.rt, &cfg, &self.initial_override, model)?;
        let mut thresholds = backend.thresholds(model, &params, cfg.hypers.sparsity)?;
        let logits = LogitsExec::load(self.rt, model)?;
        let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;
        let p = model.n_params;
        let rows_per = model.batch / n;
        let shard_tok = rows_per * model.seq_len;
        let eps = cfg.hypers.eps;

        // N full replicas (parameters + optimizer slots, zero-initialized
        // like the serial trainer's packed state); seed-sync keeps both
        // blocks bit-identical forever, which the drift check asserts
        let slot_len = dp_slot_len(&cfg.optimizer, p);
        let replicas: Vec<Mutex<(Vec<f32>, Vec<f32>)>> =
            (0..n).map(|_| Mutex::new((params.clone(), vec![0.0f32; slot_len]))).collect();

        let mut journal = match &self.journal_path {
            Some(path) => Some(JournalWriter::create(
                path,
                vec![
                    ("label", Json::Str(cfg.label())),
                    ("model", Json::Str(cfg.model.clone())),
                    ("task", Json::Str(cfg.task.clone())),
                    ("optimizer", Json::Str(cfg.optimizer.clone())),
                    ("workers", Json::Num(n as f64)),
                    ("seed", Json::Num(cfg.seed as f64)),
                    ("steps", Json::Num(cfg.steps as f64)),
                    ("mask_refresh", Json::Num(self.mask_refresh as f64)),
                    // bit-exact fingerprint of the run's initial params;
                    // replay refuses a different base (see replay_full)
                    ("init_fnv", Json::Str(params_fingerprint(&params))),
                    // the hypers replay needs; check_compatible() verifies
                    // them against the replaying config
                    ("lr", Json::Num(cfg.hypers.lr as f64)),
                    ("eps", Json::Num(cfg.hypers.eps as f64)),
                    ("sparsity", Json::Num(cfg.hypers.sparsity as f64)),
                    // slot-stateful replay (zo_mom/zo_adam) needs the
                    // moment hypers too
                    ("beta1", Json::Num(cfg.hypers.beta1 as f64)),
                    ("beta2", Json::Num(cfg.hypers.beta2 as f64)),
                    ("adam_eps", Json::Num(cfg.hypers.adam_eps as f64)),
                ],
            )?),
            None => None,
        };

        // ---- loop ----------------------------------------------------------
        let mut curve = Vec::new();
        let mut train_losses = Vec::with_capacity(cfg.steps);
        let mut ema = Ema::new(0.95);
        let mut diverged = false;
        let mut step_seconds = 0.0f64;
        let mut mask_epoch = 0u32;

        for t in 0..cfg.steps {
            let batch = loader.next_batch();
            let seed = (cfg.seed as u32, t as u32);
            let sp = crate::obs::span("dp.step");
            let _mem = crate::obs::mem_scope("train.step");

            if self.mask_refresh > 0 && t > 0 && t % self.mask_refresh == 0 {
                let _rsp = crate::obs::span("train.threshold_refresh");
                let _rmem = crate::obs::mem_scope("train.threshold_refresh");
                let master = replicas[0].lock().unwrap();
                thresholds = backend.thresholds(model, &master.0, cfg.hypers.sparsity)?;
                mask_epoch += 1;
            }

            // shared step noise, generated once and sharded across the
            // pool (chunk boundaries are free to vary: zo_noise is
            // chunk-invariant by the counter-PRNG offset contract)
            let chunks = self.pool.parallelism().min(p).max(1);
            let chunk_len = (p + chunks - 1) / chunks;
            let parts = self.pool.scatter(chunks, |c| {
                let lo = (c * chunk_len).min(p);
                let hi = ((c + 1) * chunk_len).min(p);
                if lo >= hi {
                    Ok(Vec::new())
                } else {
                    backend.zo_noise(model, seed, lo, hi)
                }
            });
            let mut z = Vec::with_capacity(p);
            for part in parts {
                z.extend(part?);
            }

            // step mask from the unperturbed (identical) replicas
            let mask = {
                let master = replicas[0].lock().unwrap();
                backend.zo_mask(model, &cfg.optimizer, &cfg.hypers, &thresholds, &master.0)?
            };
            let masked_frac = match &mask {
                Some(m) => m.iter().map(|&x| x as usize).sum::<usize>() as f32 / p as f32,
                None => 1.0,
            };

            // phase A: perturb replicas +eps/-2eps, score microbatch shards
            let shard_losses = self.pool.scatter(n, |j| -> Result<(Vec<f64>, Vec<f64>)> {
                let mut replica = replicas[j].lock().unwrap();
                let tokens = &batch.tokens[j * shard_tok..(j + 1) * shard_tok];
                let labels = &batch.labels[j * rows_per..(j + 1) * rows_per];
                perturb_in_place(&mut replica.0, &z, mask.as_deref(), eps);
                let rows_plus = backend.row_losses(model, &replica.0, tokens, labels)?;
                perturb_in_place(&mut replica.0, &z, mask.as_deref(), -2.0 * eps);
                let rows_minus = backend.row_losses(model, &replica.0, tokens, labels)?;
                Ok((rows_plus, rows_minus))
            });

            // all-reduce: canonical row-order f64 fold, then the same f32
            // casts a serial step performs — worker-count-invariant bits
            let ar_mem = crate::obs::mem_scope("dp.allreduce");
            let mut sum_plus = 0.0f64;
            let mut sum_minus = 0.0f64;
            let mut rows = 0usize;
            for shard in shard_losses {
                let (rp, rm) = shard?;
                for &v in &rp {
                    sum_plus += v;
                }
                for &v in &rm {
                    sum_minus += v;
                }
                rows += rp.len();
            }
            let l_plus = (sum_plus / rows.max(1) as f64) as f32;
            let l_minus = (sum_minus / rows.max(1) as f64) as f32;
            let g = (l_plus - l_minus) / (2.0 * eps);
            let train_loss = 0.5 * (l_plus + l_minus);
            ar_mem.end();

            if !g.is_finite() {
                // a NaN scalar would both poison every replica and break
                // the JSON journal; stop before exchanging it
                crate::info!("[{}] DIVERGED at step {t} (non-finite g)", cfg.label());
                diverged = true;
                break;
            }
            if let Some(w) = &mut journal {
                w.record(&StepRecord { step: t as u32, seed, scalar: g, mask_epoch })?;
            }
            if let Some(rec) = &self.recorder {
                rec.record_step(t as u32, train_loss, g, mask.as_deref(), p as u64, mask_epoch);
            }

            // phase B: identical masked update on every replica — the
            // whole exchange was the scalar g. Slot-stateful rules update
            // each replica's own slot block from the same scalar, so
            // slots stay bit-identical across replicas too.
            let norms = self.pool.scatter(n, |j| {
                let mut replica = replicas[j].lock().unwrap();
                let (params, slots) = &mut *replica;
                apply_update(params, slots, &z, mask.as_deref(), &cfg.hypers, g, rule)
            });
            let update_norm_sq = norms.first().copied().unwrap_or(0.0);
            step_seconds += sp.end();
            crate::obs::counter("train_steps_total", &[]).inc();

            train_losses.push(train_loss);
            let smoothed = ema.update(train_loss as f64);
            if cfg.log_every > 0 && t % (cfg.log_every * 10) == 0 {
                crate::debug!(
                    "[{} dp{n}] step {t}/{} loss {train_loss:.4} (ema {smoothed:.4}) g {g:.3} \
                     masked {masked_frac:.3} |u|^2 {update_norm_sq:.3e}",
                    cfg.label(),
                    cfg.steps,
                );
            }

            // divergence detection (Fig. 2a), after the update like the
            // serial trainer
            if !train_loss.is_finite() || train_loss > DIVERGENCE_LOSS {
                crate::info!("[{}] DIVERGED at step {t} (loss {train_loss})", cfg.label());
                diverged = true;
                break;
            }

            // periodic dev evaluation, sharded over the same pool
            let is_last = t + 1 == cfg.steps;
            if (cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0) || is_last {
                let p_host = replicas[0].lock().unwrap().0.clone();
                let dev = eval::evaluate_sharded(
                    self.rt,
                    self.pool,
                    &logits,
                    &p_host,
                    &dataset.dev,
                    cfg.eval_cap,
                )?;
                curve.push(CurvePoint {
                    step: t + 1,
                    dev_accuracy: dev.accuracy(),
                    dev_loss: dev.mean_loss,
                    train_loss_ema: smoothed,
                });
                if let Some(w) = &mut journal {
                    w.flush()?;
                }
                crate::info!(
                    "[{} dp{n}] step {}/{} dev acc {:.3} loss {:.3}",
                    cfg.label(),
                    t + 1,
                    cfg.steps,
                    dev.accuracy(),
                    dev.mean_loss
                );
            }
        }

        // ---- final check + evaluation --------------------------------------
        let (params, slots) = replicas[0].lock().unwrap().clone();
        for (j, replica) in replicas.iter().enumerate().skip(1) {
            let replica = replica.lock().unwrap();
            let drifted = replica.0.iter().zip(&params).any(|(a, b)| a.to_bits() != b.to_bits())
                || replica.1.iter().zip(&slots).any(|(a, b)| a.to_bits() != b.to_bits());
            if drifted {
                bail!("replica {j} drifted from replica 0 — seed-sync invariant broken");
            }
        }
        let final_dev = curve.last().map(|c| EvalResult { n: 0, correct: 0, mean_loss: c.dev_loss });
        let test = if self.eval_test && !diverged {
            Some(eval::evaluate_sharded(self.rt, self.pool, &logits, &params, &dataset.test, 0)?)
        } else {
            None
        };
        if let Some(w) = &mut journal {
            w.flush()?;
        }
        let steps_run = train_losses.len();
        Ok(TrainResult {
            config_label: cfg.label(),
            steps_run,
            curve,
            final_dev,
            test,
            diverged,
            wallclock_s: t_total.elapsed().as_secs_f64(),
            sec_per_step: step_seconds / steps_run.max(1) as f64,
            params,
            train_losses,
        })
    }
}

// ---------------------------------------------------------------------------
// slice-resumable entry point (the job orchestrator's training primitive)
// ---------------------------------------------------------------------------

/// The complete state of a paused slice-run between slices: everything
/// step `t+1` needs to continue **bit-identically** to an uninterrupted
/// run. Because seed-sync keeps every replica identical at step
/// boundaries, one `(params, slots)` copy represents all N workers; the
/// thresholds/mask_epoch pair carries the §8.2 refresh state that is
/// otherwise implicit in the live trainer's locals.
#[derive(Debug, Clone)]
pub struct SliceState {
    /// optimizer steps completed so far (the next step index)
    pub step: usize,
    /// threshold generation in effect (increments at each mask refresh)
    pub mask_epoch: u32,
    /// the parameters after `step` steps
    pub params: Vec<f32>,
    /// optimizer slots after `step` steps (empty for the SGD family)
    pub slots: Vec<f32>,
    /// §8.2 magnitude thresholds in effect for the next step's mask
    pub thresholds: Vec<f32>,
}

/// What one [`DpTrainer::run_slice`] call accomplished.
#[derive(Debug, Clone, Copy)]
pub struct SliceReport {
    /// steps executed in this slice
    pub steps_run: usize,
    /// the run is finished (all configured steps done, or diverged)
    pub done: bool,
    /// divergence detection fired inside this slice
    pub diverged: bool,
    /// training loss of the last completed step (NaN if none ran)
    pub last_loss: f32,
}

impl<'rt> DpTrainer<'rt> {
    /// Validate the config for slice running and return the update rule.
    fn slice_rule(&self, model: &ModelInfo) -> Result<DpRule> {
        self.cfg.validate()?;
        let Some(rule) = dp_rule(&self.cfg.optimizer) else {
            bail!(
                "slice-run training supports the mezo/smezo/smezo_large/rmezo/\
                 zo_mom/zo_adam/zo_adamu family, not '{}'",
                self.cfg.optimizer
            );
        };
        let n = self.cfg.workers.max(1);
        if model.batch % n != 0 {
            bail!("workers {n} must divide the model batch size {}", model.batch);
        }
        Ok(rule)
    }

    /// Start a fresh slice-run from `base`: compute initial thresholds
    /// and create the step journal (header identical to [`run_on`]'s, so
    /// [`replay_full`](protocol::replay_full) and the serving layer's
    /// adapter materialization work on job journals unchanged).
    ///
    /// [`run_on`]: DpTrainer::run_on
    pub fn begin_slices(&self, model: &ModelInfo, base: Vec<f32>) -> Result<SliceState> {
        self.slice_rule(model)?;
        let cfg = &self.cfg;
        if base.len() != model.n_params {
            bail!("begin_slices: base has {} params, model expects {}", base.len(), model.n_params);
        }
        let Some(path) = &self.journal_path else {
            bail!("slice-run training needs a journal path (checkpoint/resume lives there)");
        };
        let thresholds = self.rt.backend().thresholds(model, &base, cfg.hypers.sparsity)?;
        let mut journal = JournalWriter::create(
            path,
            vec![
                ("label", Json::Str(cfg.label())),
                ("model", Json::Str(cfg.model.clone())),
                ("task", Json::Str(cfg.task.clone())),
                ("optimizer", Json::Str(cfg.optimizer.clone())),
                ("workers", Json::Num(cfg.workers.max(1) as f64)),
                ("seed", Json::Num(cfg.seed as f64)),
                ("steps", Json::Num(cfg.steps as f64)),
                ("mask_refresh", Json::Num(self.mask_refresh as f64)),
                ("init_fnv", Json::Str(params_fingerprint(&base))),
                ("lr", Json::Num(cfg.hypers.lr as f64)),
                ("eps", Json::Num(cfg.hypers.eps as f64)),
                ("sparsity", Json::Num(cfg.hypers.sparsity as f64)),
                ("beta1", Json::Num(cfg.hypers.beta1 as f64)),
                ("beta2", Json::Num(cfg.hypers.beta2 as f64)),
                ("adam_eps", Json::Num(cfg.hypers.adam_eps as f64)),
            ],
        )?;
        journal.flush()?;
        let slots = vec![0.0f32; dp_slot_len(&cfg.optimizer, model.n_params)];
        Ok(SliceState { step: 0, mask_epoch: 0, params: base, slots, thresholds })
    }

    /// [`begin_slices`](DpTrainer::begin_slices) from a shared
    /// [`ParamStore`](crate::runtime::store::ParamStore) handle: the one
    /// flat copy made is the vector the returned state owns.
    pub fn begin_slices_store(
        &self,
        model: &ModelInfo,
        base: &crate::runtime::store::ParamStore,
    ) -> Result<SliceState> {
        self.begin_slices(model, base.to_vec())
    }

    /// [`resume_slices`](DpTrainer::resume_slices) from a shared
    /// [`ParamStore`](crate::runtime::store::ParamStore) handle.
    /// Materializes a transient flat copy for the replay rather than
    /// holding a resident base lock across the whole journal replay
    /// (which would convoy in-flight classify checkouts behind it).
    pub fn resume_slices_store(
        &self,
        model: &ModelInfo,
        base: &crate::runtime::store::ParamStore,
    ) -> Result<SliceState> {
        let flat = base.to_vec();
        self.resume_slices(model, &flat)
    }

    /// Rebuild the slice state of a paused run from its journal: replay
    /// the `(seed, g)` stream from `base` (no forward passes) and resume
    /// from the bit-identical parameters, slots, thresholds and epoch the
    /// live run held when it stopped. `base` must be the vector the run
    /// started from — the header's `init_fnv` makes a mismatch a hard
    /// error, not silently wrong training.
    pub fn resume_slices(&self, model: &ModelInfo, base: &[f32]) -> Result<SliceState> {
        self.slice_rule(model)?;
        let Some(path) = &self.journal_path else {
            bail!("resume_slices needs the journal path the run was recording to");
        };
        let (header, records) = protocol::load_journal(path)?;
        let outcome = protocol::replay_full(self.rt, model, &self.cfg, &header, base, &records)?;
        Ok(SliceState {
            step: outcome.steps,
            mask_epoch: outcome.mask_epoch,
            params: outcome.params,
            slots: outcome.slots,
            thresholds: outcome.thresholds,
        })
    }

    /// Advance a slice-run by at most `max_steps` optimizer steps,
    /// appending each step's record to the journal and flushing at the
    /// slice boundary. The arithmetic mirrors [`run_on`] expression for
    /// expression (shared noise from the step seed, mask from the
    /// unperturbed params, per-row f64 loss fold in canonical order, the
    /// fused masked update), so a run chopped into arbitrary slices —
    /// including across `--mask-refresh` epoch boundaries — lands on the
    /// **bit-identical** final parameters of an uninterrupted run
    /// (`tests/jobs.rs` locks this).
    ///
    /// `stop` is polled at every step boundary: when it returns true the
    /// slice ends early with a consistent state/journal pair (the
    /// cooperative mid-slice cancel the job orchestrator uses) — never
    /// mid-step, so the journal always describes exactly the updates
    /// that were applied.
    ///
    /// [`run_on`]: DpTrainer::run_on
    pub fn run_slice(
        &self,
        model: &ModelInfo,
        dataset: &Dataset,
        state: &mut SliceState,
        max_steps: usize,
        stop: Option<&dyn Fn() -> bool>,
    ) -> Result<SliceReport> {
        let rule = self.slice_rule(model)?;
        let cfg = &self.cfg;
        if state.params.len() != model.n_params {
            bail!(
                "run_slice: state has {} params, model expects {}",
                state.params.len(),
                model.n_params
            );
        }
        let end = cfg.steps.min(state.step + max_steps);
        if state.step >= end {
            return Ok(SliceReport {
                steps_run: 0,
                done: state.step >= cfg.steps,
                diverged: false,
                last_loss: f32::NAN,
            });
        }
        let Some(path) = &self.journal_path else {
            bail!("run_slice needs the journal path the run records to");
        };
        let backend = self.rt.backend();
        let n = cfg.workers.max(1);
        let p = model.n_params;
        let rows_per = model.batch / n;
        let shard_tok = rows_per * model.seq_len;
        let eps = cfg.hypers.eps;
        let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;
        loader.skip(state.step);
        let mut journal = JournalWriter::append(path)?;

        // lease remote worker sessions for this slice (none parked, or no
        // hub configured, leaves every shard local — bit-identical either
        // way). The lease streams the journal's committed records as the
        // catch-up, so it happens after `append` truncated any torn tail.
        let mut remotes: Vec<RemoteWorker> = Vec::new();
        if let Some(handle) = &self.remote {
            if n > 1 {
                let (header, records) = protocol::load_journal(path)?;
                if records.len() == state.step {
                    remotes = handle.hub.lease(
                        n - 1,
                        n,
                        &header,
                        handle.data_seed,
                        &train_fingerprint(&dataset.train),
                        &records,
                        handle.trace_id,
                    );
                    if !remotes.is_empty() {
                        crate::info!(
                            "[{}] slice at step {}: {} remote worker(s) leased",
                            cfg.label(),
                            state.step,
                            remotes.len()
                        );
                    }
                } else {
                    crate::info!(
                        "[{}] journal holds {} records but state is at step {} — \
                         keeping this slice local",
                        cfg.label(),
                        records.len(),
                        state.step
                    );
                }
            }
        }
        // remotes own the TOP shard ranks (descending from n-1), so the
        // local ranks stay the contiguous prefix 0..n_local and the
        // canonical rank-order fold below is a simple concatenation
        let n_local = n - remotes.len();
        let trace = self.remote.as_ref().map_or(0, |h| h.trace_id);
        // attribution data for the flight recorder (captured before any
        // error path drains the sessions)
        let slice_t0 = Instant::now();
        let remote_ranks: Vec<u32> = remotes.iter().map(|rw| rw.rank).collect();

        let mut steps_run = 0usize;
        let mut diverged = false;
        let mut last_loss = f32::NAN;
        // a remote failing mid-step: finish bookkeeping (journal flush),
        // sever every remote session, and surface the marked error so the
        // scheduler re-queues — journal replay makes the retry bit-exact
        let mut hard_err: Option<anyhow::Error> = None;

        'steps: for t in state.step..end {
            if stop.map(|s| s()).unwrap_or(false) {
                break;
            }
            let batch = loader.next_batch();
            let seed = (cfg.seed as u32, t as u32);
            let _step_span = crate::obs::span("dp.step");
            let _step_mem = crate::obs::mem_scope("train.step");

            if self.mask_refresh > 0 && t > 0 && t % self.mask_refresh == 0 {
                {
                    let _rsp = crate::obs::span("train.threshold_refresh");
                    let _rmem = crate::obs::mem_scope("train.threshold_refresh");
                    state.thresholds =
                        backend.thresholds(model, &state.params, cfg.hypers.sparsity)?;
                }
                state.mask_epoch += 1;
                for rw in remotes.iter_mut() {
                    if let Err(e) = rw.send(&Frame::Refresh { mask_epoch: state.mask_epoch }) {
                        hard_err = Some(e);
                        break 'steps;
                    }
                }
            }

            // kick remote phase A off before the local compute so both
            // sides' forward passes overlap
            for rw in remotes.iter_mut() {
                if let Err(e) = rw.send(&Frame::PhaseA {
                    step: t as u32,
                    seed,
                    mask_epoch: state.mask_epoch,
                }) {
                    hard_err = Some(e);
                    break 'steps;
                }
            }

            // shared step noise, sharded across the pool exactly like the
            // live trainer (chunk-invariant by the counter-PRNG contract)
            let chunks = self.pool.parallelism().min(p).max(1);
            let chunk_len = (p + chunks - 1) / chunks;
            let parts = self.pool.scatter(chunks, |c| {
                let lo = (c * chunk_len).min(p);
                let hi = ((c + 1) * chunk_len).min(p);
                if lo >= hi {
                    Ok(Vec::new())
                } else {
                    backend.zo_noise(model, seed, lo, hi)
                }
            });
            let mut z = Vec::with_capacity(p);
            for part in parts {
                z.extend(part?);
            }

            let mask = backend.zo_mask(
                model,
                &cfg.optimizer,
                &cfg.hypers,
                &state.thresholds,
                &state.params,
            )?;

            // phase A on the one representative replica: every live
            // replica (local or across TCP) holds these exact bits, so
            // perturbing once and sharding the row losses over the local
            // ranks reproduces the N-replica pass bit-for-bit
            perturb_in_place(&mut state.params, &z, mask.as_deref(), eps);
            let params_plus: &[f32] = &state.params;
            let shard_plus = self.pool.scatter(n_local, |j| -> Result<Vec<f64>> {
                let tokens = &batch.tokens[j * shard_tok..(j + 1) * shard_tok];
                let labels = &batch.labels[j * rows_per..(j + 1) * rows_per];
                backend.row_losses(model, params_plus, tokens, labels)
            });
            perturb_in_place(&mut state.params, &z, mask.as_deref(), -2.0 * eps);
            let params_minus: &[f32] = &state.params;
            let shard_minus = self.pool.scatter(n_local, |j| -> Result<Vec<f64>> {
                let tokens = &batch.tokens[j * shard_tok..(j + 1) * shard_tok];
                let labels = &batch.labels[j * rows_per..(j + 1) * rows_per];
                backend.row_losses(model, params_minus, tokens, labels)
            });

            // collect the remote shards' row losses (sessions were leased
            // in descending rank order; sort back to ascending for the fold)
            let mut remote_losses: Vec<(usize, Vec<f64>, Vec<f64>)> =
                Vec::with_capacity(remotes.len());
            for rw in remotes.iter_mut() {
                match rw.recv_losses(t as u32, rows_per) {
                    Ok((plus, minus)) => remote_losses.push((rw.rank, plus, minus)),
                    Err(e) => {
                        hard_err = Some(e);
                        break 'steps;
                    }
                }
            }
            remote_losses.sort_by_key(|(rank, ..)| *rank);

            // all-reduce: canonical rank-then-row-order f64 fold (local
            // ranks 0..n_local, then remote ranks ascending — exactly the
            // all-local rank order), then the same f32 casts the live
            // step performs
            let ar_mem = crate::obs::mem_scope("dp.allreduce");
            let mut sum_plus = 0.0f64;
            let mut sum_minus = 0.0f64;
            let mut rows = 0usize;
            for shard in shard_plus {
                let rp = shard?;
                for &v in &rp {
                    sum_plus += v;
                }
                rows += rp.len();
            }
            for (_, plus, _) in &remote_losses {
                for &v in plus {
                    sum_plus += v;
                }
                rows += plus.len();
            }
            for shard in shard_minus {
                for &v in &shard? {
                    sum_minus += v;
                }
            }
            for (_, _, minus) in &remote_losses {
                for &v in minus {
                    sum_minus += v;
                }
            }
            let l_plus = (sum_plus / rows.max(1) as f64) as f32;
            let l_minus = (sum_minus / rows.max(1) as f64) as f32;
            let g = (l_plus - l_minus) / (2.0 * eps);
            let train_loss = 0.5 * (l_plus + l_minus);
            ar_mem.end();

            if !g.is_finite() {
                // undo the net -eps offset so the state isn't silently
                // left at a perturbed point (exactness is moot — the g
                // is poison and the job fails — but a roughly-restored
                // state makes post-mortems readable). The step is NOT
                // journaled and callers must not checkpoint this state:
                // the journal stays the authoritative resume source.
                perturb_in_place(&mut state.params, &z, mask.as_deref(), eps);
                crate::info!("[{}] job DIVERGED at step {t} (non-finite g)", cfg.label());
                diverged = true;
                // the remotes are mid-exchange (phase A answered, no
                // commit): discard their sessions — the sockets may hold
                // half-read frames, so sever rather than re-park; the
                // workers reconnect fresh
                for mut rw in remotes.drain(..) {
                    let _ = rw.send(&Frame::Abort {
                        reason: format!("run diverged at step {t} (non-finite g)"),
                    });
                }
                break;
            }
            let rec = StepRecord {
                step: t as u32,
                seed,
                scalar: g,
                mask_epoch: state.mask_epoch,
            };
            journal.record(&rec)?;

            // phase B: the identical fused masked update
            apply_update(
                &mut state.params,
                &mut state.slots,
                &z,
                mask.as_deref(),
                &cfg.hypers,
                g,
                rule,
            );
            state.step = t + 1;
            steps_run += 1;
            last_loss = train_loss;
            crate::obs::counter("train_steps_total", &[]).inc();
            if let Some(recorder) = &self.recorder {
                recorder.record_step(
                    t as u32,
                    train_loss,
                    g,
                    mask.as_deref(),
                    p as u64,
                    state.mask_epoch,
                );
            }

            // broadcast the committed record; remote replicas apply the
            // identical update from it. A send failure after the local
            // commit is fine: journal and state agree at t+1, and the
            // requeued slice resumes from the journal.
            for rw in remotes.iter_mut() {
                if let Err(e) = rw.send(&Frame::Step(rec, trace)) {
                    hard_err = Some(e);
                    break 'steps;
                }
            }

            if !train_loss.is_finite() || train_loss > DIVERGENCE_LOSS {
                crate::info!("[{}] job DIVERGED at step {t} (loss {train_loss})", cfg.label());
                diverged = true;
                break;
            }
        }

        // flush before surfacing any transport error: the journal must
        // durably describe exactly the updates that were applied, or the
        // re-queued retry would re-run a committed step
        let flushed = journal.flush();
        if let Some(e) = hard_err {
            // sever every remote session (never re-park a socket that may
            // hold half-exchanged frames); survivors reconnect fresh
            drop(remotes);
            return Err(e);
        }
        flushed?;

        if diverged {
            // terminal for the job: discard any remaining remote sessions
            for mut rw in remotes.drain(..) {
                let _ = rw.send(&Frame::Abort { reason: "run diverged".into() });
            }
        } else {
            // end-of-slice drift check: every remote must land on the
            // coordinator's exact parameter bits. A mismatch is a hard
            // error (the seed-sync invariant broke — retrying cannot
            // help); a transport failure here is harmless (the slice is
            // already committed locally) so just sever that session.
            let final_fnv = params_fingerprint(&state.params);
            for rw in remotes.drain(..) {
                let rank = rw.rank;
                match rw.finish(state.step as u32, &final_fnv) {
                    Ok(()) => {}
                    Err(e) if is_worker_lost(&e) => {
                        crate::info!(
                            "[{}] remote rank {rank} lost at finish ({e:#}); severed",
                            cfg.label()
                        );
                    }
                    Err(e) => return Err(e),
                }
            }
        }

        if let Some(recorder) = &self.recorder {
            recorder.note_slice(slice_t0.elapsed().as_secs_f64(), steps_run as u64, &remote_ranks);
        }

        Ok(SliceReport {
            steps_run,
            done: diverged || state.step >= cfg.steps,
            diverged,
            last_loss,
        })
    }
}
