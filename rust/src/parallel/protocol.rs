//! The seed-sync step-exchange protocol.
//!
//! A full MeZO/Sparse-MeZO step is completely described by its
//! `(perturbation seed, projected-gradient scalar)` pair: every worker
//! regenerates the same `z` from the seed, computes the same mask from
//! its (identical) replica, and applies the same masked update — so the
//! only state that ever crosses a worker boundary is a [`StepRecord`],
//! a few bytes per step, never a parameter. The same records, appended
//! to a JSONL *journal*, make a run replayable: [`replay`] re-walks the
//! perturb/update arithmetic from the recorded scalars **without any
//! forward passes** and lands on the bit-identical final parameters —
//! the crash-recovery and audit path (`tests/parallel.rs` locks this).

use std::path::Path;

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::runtime::{ModelInfo, Runtime};
use crate::util::json::Json;
use crate::util::log::{read_jsonl, JsonlWriter};

use super::dp::{apply_sgd_update, perturb_in_place};

/// One step's exchange record — everything a peer (or a future resume)
/// needs to reproduce the update exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    /// optimizer step index
    pub step: u32,
    /// the step's perturbation seed (shared by every worker; Alg. 2)
    pub seed: (u32, u32),
    /// the all-reduced projected-gradient scalar `g`
    pub scalar: f32,
    /// threshold generation this step's mask was computed under
    /// (increments when the DP trainer refreshes §8.2 thresholds)
    pub mask_epoch: u32,
}

impl StepRecord {
    /// Serialize to one journal line. `f32 -> f64` is exact, so the
    /// scalar round-trips bit-for-bit through JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("seed_lo", Json::Num(self.seed.0 as f64)),
            ("seed_hi", Json::Num(self.seed.1 as f64)),
            ("g", Json::Num(self.scalar as f64)),
            ("mask_epoch", Json::Num(self.mask_epoch as f64)),
        ])
    }

    /// Parse one journal line.
    pub fn from_json(j: &Json) -> Result<StepRecord> {
        Ok(StepRecord {
            step: j.req("step")?.as_usize()? as u32,
            seed: (
                j.req("seed_lo")?.as_usize()? as u32,
                j.req("seed_hi")?.as_usize()? as u32,
            ),
            scalar: j.req("g")?.as_f64()? as f32,
            mask_epoch: j.req("mask_epoch")?.as_usize()? as u32,
        })
    }
}

/// Journal format tag carried in the header line.
pub const JOURNAL_KIND: &str = "dp-journal";

/// Append-only step journal: one header line, then one line per step.
pub struct JournalWriter {
    w: JsonlWriter,
}

impl JournalWriter {
    /// Create the journal and write its header. `meta` fields are merged
    /// into the header object alongside the `kind` tag.
    pub fn create(path: &Path, meta: Vec<(&str, Json)>) -> Result<JournalWriter> {
        let mut fields = vec![("kind", Json::Str(JOURNAL_KIND.into()))];
        fields.extend(meta);
        let mut w = JsonlWriter::create(path)?;
        w.write(&Json::obj(fields))?;
        Ok(JournalWriter { w })
    }

    /// Append one step record.
    pub fn record(&mut self, rec: &StepRecord) -> Result<()> {
        self.w.write(&rec.to_json())
    }

    /// Flush buffered records to disk (called at eval boundaries and at
    /// the end of the run so a crash loses at most one eval interval).
    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()
    }
}

/// Read a journal back: `(header, records)`.
pub fn load_journal(path: &Path) -> Result<(Json, Vec<StepRecord>)> {
    let lines = read_jsonl(path)?;
    let Some((header, rest)) = lines.split_first() else {
        bail!("journal {} is empty", path.display());
    };
    let kind_ok = header
        .get("kind")
        .map(|k| k.as_str().ok() == Some(JOURNAL_KIND))
        .unwrap_or(false);
    if !kind_ok {
        bail!("journal {} has no '{JOURNAL_KIND}' header line", path.display());
    }
    let records = rest.iter().map(StepRecord::from_json).collect::<Result<Vec<_>>>()?;
    Ok((header.clone(), records))
}

/// Verify a journal `header` was written by a run compatible with
/// `cfg`: same model/task/optimizer and bit-identical lr/eps/sparsity.
/// Replaying under a mismatched config would confidently produce wrong
/// parameters, so [`replay`] makes this a hard error up front.
pub fn check_compatible(header: &Json, cfg: &TrainConfig) -> Result<()> {
    for (key, want) in [
        ("model", cfg.model.as_str()),
        ("task", cfg.task.as_str()),
        ("optimizer", cfg.optimizer.as_str()),
    ] {
        let got = header.req(key)?.as_str()?;
        if got != want {
            bail!("journal {key} '{got}' does not match replay config '{want}'");
        }
    }
    for (key, want) in [
        ("lr", cfg.hypers.lr),
        ("eps", cfg.hypers.eps),
        ("sparsity", cfg.hypers.sparsity),
    ] {
        let got = header.req(key)?.as_f64()? as f32;
        if got.to_bits() != want.to_bits() {
            bail!("journal {key} {got} does not match replay config {want}");
        }
    }
    let seed = header.req("seed")?.as_f64()? as u64;
    if seed != cfg.seed {
        bail!("journal seed {seed} does not match replay config {}", cfg.seed);
    }
    Ok(())
}

/// Re-walk a journal from `init` parameters: regenerate each step's mask
/// and noise, then apply the recorded scalar through the *identical*
/// fused perturb/update arithmetic the live run used — no forward
/// passes, so replay is orders of magnitude faster than training, and
/// the result is bit-identical to the live run's final parameters.
/// `header` (from [`load_journal`]) is validated against `cfg` first so
/// a mismatched config is an error, not silently wrong parameters.
pub fn replay(
    rt: &Runtime,
    model: &ModelInfo,
    cfg: &TrainConfig,
    header: &Json,
    init: &[f32],
    records: &[StepRecord],
) -> Result<Vec<f32>> {
    check_compatible(header, cfg)?;
    if init.len() != model.n_params {
        bail!("replay: init has {} params, model expects {}", init.len(), model.n_params);
    }
    let backend = rt.backend();
    let mut params = init.to_vec();
    let mut thresholds = backend.thresholds(model, &params, cfg.hypers.sparsity)?;
    let mut mask_epoch = 0u32;
    for rec in records {
        if rec.mask_epoch != mask_epoch {
            // the live run refreshed §8.2 thresholds at this step's start
            thresholds = backend.thresholds(model, &params, cfg.hypers.sparsity)?;
            mask_epoch = rec.mask_epoch;
        }
        let mask = backend.zo_mask(model, &cfg.optimizer, &cfg.hypers, &thresholds, &params)?;
        let z = backend.zo_noise(model, rec.seed, 0, model.n_params)?;
        let eps = cfg.hypers.eps;
        perturb_in_place(&mut params, &z, mask.as_deref(), eps);
        perturb_in_place(&mut params, &z, mask.as_deref(), -2.0 * eps);
        apply_sgd_update(&mut params, &z, mask.as_deref(), eps, cfg.hypers.lr, rec.scalar);
    }
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_round_trips_exactly() {
        let rec = StepRecord {
            step: 41,
            seed: (0xDEAD_BEEF, 7),
            scalar: -3.724_119e-2,
            mask_epoch: 2,
        };
        let back = StepRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.scalar.to_bits(), rec.scalar.to_bits());
    }

    #[test]
    fn journal_round_trips_through_disk() {
        let dir = std::env::temp_dir().join(format!("smz_journal_{}", std::process::id()));
        let path = dir.join("run.journal.jsonl");
        let recs: Vec<StepRecord> = (0..5)
            .map(|t| StepRecord {
                step: t,
                seed: (9, t),
                scalar: t as f32 * 0.125,
                mask_epoch: t / 3,
            })
            .collect();
        {
            let mut w =
                JournalWriter::create(&path, vec![("label", Json::Str("unit".into()))]).unwrap();
            for r in &recs {
                w.record(r).unwrap();
            }
            w.flush().unwrap();
        }
        let (header, back) = load_journal(&path).unwrap();
        assert_eq!(header.req("label").unwrap().as_str().unwrap(), "unit");
        assert_eq!(back, recs);
        std::fs::remove_dir_all(&dir).ok();
    }
}
