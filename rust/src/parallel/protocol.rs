//! The seed-sync step-exchange protocol.
//!
//! A full MeZO/Sparse-MeZO step is completely described by its
//! `(perturbation seed, projected-gradient scalar)` pair: every worker
//! regenerates the same `z` from the seed, computes the same mask from
//! its (identical) replica, and applies the same masked update — so the
//! only state that ever crosses a worker boundary is a [`StepRecord`],
//! a few bytes per step, never a parameter. The same records, appended
//! to a JSONL *journal*, make a run replayable: [`replay`] re-walks the
//! perturb/update arithmetic from the recorded scalars **without any
//! forward passes** and lands on the bit-identical final parameters —
//! the crash-recovery and audit path (`tests/parallel.rs` locks this).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::runtime::{ModelInfo, Runtime};
use crate::util::bitset;
use crate::util::json::Json;
use crate::util::log::JsonlWriter;

use super::dp::{apply_update, dp_rule, dp_slot_len, perturb_in_place};

/// One step's exchange record — everything a peer (or a future resume)
/// needs to reproduce the update exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    /// optimizer step index
    pub step: u32,
    /// the step's perturbation seed (shared by every worker; Alg. 2)
    pub seed: (u32, u32),
    /// the all-reduced projected-gradient scalar `g`
    pub scalar: f32,
    /// threshold generation this step's mask was computed under
    /// (increments when the DP trainer refreshes §8.2 thresholds)
    pub mask_epoch: u32,
}

impl StepRecord {
    /// Serialize to one journal line. `f32 -> f64` is exact, so the
    /// scalar round-trips bit-for-bit through JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("seed_lo", Json::Num(self.seed.0 as f64)),
            ("seed_hi", Json::Num(self.seed.1 as f64)),
            ("g", Json::Num(self.scalar as f64)),
            ("mask_epoch", Json::Num(self.mask_epoch as f64)),
        ])
    }

    /// Parse one journal line.
    pub fn from_json(j: &Json) -> Result<StepRecord> {
        Ok(StepRecord {
            step: j.req("step")?.as_usize()? as u32,
            seed: (
                j.req("seed_lo")?.as_usize()? as u32,
                j.req("seed_hi")?.as_usize()? as u32,
            ),
            scalar: j.req("g")?.as_f64()? as f32,
            mask_epoch: j.req("mask_epoch")?.as_usize()? as u32,
        })
    }
}

/// Journal format tag carried in the header line.
pub const JOURNAL_KIND: &str = "dp-journal";

/// Append-only step journal: one header line, then one line per step.
pub struct JournalWriter {
    w: JsonlWriter,
}

impl JournalWriter {
    /// Create the journal and write its header. `meta` fields are merged
    /// into the header object alongside the `kind` tag.
    pub fn create(path: &Path, meta: Vec<(&str, Json)>) -> Result<JournalWriter> {
        let mut fields = vec![("kind", Json::Str(JOURNAL_KIND.into()))];
        fields.extend(meta);
        let mut w = JsonlWriter::create(path)?;
        w.write(&Json::obj(fields))?;
        Ok(JournalWriter { w })
    }

    /// Append one step record.
    pub fn record(&mut self, rec: &StepRecord) -> Result<()> {
        self.w.write(&rec.to_json())
    }

    /// Reopen an existing journal for appending — the job orchestrator's
    /// slice-resume path. Only the header line is validated (O(1) in
    /// the journal's length — a full parse per slice would make
    /// orchestration cost quadratic in job length); appending to a
    /// non-journal file is still an error rather than silent corruption.
    ///
    /// A torn trailing record (crash mid-flush; records contain no raw
    /// newlines, so a tear is exactly "the file does not end in `\n`")
    /// is truncated away first. [`load_journal`] merely *tolerates* the
    /// tear on read; appending after the fragment would fuse it with
    /// the next record into one garbled mid-file line that no later
    /// read could recover from.
    pub fn append(path: &Path) -> Result<JournalWriter> {
        use std::io::{Read, Seek, SeekFrom};
        read_header(path)?;
        let mut f = std::fs::OpenOptions::new().read(true).write(true).open(path)?;
        let len = f.seek(SeekFrom::End(0))?;
        if len > 0 {
            f.seek(SeekFrom::End(-1))?;
            let mut last = [0u8; 1];
            f.read_exact(&mut last)?;
            if last[0] != b'\n' {
                let mut bytes = Vec::with_capacity(len as usize);
                f.seek(SeekFrom::Start(0))?;
                f.read_to_end(&mut bytes)?;
                let cut = bytes.iter().rposition(|&b| b == b'\n').map(|p| p + 1).unwrap_or(0);
                f.set_len(cut as u64)?;
                crate::info!(
                    "journal {}: truncated a torn trailing record before appending \
                     (the step it described was never durable and will be re-run)",
                    path.display()
                );
            }
        }
        drop(f);
        Ok(JournalWriter { w: JsonlWriter::append(path)? })
    }

    /// Flush buffered records to disk (called at eval boundaries and at
    /// the end of the run so a crash loses at most one eval interval).
    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()
    }
}

/// Read and validate only a journal's header line — O(1) in the
/// journal's length, for per-slice checks that must not re-parse a
/// journal that grows with its job.
pub fn read_header(path: &Path) -> Result<Json> {
    use std::io::BufRead;
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening journal {}", path.display()))?;
    let mut first = String::new();
    std::io::BufReader::new(file).read_line(&mut first)?;
    if first.trim().is_empty() {
        bail!("journal {} is empty", path.display());
    }
    let header = crate::util::json::parse(first.trim())
        .with_context(|| format!("journal {} header line", path.display()))?;
    let kind_ok = header
        .get("kind")
        .map(|k| k.as_str().ok() == Some(JOURNAL_KIND))
        .unwrap_or(false);
    if !kind_ok {
        bail!("journal {} has no '{JOURNAL_KIND}' header line", path.display());
    }
    Ok(header)
}

/// Split journal text into its durable lines: `(lines, torn)`.
///
/// This is the **single** definition of "torn trailing record" every
/// journal reader shares, and it matches what [`JournalWriter::append`]
/// truncates: a final line not terminated by `'\n'` was cut mid-flush,
/// so the step it describes was never durable and is dropped *before*
/// empties are filtered. The line-terminator test matters — a tear can
/// land so that the fragment still parses as valid JSON with a wrong
/// value (e.g. `"g":1.25}` cut to `"g":1.2}`), which a parse-failure
/// heuristic would load as a corrupt record. `torn` reports whether a
/// fragment was dropped so callers can log it.
pub fn journal_lines(text: &str) -> (Vec<&str>, bool) {
    let mut raw: Vec<&str> = text.lines().collect();
    let torn = !text.is_empty() && !text.ends_with('\n');
    if torn {
        raw.pop();
    }
    (raw.into_iter().filter(|l| !l.trim().is_empty()).collect(), torn)
}

/// Count a journal's step records without parsing them (durable
/// non-empty line count minus the header) — the slice scheduler's cheap
/// checkpoint-vs-journal consistency check. A torn trailing record is
/// *not* counted, so the count always agrees with what [`load_journal`]
/// returns and what resume will re-run.
pub fn journal_record_count(path: &Path) -> Result<usize> {
    read_header(path)?;
    let text = std::fs::read_to_string(path)?;
    let (lines, _) = journal_lines(&text);
    Ok(lines.len().saturating_sub(1))
}

/// Read a journal back: `(header, records)`.
///
/// Crash tolerance: a journal's **final** line may be torn (a crash
/// mid-flush cut it short). The step it would describe was never
/// durable, and the live state that had applied it died with the
/// process — so [`journal_lines`] drops the unterminated fragment
/// (exactly what [`JournalWriter::append`] would truncate) and resume
/// re-runs that step deterministically, re-appending the identical
/// record. A malformed final line that *is* newline-terminated gets the
/// same tolerance (old journals may predate truncate-before-append); a
/// malformed line anywhere else is real corruption and a hard error.
pub fn load_journal(path: &Path) -> Result<(Json, Vec<StepRecord>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    let (lines, torn) = journal_lines(&text);
    if torn {
        crate::info!(
            "journal {}: dropping torn trailing record (crash mid-flush); \
             the step will be re-run on resume",
            path.display()
        );
    }
    let Some((&first, rest)) = lines.split_first() else {
        bail!("journal {} is empty", path.display());
    };
    let header = crate::util::json::parse(first)
        .with_context(|| format!("journal {} header line", path.display()))?;
    let kind_ok = header
        .get("kind")
        .map(|k| k.as_str().ok() == Some(JOURNAL_KIND))
        .unwrap_or(false);
    if !kind_ok {
        bail!("journal {} has no '{JOURNAL_KIND}' header line", path.display());
    }
    let mut records = Vec::with_capacity(rest.len());
    for (i, line) in rest.iter().enumerate() {
        match crate::util::json::parse(line).and_then(|j| StepRecord::from_json(&j)) {
            Ok(rec) => records.push(rec),
            Err(_) if i + 1 == rest.len() => {
                crate::info!(
                    "journal {}: dropping torn trailing record (crash mid-flush); \
                     the step will be re-run on resume",
                    path.display()
                );
                break;
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("journal {} record line {}", path.display(), i + 1)
                })
            }
        }
    }
    Ok((header, records))
}

/// Verify a journal `header` was written by a run compatible with
/// `cfg`: same model/task/optimizer and bit-identical lr/eps/sparsity.
/// Replaying under a mismatched config would confidently produce wrong
/// parameters, so [`replay`] makes this a hard error up front.
pub fn check_compatible(header: &Json, cfg: &TrainConfig) -> Result<()> {
    for (key, want) in [
        ("model", cfg.model.as_str()),
        ("task", cfg.task.as_str()),
        ("optimizer", cfg.optimizer.as_str()),
    ] {
        let got = header.req(key)?.as_str()?;
        if got != want {
            bail!("journal {key} '{got}' does not match replay config '{want}'");
        }
    }
    for (key, want) in [
        ("lr", cfg.hypers.lr),
        ("eps", cfg.hypers.eps),
        ("sparsity", cfg.hypers.sparsity),
    ] {
        let got = header.req(key)?.as_f64()? as f32;
        if got.to_bits() != want.to_bits() {
            bail!("journal {key} {got} does not match replay config {want}");
        }
    }
    // Moment hypers shape the slot-stateful replays; journals written
    // before the zo_mom/zo_adam extension lack them, so they are
    // compared when present and *required* for slot-stateful optimizers.
    let slot_stateful = dp_slot_len(&cfg.optimizer, 1) > 0;
    for (key, want) in [
        ("beta1", cfg.hypers.beta1),
        ("beta2", cfg.hypers.beta2),
        ("adam_eps", cfg.hypers.adam_eps),
    ] {
        match header.get(key) {
            Some(v) => {
                let got = v.as_f64()? as f32;
                if got.to_bits() != want.to_bits() {
                    bail!("journal {key} {got} does not match replay config {want}");
                }
            }
            None if slot_stateful => {
                bail!("journal lacks '{key}', required to replay '{}'", cfg.optimizer)
            }
            None => {}
        }
    }
    let seed = header.req("seed")?.as_f64()? as u64;
    if seed != cfg.seed {
        bail!("journal seed {seed} does not match replay config {}", cfg.seed);
    }
    Ok(())
}

/// Reconstruct the [`TrainConfig`] a journal was recorded under from its
/// header — the self-describing path the serving layer uses to
/// materialize an adapter from an uploaded journal without any
/// out-of-band configuration. The result passes [`check_compatible`]
/// against the same header by construction.
pub fn config_from_header(header: &Json) -> Result<TrainConfig> {
    let mut cfg = TrainConfig {
        model: header.req("model")?.as_str()?.to_string(),
        task: header.req("task")?.as_str()?.to_string(),
        optimizer: header.req("optimizer")?.as_str()?.to_string(),
        seed: header.req("seed")?.as_f64()? as u64,
        steps: header.req("steps")?.as_usize()?.max(1),
        eval_every: 0,
        ..TrainConfig::default()
    };
    cfg.hypers.lr = header.req("lr")?.as_f64()? as f32;
    cfg.hypers.eps = header.req("eps")?.as_f64()? as f32;
    cfg.hypers.sparsity = header.req("sparsity")?.as_f64()? as f32;
    if let Some(v) = header.get("beta1") {
        cfg.hypers.beta1 = v.as_f64()? as f32;
    }
    if let Some(v) = header.get("beta2") {
        cfg.hypers.beta2 = v.as_f64()? as f32;
    }
    if let Some(v) = header.get("adam_eps") {
        cfg.hypers.adam_eps = v.as_f64()? as f32;
    }
    if let Some(v) = header.get("workers") {
        cfg.workers = v.as_usize()?.max(1);
    }
    cfg.validate()?;
    Ok(cfg)
}

/// FNV-1a fingerprint of a parameter vector's exact bits (hex). The DP
/// trainer stamps its initial parameters' fingerprint into the journal
/// header as `init_fnv`; [`replay_full`] refuses to replay from a base
/// with a different fingerprint — replaying a `(seed, g)` stream against
/// the wrong starting point would produce confidently wrong parameters
/// (and wrong magnitude masks) with no other symptom.
pub fn params_fingerprint(params: &[f32]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in params {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    format!("{h:016x}")
}

/// Everything a full journal re-walk produces beyond the parameters.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// final parameters, bit-identical to the live run's
    pub params: Vec<f32>,
    /// final optimizer slots (empty for the stateless family)
    pub slots: Vec<f32>,
    /// union of the per-step coordinate masks as a 1-bit/param bitset
    /// (dense steps set every bit) — exactly the support an exported
    /// sparse adapter delta is allowed to touch
    pub mask_union: Vec<u64>,
    /// steps replayed
    pub steps: usize,
    /// §8.2 thresholds in effect after the last replayed step — together
    /// with `params`/`slots`/`mask_epoch` this is the complete resumable
    /// state a paused job needs to continue bit-identically
    pub thresholds: Vec<f32>,
    /// threshold generation after the last replayed step
    pub mask_epoch: u32,
}

/// Re-walk a journal from `init` parameters: regenerate each step's mask
/// and noise, then apply the recorded scalar through the *identical*
/// fused perturb/update arithmetic the live run used — no forward
/// passes, so replay is orders of magnitude faster than training, and
/// the result is bit-identical to the live run's final parameters.
/// Slot-stateful optimizers (`zo_mom`/`zo_adam`/`zo_adamu`) replay too:
/// their slots are a deterministic function of the `(seed, g)` stream,
/// rebuilt from zero exactly as the live replicas built them. The
/// outcome also carries the union of per-step masks, which is the
/// support certificate the serving layer checks adapter deltas against.
/// `header` (from [`load_journal`]) is validated against `cfg` first so
/// a mismatched config is an error, not silently wrong parameters.
pub fn replay_full(
    rt: &Runtime,
    model: &ModelInfo,
    cfg: &TrainConfig,
    header: &Json,
    init: &[f32],
    records: &[StepRecord],
) -> Result<ReplayOutcome> {
    check_compatible(header, cfg)?;
    if init.len() != model.n_params {
        bail!("replay: init has {} params, model expects {}", init.len(), model.n_params);
    }
    // journals stamped with their initial-parameter fingerprint refuse
    // to replay from a different base (pre-stamp journals skip the check)
    if let Some(v) = header.get("init_fnv") {
        let want = v.as_str()?;
        let got = params_fingerprint(init);
        if got != want {
            bail!(
                "journal was recorded from different initial parameters \
                 (init fingerprint {want}, replay base {got}); materialize \
                 the adapter against the base the run actually started from"
            );
        }
    }
    let Some(rule) = dp_rule(&cfg.optimizer) else {
        bail!("optimizer '{}' has no journal replay rule", cfg.optimizer);
    };
    let backend = rt.backend();
    let p = model.n_params;
    let mut params = init.to_vec();
    let mut slots = vec![0.0f32; dp_slot_len(&cfg.optimizer, p)];
    let mut union = bitset::new(p);
    let mut thresholds = backend.thresholds(model, &params, cfg.hypers.sparsity)?;
    let mut mask_epoch = 0u32;
    for rec in records {
        if rec.mask_epoch != mask_epoch {
            // the live run refreshed §8.2 thresholds at this step's start
            thresholds = backend.thresholds(model, &params, cfg.hypers.sparsity)?;
            mask_epoch = rec.mask_epoch;
        }
        let mask = backend.zo_mask(model, &cfg.optimizer, &cfg.hypers, &thresholds, &params)?;
        match &mask {
            Some(m) => {
                for (i, &mv) in m.iter().enumerate() {
                    if mv != 0 {
                        bitset::set(&mut union, i);
                    }
                }
            }
            None => bitset::set_all(&mut union, p),
        }
        let z = backend.zo_noise(model, rec.seed, 0, p)?;
        let eps = cfg.hypers.eps;
        perturb_in_place(&mut params, &z, mask.as_deref(), eps);
        perturb_in_place(&mut params, &z, mask.as_deref(), -2.0 * eps);
        apply_update(&mut params, &mut slots, &z, mask.as_deref(), &cfg.hypers, rec.scalar, rule);
    }
    Ok(ReplayOutcome {
        params,
        slots,
        mask_union: union,
        steps: records.len(),
        thresholds,
        mask_epoch,
    })
}

/// [`replay_full`] reduced to the final parameters (the original crash
/// recovery / audit entry point).
pub fn replay(
    rt: &Runtime,
    model: &ModelInfo,
    cfg: &TrainConfig,
    header: &Json,
    init: &[f32],
    records: &[StepRecord],
) -> Result<Vec<f32>> {
    Ok(replay_full(rt, model, cfg, header, init, records)?.params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_round_trips_exactly() {
        let rec = StepRecord {
            step: 41,
            seed: (0xDEAD_BEEF, 7),
            scalar: -3.724_119e-2,
            mask_epoch: 2,
        };
        let back = StepRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.scalar.to_bits(), rec.scalar.to_bits());
    }

    #[test]
    fn journal_round_trips_through_disk() {
        let dir = std::env::temp_dir().join(format!("smz_journal_{}", std::process::id()));
        let path = dir.join("run.journal.jsonl");
        let recs: Vec<StepRecord> = (0..5)
            .map(|t| StepRecord {
                step: t,
                seed: (9, t),
                scalar: t as f32 * 0.125,
                mask_epoch: t / 3,
            })
            .collect();
        {
            let mut w =
                JournalWriter::create(&path, vec![("label", Json::Str("unit".into()))]).unwrap();
            for r in &recs {
                w.record(r).unwrap();
            }
            w.flush().unwrap();
        }
        let (header, back) = load_journal(&path).unwrap();
        assert_eq!(header.req("label").unwrap().as_str().unwrap(), "unit");
        assert_eq!(back, recs);
        std::fs::remove_dir_all(&dir).ok();
    }
}
