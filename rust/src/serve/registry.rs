//! The in-process adapter registry: one base, N tenants, zero copies.
//!
//! The registry owns exactly **one** resident parameter vector (the
//! base) plus N named [`SparseDelta`] adapters. Serving a tenant is a
//! *checkout*: the adapter's values are swapped into the base in place
//! (O(nnz), no allocation), the forward passes run against the borrowed
//! vector, and dropping the [`Checkout`] guard swaps the base values
//! back bit-for-bit (release). Compare the naive design — a full
//! fine-tuned copy per tenant — against
//! [`memory::serving_breakdown`](crate::coordinator::memory::serving_breakdown),
//! which this registry's byte accounting feeds.
//!
//! Eviction is LRU under two simultaneous caps: an adapter-count cap
//! and a byte budget (each adapter accounted at
//! [`memory::sparse_adapter_bytes`](crate::coordinator::memory::sparse_adapter_bytes)).
//! A checked-out adapter is never evicted, and neither is a *pinned*
//! one ([`AdapterRegistry::pin`]): the HTTP layer pins an adapter from
//! request admission until its micro-batch answers, so an orchestrator
//! insert can never evict an adapter with classify traffic in flight.
//!
//! The base itself lives behind a [`ParamStore`] handle, so the
//! registry serves either tier. **Resident** keeps the historical
//! behaviour: checkout locks the vector and swaps the adapter in place.
//! **Paged** ([`AdapterRegistry::with_store`]) never mutates the shared
//! base at all: checkout copies the adapter's O(nnz) patch out of the
//! entry and hands back an [`Overlay`] view
//! ([`TenantParams::Paged`]), so N tenants serve off one page cache
//! whose resident footprint is the `--page-cache-bytes` budget — see
//! [`AdapterRegistry::working_set_bytes`].
//!
//! Lock order: `base` **before** `entries`, always. Resident `checkout`
//! takes base then entries (releasing entries before returning); the
//! guard's drop takes entries while still holding base. The paged path
//! only ever takes entries. No path takes entries and then waits on
//! base, so the order is acyclic.

use std::collections::BTreeMap;
use std::ops::Deref;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, Result};

use crate::coordinator::memory;
use crate::runtime::store::{Overlay, ParamStore};
use crate::runtime::ModelInfo;

use super::delta::SparseDelta;

/// One registered adapter plus its bookkeeping.
struct Entry {
    delta: SparseDelta,
    bytes: usize,
    hits: u64,
    last_used: u64,
    in_use: bool,
    /// outstanding [`PinGuard`]s: requests that have been admitted (the
    /// HTTP layer checked the adapter exists and enqueued rows) but
    /// whose batch has not necessarily checked the adapter out yet.
    /// A pinned adapter is never evicted, replaced or removed — without
    /// this, an orchestrator `insert` landing between admission and
    /// checkout could evict the adapter out from under an in-flight
    /// classify batch.
    pinned: u64,
}

/// Mutable registry state behind the `entries` lock.
struct Entries {
    map: BTreeMap<String, Entry>,
    bytes: usize,
    clock: u64,
}

/// Public snapshot of one adapter's bookkeeping (the `/v1/adapters`
/// listing).
#[derive(Debug, Clone)]
pub struct AdapterStat {
    /// adapter name
    pub name: String,
    /// touched coordinates
    pub nnz: usize,
    /// host bytes accounted against the budget
    pub bytes: usize,
    /// completed checkouts
    pub hits: u64,
    /// currently checked out
    pub in_use: bool,
    /// outstanding in-flight pins (see [`AdapterRegistry::pin`])
    pub pinned: u64,
}

/// The adapter registry. See the module docs for the locking contract.
pub struct AdapterRegistry {
    model: ModelInfo,
    base: Arc<ParamStore>,
    entries: Mutex<Entries>,
    max_adapters: usize,
    byte_budget: usize,
}

impl AdapterRegistry {
    /// A registry serving `model` from a resident `base` vector, holding
    /// at most `max_adapters` adapters within `byte_budget` accounted
    /// bytes (the historical constructor — wraps the vector in a
    /// resident [`ParamStore`]).
    pub fn new(
        model: ModelInfo,
        base: Vec<f32>,
        max_adapters: usize,
        byte_budget: usize,
    ) -> Result<AdapterRegistry> {
        Self::with_store(model, Arc::new(ParamStore::resident(base)), max_adapters, byte_budget)
    }

    /// A registry serving `model` from an existing [`ParamStore`] handle
    /// — resident or paged. With a paged store, checkouts are
    /// [`Overlay`] views and the base is never mutated.
    pub fn with_store(
        model: ModelInfo,
        base: Arc<ParamStore>,
        max_adapters: usize,
        byte_budget: usize,
    ) -> Result<AdapterRegistry> {
        if base.len() != model.n_params {
            bail!("registry base has {} params, model '{}' expects {}", base.len(), model.name, model.n_params);
        }
        if max_adapters == 0 || byte_budget == 0 {
            bail!("registry caps must be positive (max_adapters {max_adapters}, byte_budget {byte_budget})");
        }
        Ok(AdapterRegistry {
            model,
            base,
            entries: Mutex::new(Entries { map: BTreeMap::new(), bytes: 0, clock: 0 }),
            max_adapters,
            byte_budget,
        })
    }

    /// The model this registry serves.
    pub fn model(&self) -> &ModelInfo {
        &self.model
    }

    /// A copy of the base parameters (O(P) — prefer [`base_store`] where
    /// a handle suffices). Resident: blocks until no adapter is checked
    /// out, so the snapshot is always the *base*, never a tenant's tuned
    /// vector — the invariant adapter materialization relies on. Paged:
    /// checkouts never mutate the base, so no blocking is needed.
    ///
    /// [`base_store`]: AdapterRegistry::base_store
    pub fn base_snapshot(&self) -> Vec<f32> {
        self.base.read_all_with(|s| s.to_vec())
    }

    /// A cheap shared handle to the base store (no parameter copy).
    pub fn base_store(&self) -> Arc<ParamStore> {
        self.base.clone()
    }

    /// Bytes resident right now on behalf of serving: the base store's
    /// working set (full vector when resident, cached pages when paged)
    /// plus every registered adapter's accounted bytes. The byte budget
    /// itself stays adapter-bytes-only — this is observability, not a
    /// cap.
    pub fn working_set_bytes(&self) -> usize {
        self.base.working_set_bytes() + self.bytes()
    }

    /// Register (or replace) `name`. Evicts least-recently-used
    /// adapters as needed to respect both caps; returns the evicted
    /// names. The eviction plan is computed **before** anything is
    /// registered, so a refused insert (adapter alone over the byte
    /// budget, or nothing evictable because every resident adapter is
    /// checked out) leaves the registry exactly as it was.
    pub fn insert(&self, name: &str, delta: SparseDelta) -> Result<Vec<String>> {
        if delta.model != self.model.name || delta.n_params != self.model.n_params {
            bail!(
                "adapter '{name}' is for model '{}' ({} params); registry hosts '{}' ({})",
                delta.model,
                delta.n_params,
                self.model.name,
                self.model.n_params
            );
        }
        let bytes = delta.host_bytes();
        if bytes > self.byte_budget {
            bail!(
                "adapter '{name}' needs {bytes} bytes, over the whole registry budget {}",
                self.byte_budget
            );
        }
        let mut entries = self.entries.lock().unwrap();
        let replaced_bytes = match entries.map.get(name) {
            Some(old) if old.in_use || old.pinned > 0 => {
                bail!("adapter '{name}' is checked out or pinned by in-flight requests; cannot replace it")
            }
            Some(old) => old.bytes,
            None => 0,
        };
        let existed = entries.map.contains_key(name);

        // plan LRU eviction against the *projected* state; commit only
        // if both caps can actually be satisfied
        let mut projected_len = entries.map.len() + usize::from(!existed);
        let mut projected_bytes = entries.bytes - replaced_bytes + bytes;
        let mut victims: Vec<String> = Vec::new();
        while projected_len > self.max_adapters || projected_bytes > self.byte_budget {
            let victim = entries
                .map
                .iter()
                .filter(|(n, e)| {
                    !e.in_use && e.pinned == 0 && n.as_str() != name && !victims.contains(*n)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            let Some(victim) = victim else {
                bail!(
                    "cannot register adapter '{name}': registry would hold {projected_bytes} \
                     bytes / {projected_len} adapters with nothing evictable (all checked out \
                     or pinned by in-flight requests); '{name}' was NOT registered",
                );
            };
            projected_len -= 1;
            projected_bytes -= entries.map.get(&victim).map(|e| e.bytes).unwrap_or(0);
            victims.push(victim);
        }

        // commit: evict the plan, replace the old entry, insert the new
        if !victims.is_empty() {
            crate::obs::counter("serve_registry_evictions_total", &[]).add(victims.len() as u64);
        }
        for v in &victims {
            let e = entries.map.remove(v).unwrap();
            entries.bytes -= e.bytes;
        }
        if existed {
            let e = entries.map.remove(name).unwrap();
            entries.bytes -= e.bytes;
        }
        entries.clock += 1;
        let stamp = entries.clock;
        entries.map.insert(
            name.to_string(),
            Entry { delta, bytes, hits: 0, last_used: stamp, in_use: false, pinned: 0 },
        );
        entries.bytes += bytes;
        Ok(victims)
    }

    /// Remove `name` (error if absent, checked out, or pinned).
    pub fn remove(&self, name: &str) -> Result<()> {
        let mut entries = self.entries.lock().unwrap();
        match entries.map.get(name) {
            None => bail!("no adapter '{name}' registered"),
            Some(e) if e.in_use || e.pinned > 0 => {
                bail!("adapter '{name}' is checked out or pinned by in-flight requests")
            }
            Some(_) => {
                let e = entries.map.remove(name).unwrap();
                entries.bytes -= e.bytes;
                Ok(())
            }
        }
    }

    /// Pin `name` against eviction for the lifetime of the returned
    /// guard. The HTTP layer pins an adapter the moment a classify
    /// request is admitted and holds the pin until the batch answers —
    /// closing the admission→checkout window in which a concurrent
    /// insert (e.g. the job orchestrator auto-publishing a finished
    /// adapter) could otherwise evict it and fail the batch spuriously.
    /// Pins nest; eviction, replacement and removal all refuse while
    /// any pin is outstanding.
    pub fn pin(&self, name: &str) -> Result<PinGuard<'_>> {
        let mut entries = self.entries.lock().unwrap();
        let Some(entry) = entries.map.get_mut(name) else {
            bail!("no adapter '{name}' registered");
        };
        entry.pinned += 1;
        crate::obs::counter("serve_registry_pins_total", &[]).inc();
        Ok(PinGuard { registry: self, name: name.to_string() })
    }

    /// Whether `name` is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.entries.lock().unwrap().map.contains_key(name)
    }

    /// Registered adapter count.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().map.len()
    }

    /// Whether no adapter is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total accounted adapter bytes.
    pub fn bytes(&self) -> usize {
        self.entries.lock().unwrap().bytes
    }

    /// The registry's byte budget.
    pub fn byte_budget(&self) -> usize {
        self.byte_budget
    }

    /// Bookkeeping snapshot of every adapter, name order.
    pub fn stats(&self) -> Vec<AdapterStat> {
        let entries = self.entries.lock().unwrap();
        entries
            .map
            .iter()
            .map(|(name, e)| AdapterStat {
                name: name.clone(),
                nnz: e.delta.nnz(),
                bytes: e.bytes,
                hits: e.hits,
                in_use: e.in_use,
                pinned: e.pinned,
            })
            .collect()
    }

    /// Check `name` out and return a guard over the tenant's parameters.
    ///
    /// Resident base: the adapter's values are swapped into the base in
    /// place and the guard dereferences to the tuned vector; exclusive —
    /// a second checkout blocks until the guard drops (the micro-batcher
    /// serializes same-server forward passes anyway); dropping the guard
    /// swaps the base back bit-for-bit.
    ///
    /// Paged base: the adapter's O(nnz) patch is copied out of the entry
    /// and [`Checkout::tenant`] yields an [`Overlay`] view over the
    /// shared store — the base is never mutated and no parameter-sized
    /// allocation happens. The guard does *not* deref in this mode.
    pub fn checkout(&self, name: &str) -> Result<Checkout<'_>> {
        if !self.base.is_paged() {
            // lock order: base first, then entries (see module docs)
            let mut params = self.base.lock_resident();
            let mut entries = self.entries.lock().unwrap();
            entries.clock += 1;
            let stamp = entries.clock;
            let Some(entry) = entries.map.get_mut(name) else {
                bail!("no adapter '{name}' registered");
            };
            entry.delta.swap(&mut params);
            entry.in_use = true;
            entry.hits += 1;
            entry.last_used = stamp;
            drop(entries);
            return Ok(Checkout {
                registry: self,
                name: name.to_string(),
                inner: CheckoutInner::Resident(Some(params)),
            });
        }
        let mut entries = self.entries.lock().unwrap();
        entries.clock += 1;
        let stamp = entries.clock;
        let Some(entry) = entries.map.get_mut(name) else {
            bail!("no adapter '{name}' registered");
        };
        let idx = entry.delta.indices().to_vec();
        let val = entry.delta.values().to_vec();
        entry.in_use = true;
        entry.hits += 1;
        entry.last_used = stamp;
        drop(entries);
        Ok(Checkout { registry: self, name: name.to_string(), inner: CheckoutInner::Paged { idx, val } })
    }
}

/// How a [`Checkout`] exposes the tenant's parameters to the forward
/// pass.
pub enum TenantParams<'a> {
    /// Resident base with the adapter swapped in: one flat tuned slice.
    Flat(&'a [f32]),
    /// Paged base: the adapter's sparse patch viewed over the shared
    /// page-cached store (bit-identical reads to the flat case).
    Paged(Overlay<'a>),
}

/// RAII pin: while alive, the named adapter cannot be evicted, replaced
/// or removed. See [`AdapterRegistry::pin`].
pub struct PinGuard<'a> {
    registry: &'a AdapterRegistry,
    name: String,
}

impl PinGuard<'_> {
    /// The pinned adapter's name.
    pub fn name(&self) -> &str {
        &self.name
    }
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        let mut entries = self.registry.entries.lock().unwrap();
        if let Some(entry) = entries.map.get_mut(&self.name) {
            entry.pinned = entry.pinned.saturating_sub(1);
        }
    }
}

/// RAII checkout guard. Over a resident base it derefs to the tuned
/// parameter slice and dropping it reverts the base (release); over a
/// paged base use [`Checkout::tenant`]. See
/// [`AdapterRegistry::checkout`].
pub struct Checkout<'a> {
    registry: &'a AdapterRegistry,
    name: String,
    inner: CheckoutInner<'a>,
}

enum CheckoutInner<'a> {
    Resident(Option<MutexGuard<'a, Vec<f32>>>),
    Paged { idx: Vec<u32>, val: Vec<f32> },
}

impl Checkout<'_> {
    /// The tenant's parameters in whichever representation this
    /// checkout carries.
    pub fn tenant(&self) -> TenantParams<'_> {
        match &self.inner {
            CheckoutInner::Resident(params) => {
                TenantParams::Flat(params.as_ref().expect("checkout guard intact"))
            }
            CheckoutInner::Paged { idx, val } => {
                TenantParams::Paged(Overlay::new(&self.registry.base, idx, val))
            }
        }
    }
}

impl Deref for Checkout<'_> {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match &self.inner {
            CheckoutInner::Resident(params) => params.as_ref().expect("checkout guard intact"),
            CheckoutInner::Paged { .. } => {
                panic!("paged checkout has no flat view; use Checkout::tenant()")
            }
        }
    }
}

impl Drop for Checkout<'_> {
    fn drop(&mut self) {
        // resident: still holding the base lock — entries after base is
        // the registry's one legal order. paged: entries only.
        let mut entries = self.registry.entries.lock().unwrap();
        match &mut self.inner {
            CheckoutInner::Resident(params) => {
                if let (Some(entry), Some(params)) =
                    (entries.map.get_mut(&self.name), params.as_mut())
                {
                    entry.delta.swap(params);
                    entry.in_use = false;
                }
            }
            CheckoutInner::Paged { .. } => {
                if let Some(entry) = entries.map.get_mut(&self.name) {
                    entry.in_use = false;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayoutEntry;
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    fn toy_model(n_params: usize) -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            family: "llama".into(),
            size: "tiny".into(),
            n_layers: 1,
            d_model: 4,
            n_heads: 1,
            d_ff: 8,
            vocab: 16,
            seq_len: 8,
            batch: 2,
            window: 0,
            n_params,
            n_lora_params: 0,
            lora_rank: 0,
            n_entries: 1,
            n_hypers: 8,
            n_metrics: 8,
            layout: vec![LayoutEntry {
                name: "w".into(),
                shape: vec![n_params],
                kind: "matrix".into(),
                offset: 0,
                size: n_params,
                layer_id: 0,
            }],
            lora_layout: vec![],
            programs: BTreeMap::new(),
        }
    }

    fn delta_touching(model: &ModelInfo, base: &[f32], coords: &[usize], bump: f32) -> SparseDelta {
        let mut tuned = base.to_vec();
        for &c in coords {
            tuned[c] = base[c] + bump;
        }
        SparseDelta::extract(model, base, &tuned, None, Json::Null).unwrap()
    }

    #[test]
    fn checkout_installs_and_release_restores_bit_exactly() {
        let m = toy_model(12);
        let base: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let reg = AdapterRegistry::new(m.clone(), base.clone(), 4, 1 << 20).unwrap();
        reg.insert("a", delta_touching(&m, &base, &[1, 5], 10.0)).unwrap();
        {
            let co = reg.checkout("a").unwrap();
            assert_eq!(co[1], base[1] + 10.0);
            assert_eq!(co[5], base[5] + 10.0);
            assert_eq!(co[0].to_bits(), base[0].to_bits());
        } // release
        assert_eq!(reg.base_snapshot(), base);
        // a second checkout cycle still works (the swap healed)
        {
            let co = reg.checkout("a").unwrap();
            assert_eq!(co[1], base[1] + 10.0);
        }
        assert_eq!(reg.base_snapshot(), base);
        assert_eq!(reg.stats()[0].hits, 2);
        assert!(reg.checkout("missing").is_err());
    }

    #[test]
    fn lru_eviction_respects_count_and_bytes() {
        let m = toy_model(64);
        let base = vec![1.0f32; 64];
        let per = memory::sparse_adapter_bytes(64, 4);
        // budget fits exactly two adapters of nnz 4
        let reg = AdapterRegistry::new(m.clone(), base.clone(), 8, 2 * per).unwrap();
        reg.insert("a", delta_touching(&m, &base, &[0, 1, 2, 3], 1.0)).unwrap();
        reg.insert("b", delta_touching(&m, &base, &[4, 5, 6, 7], 1.0)).unwrap();
        // touch "a" so "b" becomes least-recent
        drop(reg.checkout("a").unwrap());
        let evicted = reg.insert("c", delta_touching(&m, &base, &[8, 9, 10, 11], 1.0)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(reg.contains("a") && reg.contains("c") && !reg.contains("b"));
        assert!(reg.bytes() <= 2 * per);
        // count cap: max_adapters 2 with a huge budget
        let reg2 = AdapterRegistry::new(m.clone(), base.clone(), 2, 1 << 20).unwrap();
        reg2.insert("a", delta_touching(&m, &base, &[0], 1.0)).unwrap();
        reg2.insert("b", delta_touching(&m, &base, &[1], 1.0)).unwrap();
        let ev = reg2.insert("c", delta_touching(&m, &base, &[2], 1.0)).unwrap();
        assert_eq!(ev.len(), 1);
        assert_eq!(reg2.len(), 2);
        // an adapter alone over budget is refused outright
        let tiny = AdapterRegistry::new(m.clone(), base.clone(), 2, 8).unwrap();
        assert!(tiny.insert("x", delta_touching(&m, &base, &[0], 1.0)).is_err());
    }

    #[test]
    fn refused_insert_leaves_registry_untouched() {
        // cap 1, and the only resident adapter is checked out: a new
        // insert must be refused WITHOUT registering anything
        let m = toy_model(16);
        let base = vec![1.0f32; 16];
        let reg = AdapterRegistry::new(m.clone(), base.clone(), 1, 1 << 20).unwrap();
        reg.insert("a", delta_touching(&m, &base, &[0, 1], 1.0)).unwrap();
        let bytes_before = reg.bytes();
        let co = reg.checkout("a").unwrap();
        let err = reg.insert("b", delta_touching(&m, &base, &[2], 1.0)).unwrap_err();
        assert!(err.to_string().contains("NOT registered"), "{err:#}");
        assert_eq!(reg.len(), 1);
        assert!(reg.contains("a") && !reg.contains("b"));
        assert_eq!(reg.bytes(), bytes_before);
        drop(co);
        // once released, the same insert succeeds and evicts "a"
        let evicted = reg.insert("b", delta_touching(&m, &base, &[2], 1.0)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn pinned_adapter_survives_orchestrator_inserts() {
        // the train→serve race: a classify request was admitted for "a"
        // (pinned) but its batch has not checked "a" out yet; a job
        // completing concurrently publishes "b" into a full registry.
        // The insert must refuse rather than evict the pinned adapter.
        let m = toy_model(16);
        let base = vec![1.0f32; 16];
        let reg = AdapterRegistry::new(m.clone(), base.clone(), 1, 1 << 20).unwrap();
        reg.insert("a", delta_touching(&m, &base, &[0, 1], 1.0)).unwrap();
        let pin = reg.pin("a").unwrap();
        assert_eq!(pin.name(), "a");
        let err = reg.insert("b", delta_touching(&m, &base, &[2], 1.0)).unwrap_err();
        assert!(err.to_string().contains("pinned"), "{err:#}");
        assert!(reg.contains("a") && !reg.contains("b"));
        // replacing or removing the pinned adapter is refused too
        assert!(reg.insert("a", delta_touching(&m, &base, &[3], 1.0)).is_err());
        assert!(reg.remove("a").is_err());
        assert_eq!(reg.stats()[0].pinned, 1);
        // the pin does NOT block checkout — that's the whole point:
        // the in-flight batch still gets to run
        {
            let co = reg.checkout("a").unwrap();
            assert_eq!(co[0], base[0] + 1.0);
        }
        // nested pins: both must drop before eviction is allowed
        let pin2 = reg.pin("a").unwrap();
        drop(pin);
        assert!(reg.insert("b", delta_touching(&m, &base, &[2], 1.0)).is_err());
        drop(pin2);
        let evicted = reg.insert("b", delta_touching(&m, &base, &[2], 1.0)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert_eq!(reg.stats()[0].pinned, 0);
        // pinning a missing adapter errors
        assert!(reg.pin("ghost").is_err());
    }

    #[test]
    fn wrong_model_and_double_names_handled() {
        let m = toy_model(8);
        let base = vec![0.5f32; 8];
        let reg = AdapterRegistry::new(m.clone(), base.clone(), 4, 1 << 20).unwrap();
        // replacing a name adjusts the byte accounting instead of leaking
        reg.insert("a", delta_touching(&m, &base, &[0, 1, 2], 1.0)).unwrap();
        let before = reg.bytes();
        reg.insert("a", delta_touching(&m, &base, &[3], 1.0)).unwrap();
        assert!(reg.bytes() < before);
        assert_eq!(reg.len(), 1);
        // ABI mismatch rejected
        let other = toy_model(9);
        let bad = delta_touching(&other, &vec![0.0f32; 9], &[0], 1.0);
        assert!(reg.insert("bad", bad).is_err());
        reg.remove("a").unwrap();
        assert!(reg.is_empty());
        assert!(reg.remove("a").is_err());
    }

    #[test]
    fn paged_checkout_overlays_without_touching_base() {
        let m = toy_model(32);
        let base: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        let store = Arc::new(ParamStore::file_backed(&base, 1 << 16).unwrap());
        let reg = AdapterRegistry::with_store(m.clone(), store, 4, 1 << 20).unwrap();
        reg.insert("a", delta_touching(&m, &base, &[1, 5], 10.0)).unwrap();
        let co = reg.checkout("a").unwrap();
        let TenantParams::Paged(ov) = co.tenant() else { panic!("expected paged tenant") };
        let mut out = vec![0.0f32; 32];
        ov.read_run(0, &mut out);
        assert_eq!(out[1].to_bits(), (base[1] + 10.0).to_bits());
        assert_eq!(out[5].to_bits(), (base[5] + 10.0).to_bits());
        assert_eq!(out[0].to_bits(), base[0].to_bits());
        // the shared base is untouched even mid-checkout, and snapshot
        // does not block on the outstanding paged checkout
        assert_eq!(reg.base_snapshot(), base);
        assert!(reg.stats()[0].in_use);
        drop(co);
        assert!(!reg.stats()[0].in_use);
        assert!(reg.working_set_bytes() >= reg.bytes());
        // resident registries still hand out flat tenants
        let flat = AdapterRegistry::new(m.clone(), base.clone(), 4, 1 << 20).unwrap();
        flat.insert("a", delta_touching(&m, &base, &[1, 5], 10.0)).unwrap();
        let co = flat.checkout("a").unwrap();
        assert!(matches!(co.tenant(), TenantParams::Flat(_)));
        assert_eq!(co[1].to_bits(), (base[1] + 10.0).to_bits());
    }
}
