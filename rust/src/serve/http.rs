//! Minimal std-only HTTP/1.1 loopback server + client.
//!
//! No HTTP crate exists in the vendored dependency set, so this module
//! hand-rolls exactly the subset the serving API needs: one request per
//! connection (`Connection: close`), `Content-Length` bodies, JSON in
//! and out. Endpoints:
//!
//! | method + path        | action |
//! |----------------------|--------|
//! | `GET  /healthz`      | liveness + registry/queue gauges |
//! | `GET  /v1/adapters`  | list registered adapters (nnz, bytes, hits) |
//! | `POST /v1/adapters`  | register: `{"name", "journal": path}` replays a step journal against the base and extracts the delta under its mask-union certificate; `{"name", "delta": path}` loads a saved `.adapter` file |
//! | `POST /v1/classify`  | `{"adapter", "prompts": [[tok,...],...]}` → per-row logits + candidate-free argmax, micro-batched with concurrent same-adapter requests |
//!
//! Logits cross the wire losslessly: `f32 → f64` is exact, the JSON
//! writer emits shortest round-trip decimal for f64, and the client
//! parses it back to the identical bits — so a served classification is
//! bit-comparable to offline evaluation (asserted in `tests/serve.rs`).
//!
//! Threading: one accept thread, one detached thread per connection
//! (loopback traffic, bounded by the OS backlog), one dispatcher thread
//! draining the [`MicroBatcher`](super::batching::MicroBatcher).
//! [`RunningServer::shutdown`] flips the stop flag, drains the batcher,
//! pokes the listener with a loopback connect, and joins.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

use super::batching::ServeEngine;
use super::delta::SparseDelta;

/// A parsed inbound request.
struct Request {
    method: String,
    path: String,
    body: String,
}

/// Handle to a live server; dropping it shuts the server down.
pub struct RunningServer {
    /// the bound loopback address (`127.0.0.1:port`)
    pub addr: SocketAddr,
    engine: Arc<ServeEngine>,
    stop_flag: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    dispatch: Option<JoinHandle<()>>,
}

impl RunningServer {
    /// Stop accepting, drain in-flight batches, join the server threads.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }

    /// Block on the accept thread forever (the CLI `serve` command's
    /// foreground mode).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
    }

    fn stop_impl(&mut self) {
        if self.accept.is_none() && self.dispatch.is_none() {
            return;
        }
        self.stop_flag.store(true, Ordering::Release);
        self.engine.batcher.shutdown();
        // poke the blocking accept() so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RunningServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Bind `127.0.0.1:port` (0 = ephemeral) and start serving `engine`.
pub fn serve(engine: Arc<ServeEngine>, port: u16) -> Result<RunningServer> {
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    let addr = listener.local_addr()?;
    let stop_flag = Arc::new(AtomicBool::new(false));

    let dispatch = {
        let engine = Arc::clone(&engine);
        thread::Builder::new()
            .name("smz-serve-batch".into())
            .spawn(move || engine.batcher.run(|adapter, rows| engine.classify(adapter, rows)))?
    };
    let accept = {
        let engine = Arc::clone(&engine);
        let stop_flag = Arc::clone(&stop_flag);
        thread::Builder::new().name("smz-serve-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let engine = Arc::clone(&engine);
                // detached per-connection worker; loopback-scale only
                let _ = thread::Builder::new()
                    .name("smz-serve-conn".into())
                    .spawn(move || handle_connection(&engine, stream));
            }
        })?
    };
    Ok(RunningServer {
        addr,
        engine,
        stop_flag,
        accept: Some(accept),
        dispatch: Some(dispatch),
    })
}

/// Serve one request on one connection; errors end the connection.
fn handle_connection(engine: &ServeEngine, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let response = match read_request(&mut stream) {
        Ok(req) => route(engine, &req),
        Err(e) => (400, error_json(&e)),
    };
    let _ = write_response(&mut stream, response.0, &response.1);
}

/// `{"error": "<context chain>"}`.
fn error_json(e: &anyhow::Error) -> Json {
    Json::obj(vec![("error", Json::Str(format!("{e:#}")))])
}

/// Dispatch one request to its endpoint.
fn route(engine: &ServeEngine, req: &Request) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, healthz(engine)),
        ("GET", "/v1/adapters") => (200, list_adapters(engine)),
        ("POST", "/v1/adapters") => match post_adapter(engine, &req.body) {
            Ok(body) => (200, body),
            Err(e) => (400, error_json(&e)),
        },
        ("POST", "/v1/classify") => match post_classify(engine, &req.body) {
            Ok(body) => (200, body),
            Err(ClassifyError::UnknownAdapter(e)) => (404, error_json(&e)),
            Err(ClassifyError::Bad(e)) => (400, error_json(&e)),
        },
        _ => (
            404,
            Json::obj(vec![(
                "error",
                Json::Str(format!("no route {} {}", req.method, req.path)),
            )]),
        ),
    }
}

fn healthz(engine: &ServeEngine) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("platform", Json::Str(engine.runtime().backend().platform().to_string())),
        ("model", Json::Str(engine.model().name.clone())),
        ("adapters", Json::Num(engine.registry.len() as f64)),
        ("pending_requests", Json::Num(engine.batcher.pending() as f64)),
    ])
}

fn list_adapters(engine: &ServeEngine) -> Json {
    let stats = engine.registry.stats();
    Json::obj(vec![
        (
            "adapters",
            Json::Arr(
                stats
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::Str(s.name.clone())),
                            ("nnz", Json::Num(s.nnz as f64)),
                            ("bytes", Json::Num(s.bytes as f64)),
                            ("hits", Json::Num(s.hits as f64)),
                            ("in_use", Json::Bool(s.in_use)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("bytes", Json::Num(engine.registry.bytes() as f64)),
        ("byte_budget", Json::Num(engine.registry.byte_budget() as f64)),
    ])
}

/// Register an adapter from a journal replay or a saved adapter file.
fn post_adapter(engine: &ServeEngine, body: &str) -> Result<Json> {
    let doc = json::parse(body).context("request body")?;
    let name = doc.req("name")?.as_str()?.to_string();
    let delta = if let Some(j) = doc.get("journal") {
        let path = j.as_str()?.to_string();
        let base = engine.registry.base_snapshot();
        SparseDelta::from_journal(
            engine.runtime(),
            engine.model(),
            &base,
            Path::new(&path),
            vec![("name", Json::Str(name.clone()))],
        )?
    } else if let Some(d) = doc.get("delta") {
        SparseDelta::load(Path::new(d.as_str()?), engine.model())?
    } else {
        bail!("adapter upload needs a 'journal' or 'delta' path");
    };
    let nnz = delta.nnz();
    let bytes = delta.host_bytes();
    let evicted = engine.registry.insert(&name, delta)?;
    Ok(Json::obj(vec![
        ("name", Json::Str(name)),
        ("nnz", Json::Num(nnz as f64)),
        ("bytes", Json::Num(bytes as f64)),
        ("evicted", Json::Arr(evicted.into_iter().map(Json::Str).collect())),
    ]))
}

/// Classify failures that map to distinct HTTP statuses.
enum ClassifyError {
    /// the named adapter is not registered (404)
    UnknownAdapter(anyhow::Error),
    /// anything else the caller got wrong (400)
    Bad(anyhow::Error),
}

impl From<anyhow::Error> for ClassifyError {
    fn from(e: anyhow::Error) -> ClassifyError {
        ClassifyError::Bad(e)
    }
}

/// Micro-batched classification: parse rows, enqueue, block on the
/// ticket, render logits + argmax.
fn post_classify(engine: &ServeEngine, body: &str) -> Result<Json, ClassifyError> {
    let doc = json::parse(body).context("request body")?;
    let adapter = doc.req("adapter")?.as_str()?.to_string();
    if !engine.registry.contains(&adapter) {
        return Err(ClassifyError::UnknownAdapter(anyhow!(
            "no adapter '{adapter}' registered"
        )));
    }
    let prompts = doc.req("prompts")?.as_arr()?;
    if prompts.is_empty() {
        return Err(ClassifyError::Bad(anyhow!("'prompts' is empty")));
    }
    let mut rows: Vec<Vec<i32>> = Vec::with_capacity(prompts.len());
    for p in prompts {
        let mut row = Vec::new();
        for t in p.as_arr()? {
            row.push(t.as_usize()? as i32);
        }
        rows.push(row);
    }
    let n = rows.len();
    let logits = engine.batcher.submit(&adapter, rows).wait()?;
    let argmax: Vec<Json> = logits
        .iter()
        .map(|row| {
            let mut best = 0usize;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            Json::Num(best as f64)
        })
        .collect();
    Ok(Json::obj(vec![
        ("adapter", Json::Str(adapter)),
        ("rows", Json::Num(n as f64)),
        ("vocab", Json::Num(engine.model().vocab as f64)),
        ("logits", Json::Arr(logits.iter().map(|r| Json::from_f32s(r)).collect())),
        ("argmax", Json::Arr(argmax)),
    ]))
}

// ---------------------------------------------------------------------------
// wire plumbing
// ---------------------------------------------------------------------------

/// Find the first occurrence of `needle` in `haystack`.
fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one request: request line, headers (only `Content-Length` is
/// interpreted), body.
fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > (1 << 20) {
            bail!("request headers too large");
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-utf8 headers")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("request line lacks a path"))?.to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("Content-Length")?;
            }
        }
    }
    if content_length > (64 << 20) {
        bail!("request body too large ({content_length} bytes)");
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, body: String::from_utf8(body).context("non-utf8 body")? })
}

/// Canonical reason phrases for the statuses this server emits.
fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    }
}

/// Write one JSON response and flush.
fn write_response(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    let payload = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status_text(status),
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// The curl-free loopback client: one request, parsed JSON back.
/// `(status, body)`; an empty response body parses as `Json::Null`.
/// This is the client `tests/serve.rs`, the CI smoke and the README
/// example all share.
pub fn loopback_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> Result<(u16, Json)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    let payload = body.map(|b| b.to_string()).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let header_end =
        find_subslice(&raw, b"\r\n\r\n").ok_or_else(|| anyhow!("malformed response"))?;
    let head = std::str::from_utf8(&raw[..header_end])?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("no status in '{head}'"))?
        .parse()
        .context("status code")?;
    let body_text = std::str::from_utf8(&raw[header_end + 4..])?;
    let body = if body_text.trim().is_empty() {
        Json::Null
    } else {
        json::parse(body_text).with_context(|| format!("response body of {method} {path}"))?
    };
    Ok((status, body))
}
