//! Minimal std-only HTTP/1.1 loopback server + client.
//!
//! No HTTP crate exists in the vendored dependency set, so this module
//! hand-rolls exactly the subset the serving API needs: HTTP/1.1
//! keep-alive connections (one request at a time per connection,
//! `Content-Length` bodies, JSON in and out), with a bounded concurrent
//! connection pool. Endpoints:
//!
//! | method + path                | action |
//! |------------------------------|--------|
//! | `GET  /healthz`              | liveness + registry/queue/jobs/remote-worker gauges (every number is read back from the [`crate::obs`] gauge registry, so `/healthz` and `/metrics` can never disagree) |
//! | `GET  /metrics`              | Prometheus text exposition of the whole [`crate::obs`] registry (the one non-JSON endpoint) |
//! | `GET  /statsz`               | JSON snapshot of the same registry with precomputed histogram quantiles (what the `stats` CLI renders) |
//! | `GET  /v1/adapters`          | list registered adapters (nnz, bytes, hits, pins) |
//! | `POST /v1/adapters`          | register: `{"name", "journal": path}` replays a step journal against the base and extracts the delta under its mask-union certificate; `{"name", "delta": path}` loads a saved `.adapter` file |
//! | `POST /v1/classify`          | `{"adapter", "prompts": [[tok,...],...]}` → per-row logits + candidate-free argmax, micro-batched with concurrent same-adapter requests; the adapter is pinned against eviction while the request is in flight |
//! | `POST /v1/jobs`              | submit a fine-tuning job ([`JobSpec`](crate::jobs::JobSpec) JSON) |
//! | `POST /v1/jobs/grid`         | submit a sweep grid ([`GridSpec`](crate::jobs::GridSpec) JSON) — fans out to N queued cells, answers the parent status |
//! | `GET  /v1/jobs`              | list jobs (id, state, progress) and grid parents |
//! | `GET  /v1/jobs/{id}`         | one job's full state, or a grid parent's derived status |
//! | `GET  /v1/jobs/{id}/timeline`| the job's flight-recorder timeline: downsampled per-step series (loss, `g`, sparsity, mask churn), worker attribution, timings, active alerts, `trace_id` |
//! | `POST /v1/jobs/{id}/cancel`  | request cancellation (honored at the next step boundary); on a grid parent, fans out to every non-terminal cell |
//! | `POST /v1/jobs/{id}/resume`  | re-queue a cancelled/failed job (continues bit-identically from its journal); on a grid parent, fans out to every resumable cell |
//!
//! The `/v1/jobs` family answers 400 with an explanatory error when the
//! server was started without a jobs directory. A request declaring a
//! `Content-Length` above [`MAX_BODY_BYTES`] is answered `413` before
//! any body byte is read or buffered.
//!
//! Logits cross the wire losslessly: `f32 → f64` is exact, the JSON
//! writer emits shortest round-trip decimal for f64, and the client
//! parses it back to the identical bits — so a served classification is
//! bit-comparable to offline evaluation (asserted in `tests/serve.rs`).
//!
//! Threading: one accept thread admitting at most [`MAX_CONNECTIONS`]
//! concurrent connection threads (excess accepts wait for a slot — the
//! bounded pool), one dispatcher thread draining the
//! [`MicroBatcher`](super::batching::MicroBatcher), and — when jobs are
//! enabled — one background [`Scheduler`](crate::jobs::Scheduler)
//! thread slicing fine-tuning jobs over the same worker pool.
//! Connections are persistent (`Connection: keep-alive` is the HTTP/1.1
//! default), so job polling and classify traffic reuse one TCP
//! connection via [`LoopbackClient`] instead of paying a
//! connect/teardown per request; [`loopback_request`] remains the
//! one-shot (`Connection: close`) convenience.
//! [`RunningServer::shutdown`] flips the stop flag, drains the batcher,
//! pokes the listener with a loopback connect, and joins all three.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::jobs::{GridSpec, JobQueue, JobSpec, Scheduler};
use crate::util::json::{self, Json};

use super::batching::ServeEngine;
use super::delta::SparseDelta;

/// Cap on concurrently-served connections. Accepts beyond the cap wait
/// for a slot instead of spawning unboundedly — the bounded pool that
/// keeps a polling storm from exhausting threads.
pub const MAX_CONNECTIONS: usize = 64;

/// Cap on an HTTP message body, both directions. A request declaring
/// more than this is rejected with `413` *before* any body byte is
/// read or buffered — a malformed or hostile `Content-Length` must not
/// be able to park the read loop on a gigabyte promise — and the
/// clients refuse to buffer responses past the same bound.
pub const MAX_BODY_BYTES: usize = 64 << 20;

/// A parsed inbound request.
struct Request {
    method: String,
    path: String,
    body: String,
    /// connection persists after this request (HTTP/1.1 default)
    keep_alive: bool,
}

/// A request-read failure carrying the HTTP status it should answer
/// with (400 for malformed bytes, 413 for an oversized body claim).
struct HttpError {
    status: u16,
    err: anyhow::Error,
}

impl From<anyhow::Error> for HttpError {
    fn from(err: anyhow::Error) -> HttpError {
        HttpError { status: 400, err }
    }
}

/// Counting semaphore for live connections (std has no Semaphore).
struct ConnSlots {
    count: Mutex<usize>,
    freed: Condvar,
}

impl ConnSlots {
    fn new() -> Arc<ConnSlots> {
        Arc::new(ConnSlots { count: Mutex::new(0), freed: Condvar::new() })
    }

    /// Block until a slot is free, then take it. Returns `false`
    /// without taking a slot when `stop` flips — a saturated pool must
    /// never be able to hang shutdown.
    fn acquire(&self, stop: &AtomicBool) -> bool {
        let mut count = self.count.lock().unwrap();
        while *count >= MAX_CONNECTIONS {
            if stop.load(Ordering::Acquire) {
                return false;
            }
            let (guard, _) =
                self.freed.wait_timeout(count, Duration::from_millis(100)).unwrap();
            count = guard;
        }
        if stop.load(Ordering::Acquire) {
            return false;
        }
        *count += 1;
        true
    }

    fn release(&self) {
        let mut count = self.count.lock().unwrap();
        *count = count.saturating_sub(1);
        self.freed.notify_one();
    }
}

/// Handle to a live server; dropping it shuts the server down.
pub struct RunningServer {
    /// the bound loopback address (`127.0.0.1:port`)
    pub addr: SocketAddr,
    engine: Arc<ServeEngine>,
    stop_flag: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    dispatch: Option<JoinHandle<()>>,
    scheduler: Option<JoinHandle<()>>,
}

impl RunningServer {
    /// Stop accepting, drain in-flight batches, join the server threads
    /// (including the job scheduler, which stops at its next slice
    /// boundary).
    pub fn shutdown(mut self) {
        self.stop_impl();
    }

    /// Block on the accept thread forever (the CLI `serve` command's
    /// foreground mode).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }

    fn stop_impl(&mut self) {
        if self.accept.is_none() && self.dispatch.is_none() && self.scheduler.is_none() {
            return;
        }
        self.stop_flag.store(true, Ordering::Release);
        self.engine.batcher.shutdown();
        // poke the blocking accept() so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RunningServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Bind `127.0.0.1:port` (0 = ephemeral) and start serving `engine`.
/// When the engine carries a jobs handle, a background scheduler thread
/// is started alongside the accept/dispatch pair.
pub fn serve(engine: Arc<ServeEngine>, port: u16) -> Result<RunningServer> {
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    let addr = listener.local_addr()?;
    let stop_flag = Arc::new(AtomicBool::new(false));
    let slots = ConnSlots::new();

    let dispatch = {
        let engine = Arc::clone(&engine);
        thread::Builder::new()
            .name("smz-serve-batch".into())
            .spawn(move || engine.batcher.run(|adapter, rows| engine.classify(adapter, rows)))?
    };
    let scheduler = match engine.jobs() {
        Some(handle) => {
            let sched = Scheduler::new(
                Arc::clone(&engine),
                Arc::clone(&handle.queue),
                handle.slice_steps,
            );
            // a restarted server re-registers the durable adapter
            // artifacts of already-published jobs before taking traffic
            let restored = sched.reload_published();
            if restored > 0 {
                crate::info!("[jobs] restored {restored} published adapter(s) from artifacts");
            }
            let stop = Arc::clone(&stop_flag);
            Some(
                thread::Builder::new()
                    .name("smz-serve-jobs".into())
                    .spawn(move || sched.run_loop(&stop))?,
            )
        }
        None => None,
    };
    let accept = {
        let engine = Arc::clone(&engine);
        let stop_flag = Arc::clone(&stop_flag);
        let slots = Arc::clone(&slots);
        thread::Builder::new().name("smz-serve-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                // bounded pool: wait for a free slot (stop-aware)
                if !slots.acquire(&stop_flag) {
                    break;
                }
                let engine = Arc::clone(&engine);
                let slots_for_conn = Arc::clone(&slots);
                let stop_for_conn = Arc::clone(&stop_flag);
                let spawned = thread::Builder::new().name("smz-serve-conn".into()).spawn(
                    move || {
                        handle_connection(&engine, stream, &stop_for_conn);
                        slots_for_conn.release();
                    },
                );
                if spawned.is_err() {
                    slots.release();
                }
            }
        })?
    };
    Ok(RunningServer {
        addr,
        engine,
        stop_flag,
        accept: Some(accept),
        dispatch: Some(dispatch),
        scheduler,
    })
}

/// Serve requests on one connection until the peer closes, asks for
/// `Connection: close`, errors, goes idle past the read timeout, or the
/// server shuts down. A 400 is only ever written in response to bytes
/// the peer actually sent — an idle timeout *between* requests closes
/// silently, so a keep-alive client can never read a stale unsolicited
/// error as the answer to its next request.
fn handle_connection(engine: &ServeEngine, mut stream: TcpStream, stop: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let req = match read_request(&mut stream, &mut buf) {
            Ok(Some(req)) => req,
            Ok(None) => break, // clean close (or idle timeout) between requests
            Err(e) => {
                // 400 or 413; either way the connection closes (an
                // unread or malformed body cannot be resynchronized)
                let _ = write_response(&mut stream, e.status, &error_json(&e.err), false);
                break;
            }
        };
        let keep_alive = req.keep_alive;
        let label = route_label(&req.path);
        let started = Instant::now();
        // `/metrics` is the one plain-text endpoint; everything else
        // routes to a JSON body
        let write_ok = if req.method == "GET" && req.path == "/metrics" {
            sync_gauges(engine);
            let text = crate::obs::render_prometheus();
            write_text_response(&mut stream, 200, &text, keep_alive).is_ok()
        } else {
            let (status, body) = route(engine, &req);
            write_response(&mut stream, status, &body, keep_alive).is_ok()
        };
        crate::obs::counter("http_requests_total", &[("route", label)]).inc();
        crate::obs::histogram("http_request_seconds", &[("route", label)])
            .observe(started.elapsed().as_secs_f64());
        if !write_ok || !keep_alive || stop.load(Ordering::Acquire) {
            break;
        }
    }
}

/// `{"error": "<context chain>"}`.
fn error_json(e: &anyhow::Error) -> Json {
    Json::obj(vec![("error", Json::Str(format!("{e:#}")))])
}

/// Collapse a request path onto the fixed route-label set so the
/// `http_requests_total{route=...}` family stays bounded no matter what
/// paths peers probe (`/v1/jobs/123/cancel` counts as `/v1/jobs`;
/// unknown paths count as `other`).
fn route_label(path: &str) -> &'static str {
    match path {
        "/healthz" => "/healthz",
        "/metrics" => "/metrics",
        "/statsz" => "/statsz",
        "/v1/adapters" => "/v1/adapters",
        "/v1/classify" => "/v1/classify",
        p if p == "/v1/jobs" || p.starts_with("/v1/jobs/") => "/v1/jobs",
        _ => "other",
    }
}

/// Copy this engine's live occupancy numbers into the process-global
/// gauge registry. Runs at scrape time (`/healthz`, `/metrics`,
/// `/statsz`) rather than at mutation sites, so a process hosting
/// several engines (tests) always reports the engine actually being
/// scraped, and a series that drops to zero is overwritten instead of
/// going stale.
fn sync_gauges(engine: &ServeEngine) {
    crate::obs::sync_build_info();
    crate::obs::mem::sync_registry();
    crate::runtime::store::sync_registry();
    crate::obs::gauge("serve_registry_adapters", &[]).set(engine.registry.len() as i64);
    crate::obs::gauge("serve_registry_bytes", &[]).set(engine.registry.bytes() as i64);
    crate::obs::gauge("serve_working_set_bytes", &[])
        .set(engine.registry.working_set_bytes() as i64);
    crate::obs::gauge("serve_pending_requests", &[]).set(engine.batcher.pending() as i64);
    if let Some(handle) = engine.jobs() {
        crate::obs::gauge("jobs_active", &[]).set(handle.queue.active() as i64);
        for (state, class, n) in handle.queue.depth_stats() {
            crate::obs::gauge("jobs_queue_depth", &[("state", state), ("priority", class)])
                .set(n as i64);
        }
    }
    if let Some(hub) = engine.worker_hub() {
        crate::obs::gauge("transport_workers_connected", &[]).set(hub.connected() as i64);
        crate::obs::gauge("transport_worker_sessions_served", &[])
            .set(hub.sessions_served() as i64);
    }
}

/// Dispatch one request to its endpoint.
fn route(engine: &ServeEngine, req: &Request) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, healthz(engine)),
        ("GET", "/statsz") => {
            sync_gauges(engine);
            (200, crate::obs::snapshot_json())
        }
        ("GET", "/v1/adapters") => (200, list_adapters(engine)),
        ("POST", "/v1/adapters") => match post_adapter(engine, &req.body) {
            Ok(body) => (200, body),
            Err(e) => (400, error_json(&e)),
        },
        ("POST", "/v1/classify") => match post_classify(engine, &req.body) {
            Ok(body) => (200, body),
            Err(ClassifyError::UnknownAdapter(e)) => (404, error_json(&e)),
            Err(ClassifyError::Bad(e)) => (400, error_json(&e)),
        },
        ("POST", "/v1/jobs") => match post_job(engine, &req.body) {
            Ok(body) => (200, body),
            Err(e) => (400, error_json(&e)),
        },
        ("POST", "/v1/jobs/grid") => match post_grid(engine, &req.body) {
            Ok(body) => (200, body),
            Err(e) => (400, error_json(&e)),
        },
        ("GET", "/v1/jobs") => match list_jobs(engine) {
            Ok(body) => (200, body),
            Err(e) => (400, error_json(&e)),
        },
        (method, path) if path.starts_with("/v1/jobs/") => job_item(engine, method, path),
        _ => (
            404,
            Json::obj(vec![(
                "error",
                Json::Str(format!("no route {} {}", req.method, req.path)),
            )]),
        ),
    }
}

/// Every numeric gauge is synced into the [`crate::obs`] registry
/// first, then *read back from it* — the registry is the single source
/// of truth, so `/healthz`, `/metrics` and `/statsz` can never disagree
/// about the same quantity.
fn healthz(engine: &ServeEngine) -> Json {
    sync_gauges(engine);
    let g = |name: &str| Json::Num(crate::obs::gauge(name, &[]).get() as f64);
    // an active alert degrades health without failing liveness: `ok`
    // stays true (the process serves), `status` flips to "degraded" so
    // probes that care can distinguish
    let alerts = crate::obs::alerts::active_count();
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        (
            "status",
            Json::Str(if alerts == 0 { "ok" } else { "degraded" }.to_string()),
        ),
        ("alerts_active", Json::Num(alerts as f64)),
        ("platform", Json::Str(engine.runtime().backend().platform().to_string())),
        ("model", Json::Str(engine.model().name.clone())),
        ("adapters", g("serve_registry_adapters")),
        ("pending_requests", g("serve_pending_requests")),
        ("max_connections", Json::Num(MAX_CONNECTIONS as f64)),
    ];
    if engine.jobs().is_some() {
        fields.push(("jobs_enabled", Json::Bool(true)));
        fields.push(("jobs_active", g("jobs_active")));
    } else {
        fields.push(("jobs_enabled", Json::Bool(false)));
    }
    if engine.worker_hub().is_some() {
        fields.push(("workers_connected", g("transport_workers_connected")));
        fields.push(("worker_sessions_served", g("transport_worker_sessions_served")));
    }
    Json::obj(fields)
}

fn list_adapters(engine: &ServeEngine) -> Json {
    let stats = engine.registry.stats();
    Json::obj(vec![
        (
            "adapters",
            Json::Arr(
                stats
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::Str(s.name.clone())),
                            ("nnz", Json::Num(s.nnz as f64)),
                            ("bytes", Json::Num(s.bytes as f64)),
                            ("hits", Json::Num(s.hits as f64)),
                            ("in_use", Json::Bool(s.in_use)),
                            ("pinned", Json::Num(s.pinned as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("bytes", Json::Num(engine.registry.bytes() as f64)),
        ("byte_budget", Json::Num(engine.registry.byte_budget() as f64)),
    ])
}

/// Register an adapter from a journal replay or a saved adapter file.
fn post_adapter(engine: &ServeEngine, body: &str) -> Result<Json> {
    let doc = json::parse(body).context("request body")?;
    let name = doc.req("name")?.as_str()?.to_string();
    let delta = if let Some(j) = doc.get("journal") {
        let path = j.as_str()?.to_string();
        let base = engine.registry.base_snapshot();
        SparseDelta::from_journal(
            engine.runtime(),
            engine.model(),
            &base,
            Path::new(&path),
            vec![("name", Json::Str(name.clone()))],
        )?
    } else if let Some(d) = doc.get("delta") {
        SparseDelta::load(Path::new(d.as_str()?), engine.model())?
    } else {
        bail!("adapter upload needs a 'journal' or 'delta' path");
    };
    let nnz = delta.nnz();
    let bytes = delta.host_bytes();
    let evicted = engine.registry.insert(&name, delta)?;
    Ok(Json::obj(vec![
        ("name", Json::Str(name)),
        ("nnz", Json::Num(nnz as f64)),
        ("bytes", Json::Num(bytes as f64)),
        ("evicted", Json::Arr(evicted.into_iter().map(Json::Str).collect())),
    ]))
}

/// The jobs queue, or the explanatory error every `/v1/jobs` route
/// shares when the server runs without one.
fn jobs_queue(engine: &ServeEngine) -> Result<&Arc<JobQueue>> {
    engine
        .jobs()
        .map(|h| &h.queue)
        .ok_or_else(|| anyhow!("jobs are not enabled on this server (start with --jobs-dir)"))
}

/// `POST /v1/jobs`: submit a fine-tuning job.
fn post_job(engine: &ServeEngine, body: &str) -> Result<Json> {
    let queue = jobs_queue(engine)?;
    let spec = JobSpec::from_json(&json::parse(body).context("request body")?)?;
    let id = queue.submit(spec)?;
    Ok(queue.get(id)?.to_json())
}

/// `POST /v1/jobs/grid`: submit a sweep grid — one spec fanning out to
/// N queued cells. Answers the parent status (id, derived state, child
/// rows).
fn post_grid(engine: &ServeEngine, body: &str) -> Result<Json> {
    let queue = jobs_queue(engine)?;
    let spec = GridSpec::from_json(&json::parse(body).context("request body")?)?;
    let grid = queue.submit_grid(spec)?;
    queue.grid_status(grid.id)
}

/// `GET /v1/jobs`: every job (id order) and every grid parent.
fn list_jobs(engine: &ServeEngine) -> Result<Json> {
    let queue = jobs_queue(engine)?;
    let grids = queue
        .grids()
        .iter()
        .map(|g| queue.grid_status(g.id))
        .collect::<Result<Vec<Json>>>()?;
    Ok(Json::obj(vec![
        ("jobs", Json::Arr(queue.list().iter().map(|j| j.to_json()).collect())),
        ("grids", Json::Arr(grids)),
        ("active", Json::Num(queue.active() as f64)),
    ]))
}

/// `/v1/jobs/{id}` and `/v1/jobs/{id}/{cancel|resume}` — `id` may name
/// a plain job or a grid parent; grid cancel/resume fan out to the
/// non-terminal (resp. resumable) children and answer the parent
/// status.
fn job_item(engine: &ServeEngine, method: &str, path: &str) -> (u16, Json) {
    let queue = match jobs_queue(engine) {
        Ok(q) => q,
        Err(e) => return (400, error_json(&e)),
    };
    let rest = path.strip_prefix("/v1/jobs/").unwrap_or("");
    let mut segments = rest.split('/');
    let id: u64 = match segments.next().unwrap_or("").parse() {
        Ok(id) => id,
        Err(_) => return (404, error_json(&anyhow!("no route {method} {path}"))),
    };
    let action = segments.next();
    if segments.next().is_some() {
        return (404, error_json(&anyhow!("no route {method} {path}")));
    }
    let is_grid = queue.has_grid(id);
    let result = match (method, action, is_grid) {
        ("GET", None, false) => queue.get(id).map(|j| j.to_json()),
        ("GET", None, true) => queue.grid_status(id),
        ("GET", Some("timeline"), false) => job_timeline(queue, id),
        ("POST", Some("cancel"), false) => queue.cancel(id).map(|j| j.to_json()),
        ("POST", Some("cancel"), true) => {
            queue.cancel_grid(id).and_then(|_| queue.grid_status(id))
        }
        ("POST", Some("resume"), false) => queue.resume(id).map(|j| j.to_json()),
        ("POST", Some("resume"), true) => {
            queue.resume_grid(id).and_then(|_| queue.grid_status(id))
        }
        _ => return (404, error_json(&anyhow!("no route {method} {path}"))),
    };
    match result {
        Ok(body) => (200, body),
        Err(e) if format!("{e:#}").contains("no job") => (404, error_json(&e)),
        Err(e) => (400, error_json(&e)),
    }
}

/// `GET /v1/jobs/{id}/timeline`: the job's flight-recorder snapshot —
/// downsampled per-step series, worker attribution, timings — merged
/// with queue-side identity (state, `trace_id`) and the live alert set.
/// A job that has not run a step yet (still queued, or the server
/// restarted and the in-memory recorder is gone) answers an empty
/// timeline rather than a 404: the job exists, it just has no samples.
fn job_timeline(queue: &JobQueue, id: u64) -> Result<Json> {
    let job = queue.get(id)?;
    let timeline = match crate::obs::recorder::get(id) {
        Some(rec) => rec.timeline_json(),
        None => crate::obs::recorder::FlightRecorder::new(
            crate::obs::recorder::DEFAULT_BUDGET_BYTES,
        )
        .timeline_json(),
    };
    let Json::Obj(mut fields) = timeline else { bail!("timeline is not an object") };
    fields.insert("id".into(), Json::Num(job.id as f64));
    fields.insert("state".into(), Json::Str(job.state.as_str().into()));
    fields.insert("trace_id".into(), Json::Str(format!("{:016x}", job.trace_id)));
    fields.insert("alerts".into(), crate::obs::alerts::alerts_json(id));
    fields.insert("steps_done".into(), Json::Num(job.steps_done as f64));
    Ok(Json::Obj(fields))
}

/// Classify failures that map to distinct HTTP statuses.
enum ClassifyError {
    /// the named adapter is not registered (404)
    UnknownAdapter(anyhow::Error),
    /// anything else the caller got wrong (400)
    Bad(anyhow::Error),
}

impl From<anyhow::Error> for ClassifyError {
    fn from(e: anyhow::Error) -> ClassifyError {
        ClassifyError::Bad(e)
    }
}

/// Micro-batched classification: pin the adapter (admission = it cannot
/// be evicted until this request answers), parse rows, enqueue, block
/// on the ticket, render logits + argmax.
fn post_classify(engine: &ServeEngine, body: &str) -> Result<Json, ClassifyError> {
    let doc = json::parse(body).context("request body")?;
    let adapter = doc.req("adapter")?.as_str()?.to_string();
    let _pin = engine
        .registry
        .pin(&adapter)
        .map_err(ClassifyError::UnknownAdapter)?;
    let prompts = doc.req("prompts")?.as_arr()?;
    if prompts.is_empty() {
        return Err(ClassifyError::Bad(anyhow!("'prompts' is empty")));
    }
    let mut rows: Vec<Vec<i32>> = Vec::with_capacity(prompts.len());
    for p in prompts {
        let mut row = Vec::new();
        for t in p.as_arr()? {
            row.push(t.as_usize()? as i32);
        }
        rows.push(row);
    }
    let n = rows.len();
    let logits = engine.batcher.submit(&adapter, rows).wait()?;
    let argmax: Vec<Json> = logits
        .iter()
        .map(|row| {
            let mut best = 0usize;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            Json::Num(best as f64)
        })
        .collect();
    Ok(Json::obj(vec![
        ("adapter", Json::Str(adapter)),
        ("rows", Json::Num(n as f64)),
        ("vocab", Json::Num(engine.model().vocab as f64)),
        ("logits", Json::Arr(logits.iter().map(|r| Json::from_f32s(r)).collect())),
        ("argmax", Json::Arr(argmax)),
    ]))
}

// ---------------------------------------------------------------------------
// wire plumbing
// ---------------------------------------------------------------------------

/// Find the first occurrence of `needle` in `haystack`.
fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Grow `buf` from `stream` until it holds a `\r\n\r\n`-terminated head;
/// returns the head end offset, or `None` on a clean close — or a read
/// error (idle timeout, reset) — with no buffered bytes: either way the
/// peer sent nothing of a new message, so there is nothing to answer.
fn read_head(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<Option<usize>> {
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(pos) = find_subslice(buf, b"\r\n\r\n") {
            return Ok(Some(pos));
        }
        if buf.len() > (1 << 20) {
            bail!("message headers too large");
        }
        let n = match stream.read(&mut tmp) {
            Ok(n) => n,
            Err(_) if buf.is_empty() => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            bail!("connection closed mid-headers");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// Grow `buf` from `stream` until it holds at least `total` bytes.
fn read_until_len(stream: &mut TcpStream, buf: &mut Vec<u8>, total: usize) -> Result<()> {
    let mut tmp = [0u8; 4096];
    while buf.len() < total {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    Ok(())
}

/// Read one request out of the connection buffer (refilling from the
/// stream as needed), leaving any pipelined bytes for the next call.
/// `Ok(None)` = the peer closed cleanly between requests. The error
/// carries the status to answer with: `413` when the declared body
/// exceeds [`MAX_BODY_BYTES`] (detected before reading or buffering a
/// single body byte), `400` for anything malformed.
fn read_request(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<Option<Request>, HttpError> {
    let Some(header_end) = read_head(stream, buf)? else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-utf8 headers")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("request line lacks a path"))?.to_string();
    // HTTP/1.1 defaults to keep-alive; HTTP/1.0 to close
    let version = parts.next().unwrap_or("HTTP/1.1");
    let mut keep_alive = !version.eq_ignore_ascii_case("HTTP/1.0");
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse().context("Content-Length")?;
            } else if k.eq_ignore_ascii_case("connection") {
                if v.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if v.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError {
            status: 413,
            err: anyhow!(
                "request body too large ({content_length} bytes, cap {MAX_BODY_BYTES})"
            ),
        });
    }
    let body_start = header_end + 4;
    read_until_len(stream, buf, body_start + content_length)?;
    let body = String::from_utf8(buf[body_start..body_start + content_length].to_vec())
        .context("non-utf8 body")?;
    buf.drain(..body_start + content_length);
    Ok(Some(Request { method, path, body, keep_alive }))
}

/// Canonical reason phrases for the statuses this server emits.
fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        _ => "Internal Server Error",
    }
}

/// Write one JSON response and flush. The `Connection` header echoes
/// whether this connection stays open.
fn write_response(stream: &mut TcpStream, status: u16, body: &Json, keep_alive: bool) -> Result<()> {
    let payload = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status_text(status),
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Write one plain-text response and flush (`/metrics` — Prometheus
/// exposition format is `text/plain`, not JSON).
fn write_text_response(
    stream: &mut TcpStream,
    status: u16,
    payload: &str,
    keep_alive: bool,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status_text(status),
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// A persistent loopback client: one TCP connection, many requests
/// (HTTP/1.1 keep-alive). This is what job submit-then-poll loops and
/// classify traffic should use — no connect/teardown per request.
pub struct LoopbackClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl LoopbackClient {
    /// Connect to a running server.
    pub fn connect(addr: SocketAddr) -> Result<LoopbackClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(120)))?;
        Ok(LoopbackClient { stream, buf: Vec::new() })
    }

    /// One request/response over the persistent connection:
    /// `(status, parsed JSON body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<(u16, Json)> {
        let payload = body.map(|b| b.to_string()).unwrap_or_default();
        let (status, body_text) = self.raw_request(method, path, &payload)?;
        let body = if body_text.trim().is_empty() {
            Json::Null
        } else {
            json::parse(&body_text).with_context(|| format!("response body of {method} {path}"))?
        };
        Ok((status, body))
    }

    /// One request/response returning the raw body text: `(status,
    /// body)`. This is the path for the plain-text `/metrics`
    /// endpoint, whose Prometheus exposition body is not JSON.
    pub fn request_text(&mut self, method: &str, path: &str) -> Result<(u16, String)> {
        self.raw_request(method, path, "")
    }

    fn raw_request(&mut self, method: &str, path: &str, payload: &str) -> Result<(u16, String)> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: loopback\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            payload.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(payload.as_bytes())?;
        self.stream.flush()?;

        let header_end = read_head(&mut self.stream, &mut self.buf)?
            .ok_or_else(|| anyhow!("server closed the connection before responding"))?;
        let head = std::str::from_utf8(&self.buf[..header_end])?;
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .ok_or_else(|| anyhow!("no status in '{head}'"))?
            .parse()
            .context("status code")?;
        let mut content_length = 0usize;
        for line in head.split("\r\n").skip(1) {
            if let Some((k, v)) = line.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().context("Content-Length")?;
                }
            }
        }
        if content_length > MAX_BODY_BYTES {
            bail!("response body too large ({content_length} bytes, cap {MAX_BODY_BYTES})");
        }
        let body_start = header_end + 4;
        read_until_len(&mut self.stream, &mut self.buf, body_start + content_length)?;
        let body_text =
            std::str::from_utf8(&self.buf[body_start..body_start + content_length])?.to_string();
        self.buf.drain(..body_start + content_length);
        Ok((status, body_text))
    }
}

/// The curl-free one-shot client: one request on a fresh connection
/// (`Connection: close`), parsed JSON back. `(status, body)`; an empty
/// response body parses as `Json::Null`. Prefer [`LoopbackClient`] for
/// anything that issues more than one request.
pub fn loopback_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> Result<(u16, Json)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    let payload = body.map(|b| b.to_string()).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    let mut raw = Vec::new();
    // bounded read: headers + at most MAX_BODY_BYTES of body
    stream.take((MAX_BODY_BYTES + (1 << 20)) as u64).read_to_end(&mut raw)?;
    let header_end =
        find_subslice(&raw, b"\r\n\r\n").ok_or_else(|| anyhow!("malformed response"))?;
    let head = std::str::from_utf8(&raw[..header_end])?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("no status in '{head}'"))?
        .parse()
        .context("status code")?;
    let body_text = std::str::from_utf8(&raw[header_end + 4..])?;
    let body = if body_text.trim().is_empty() {
        Json::Null
    } else {
        json::parse(body_text).with_context(|| format!("response body of {method} {path}"))?
    };
    Ok((status, body))
}
