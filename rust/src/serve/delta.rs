//! Sparse adapter deltas: a fine-tuned model as `base + delta`.
//!
//! Sparse-MeZO's defining property is that an update only ever touches
//! masked coordinates, so a whole fine-tuning run compresses to the set
//! of coordinates its masks selected plus their final values — a
//! task-specific artifact proportional to `(1 - sparsity) * P`, not `P`.
//! [`SparseDelta`] is that artifact:
//!
//! * **extract** — diff a tuned parameter vector against the base by
//!   *bit* comparison, with an exact-sparsity certificate: when the
//!   caller supplies the union of the run's per-step masks (from
//!   [`replay_full`](crate::parallel::protocol::replay_full)), any
//!   changed coordinate outside that support is a hard error, locking
//!   the paper's §3.3 claim ("the update lives inside the mask") at
//!   export time.
//! * **swap** — the checkout primitive: exchange the delta's values with
//!   the parameter vector's in place. One call installs the adapter
//!   (and parks the base values in the delta); a second call restores
//!   the base **bit-for-bit**. No parameter copy is ever made, which is
//!   what lets the registry serve N tenants out of one resident vector.
//! * **save/load** — a compact on-disk form: a 1-bit/param support
//!   bitset (§3.3's quantized-mask representation) plus the raw f32
//!   values in ascending coordinate order, with an FNV-1a payload
//!   checksum. Exact by construction — the served logits are
//!   bit-identical to evaluating the tuned parameters directly.
//! * **save_paged** — the version-2 chunked layout (`SMZA2\n`): a
//!   per-page offset table over explicit `(idx, val)` arrays, aligned
//!   to the [`ParamStore`] page size so a paged server can locate one
//!   page's patch without scanning the whole support. [`load`]
//!   auto-detects either version by magic.
//!
//! [`load`]: SparseDelta::load

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::memory;
use crate::parallel::protocol;
use crate::runtime::store::{ParamStore, PAGE_FLOATS};
use crate::runtime::{ModelInfo, Runtime};
use crate::util::bitset;
use crate::util::json::{self, Json};

/// On-disk magic for the adapter format (version 1, bitset payload).
const MAGIC: &[u8] = b"SMZA1\n";
/// On-disk magic for the chunked adapter format (version 2, paged).
const MAGIC2: &[u8] = b"SMZA2\n";

/// A compact sparse adapter: the coordinates a fine-tuning run touched
/// and their values. At rest `val[k]` holds the *tuned* value of
/// coordinate `idx[k]`; while checked out (after one [`swap`]) it holds
/// the parked *base* value — see [`SparseDelta::swap`].
///
/// [`swap`]: SparseDelta::swap
#[derive(Debug, Clone)]
pub struct SparseDelta {
    /// model name the delta belongs to (ABI fingerprint)
    pub model: String,
    /// parameter count of that model (ABI fingerprint)
    pub n_params: usize,
    /// touched coordinates, ascending
    idx: Vec<u32>,
    /// the touched coordinates' values (tuned at rest; base mid-checkout)
    val: Vec<f32>,
    /// free-form provenance (source journal, task, optimizer, steps)
    pub meta: Json,
}

impl SparseDelta {
    /// Diff `tuned` against `base` by bit comparison. With
    /// `allowed = Some(support)` (a 1-bit/param bitset, normally the
    /// mask union from a journal replay), any changed coordinate outside
    /// the support fails the export — the exact-sparsity invariant.
    pub fn extract(
        model: &ModelInfo,
        base: &[f32],
        tuned: &[f32],
        allowed: Option<&[u64]>,
        meta: Json,
    ) -> Result<SparseDelta> {
        if base.len() != model.n_params || tuned.len() != model.n_params {
            bail!(
                "extract: base/tuned len {}/{} != model '{}' n_params {}",
                base.len(),
                tuned.len(),
                model.name,
                model.n_params
            );
        }
        if let Some(bits) = allowed {
            if bits.len() != bitset::words(model.n_params) {
                bail!(
                    "extract: support bitset has {} words, expected {}",
                    bits.len(),
                    bitset::words(model.n_params)
                );
            }
        }
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for i in 0..model.n_params {
            if base[i].to_bits() == tuned[i].to_bits() {
                continue;
            }
            if let Some(bits) = allowed {
                if !bitset::get(bits, i) {
                    bail!(
                        "exact-sparsity invariant violated: coordinate {i} changed \
                         ({} -> {}) outside the declared mask support",
                        base[i],
                        tuned[i]
                    );
                }
            }
            idx.push(i as u32);
            val.push(tuned[i]);
        }
        Ok(SparseDelta { model: model.name.clone(), n_params: model.n_params, idx, val, meta })
    }

    /// Materialize an adapter from a step journal: rebuild the journal's
    /// config from its self-describing header, replay it from `base`
    /// (no forward passes), and extract the delta under the replay's
    /// mask-union certificate. `base` must be the parameter vector the
    /// journaled run started from — the registry's resident base.
    pub fn from_journal(
        rt: &Runtime,
        model: &ModelInfo,
        base: &[f32],
        path: &Path,
        mut meta: Vec<(&str, Json)>,
    ) -> Result<SparseDelta> {
        let (header, records) = protocol::load_journal(path)?;
        let cfg = protocol::config_from_header(&header)
            .with_context(|| format!("journal {} header", path.display()))?;
        if cfg.model != model.name {
            bail!("journal is for model '{}', server hosts '{}'", cfg.model, model.name);
        }
        let outcome = protocol::replay_full(rt, model, &cfg, &header, base, &records)?;
        meta.extend([
            ("source", Json::Str(format!("journal:{}", path.display()))),
            ("task", Json::Str(cfg.task.clone())),
            ("optimizer", Json::Str(cfg.optimizer.clone())),
            ("steps", Json::Num(outcome.steps as f64)),
        ]);
        SparseDelta::extract(model, base, &outcome.params, Some(&outcome.mask_union), Json::obj(meta))
    }

    /// Number of touched coordinates.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// The touched coordinates, ascending.
    pub fn indices(&self) -> &[u32] {
        &self.idx
    }

    /// The stored values (tuned at rest, parked base values mid-checkout).
    pub fn values(&self) -> &[f32] {
        &self.val
    }

    /// The support as a 1-bit/param bitset (§3.3 representation).
    pub fn support_bitset(&self) -> Vec<u64> {
        let mut bits = bitset::new(self.n_params);
        for &i in &self.idx {
            bitset::set(&mut bits, i as usize);
        }
        bits
    }

    /// Host bytes this adapter accounts for in the registry budget.
    pub fn host_bytes(&self) -> usize {
        memory::sparse_adapter_bytes(self.n_params, self.nnz())
    }

    /// Exchange the delta's stored values with `params` at the support
    /// coordinates. **Involution**: the first call installs the tuned
    /// values (checkout) and parks the base values in the delta; the
    /// second call restores `params` to its prior state bit-for-bit
    /// (release). No copy of `params` is ever taken.
    pub fn swap(&mut self, params: &mut [f32]) {
        debug_assert_eq!(params.len(), self.n_params);
        for (i, v) in self.idx.iter().zip(self.val.iter_mut()) {
            std::mem::swap(&mut params[*i as usize], v);
        }
    }

    /// [`swap`](SparseDelta::swap) against a [`ParamStore`] instead of a
    /// flat slice — the same copy-free involution, expressed as
    /// page-granular read-modify-writes so a file-backed store only
    /// faults the pages the support actually touches. Bit-identical to
    /// `swap` on the equivalent flat vector.
    pub fn swap_store(&mut self, store: &ParamStore) {
        debug_assert_eq!(store.len(), self.n_params);
        let mut k = 0usize;
        while k < self.idx.len() {
            let page = self.idx[k] as usize / PAGE_FLOATS;
            let mut end = k + 1;
            while end < self.idx.len() && self.idx[end] as usize / PAGE_FLOATS == page {
                end += 1;
            }
            let lo = self.idx[k] as usize;
            let hi = self.idx[end - 1] as usize;
            let idxs = &self.idx[k..end];
            let vals = &mut self.val[k..end];
            store.update_runs(lo, hi - lo + 1, |goff, run| {
                for (i, v) in idxs.iter().zip(vals.iter_mut()) {
                    std::mem::swap(&mut run[*i as usize - goff], v);
                }
            });
            k = end;
        }
    }

    /// Write the compact on-disk form (creating parent dirs); returns
    /// bytes written. Layout: magic, one JSON header line, the support
    /// bitset (LE u64 words), the values (LE f32, ascending coordinate
    /// order). Must be called at rest (not mid-checkout), or the parked
    /// base values would be serialized as the adapter.
    pub fn save(&self, path: &Path) -> Result<usize> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let bits = self.support_bitset();
        let mut payload = Vec::with_capacity(bits.len() * 8 + self.val.len() * 4);
        for w in &bits {
            payload.extend_from_slice(&w.to_le_bytes());
        }
        for v in &self.val {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let header = Json::obj(vec![
            ("kind", Json::Str("sparse-adapter".into())),
            ("model", Json::Str(self.model.clone())),
            ("n_params", Json::Num(self.n_params as f64)),
            ("nnz", Json::Num(self.nnz() as f64)),
            ("checksum", Json::Str(format!("{:016x}", fnv64(&payload)))),
            ("meta", self.meta.clone()),
        ]);
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let head = header.to_string();
        f.write_all(MAGIC)?;
        f.write_all(head.as_bytes())?;
        f.write_all(b"\n")?;
        f.write_all(&payload)?;
        Ok(MAGIC.len() + head.len() + 1 + payload.len())
    }

    /// Write the version-2 chunked form (creating parent dirs); returns
    /// bytes written. Layout: magic `SMZA2\n`, one JSON header line,
    /// then a chunk table of `(page u32, start u32)` LE pairs — one per
    /// [`PAGE_FLOATS`]-sized page with support, `start` indexing into
    /// the arrays that follow — then the `idx` u32s and `val` f32s (LE,
    /// ascending coordinate order). Same at-rest-only caveat as
    /// [`save`](SparseDelta::save).
    pub fn save_paged(&self, path: &Path) -> Result<usize> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut chunks: Vec<(u32, u32)> = Vec::new();
        for (k, &i) in self.idx.iter().enumerate() {
            let page = (i as usize / PAGE_FLOATS) as u32;
            if chunks.last().map(|c| c.0) != Some(page) {
                chunks.push((page, k as u32));
            }
        }
        let mut payload = Vec::with_capacity(chunks.len() * 8 + self.idx.len() * 8);
        for (p, s) in &chunks {
            payload.extend_from_slice(&p.to_le_bytes());
            payload.extend_from_slice(&s.to_le_bytes());
        }
        for i in &self.idx {
            payload.extend_from_slice(&i.to_le_bytes());
        }
        for v in &self.val {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let header = Json::obj(vec![
            ("kind", Json::Str("sparse-adapter".into())),
            ("model", Json::Str(self.model.clone())),
            ("n_params", Json::Num(self.n_params as f64)),
            ("nnz", Json::Num(self.nnz() as f64)),
            ("n_chunks", Json::Num(chunks.len() as f64)),
            ("page_floats", Json::Num(PAGE_FLOATS as f64)),
            ("checksum", Json::Str(format!("{:016x}", fnv64(&payload)))),
            ("meta", self.meta.clone()),
        ]);
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let head = header.to_string();
        f.write_all(MAGIC2)?;
        f.write_all(head.as_bytes())?;
        f.write_all(b"\n")?;
        f.write_all(&payload)?;
        Ok(MAGIC2.len() + head.len() + 1 + payload.len())
    }

    /// Read an adapter back — either on-disk version, auto-detected by
    /// magic — validating model ABI, payload length, support/nnz/chunk
    /// consistency and the checksum before decoding anything. Values
    /// round-trip bit-for-bit; a failed load is a clean error, never a
    /// panic or a partially constructed delta.
    pub fn load(path: &Path, expect: &ModelInfo) -> Result<SparseDelta> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open adapter {path:?}"))?
            .read_to_end(&mut bytes)?;
        let v2 = bytes.starts_with(MAGIC2);
        if !v2 && !bytes.starts_with(MAGIC) {
            bail!("{path:?} is not a sparse-adapter file (bad magic)");
        }
        let rest = &bytes[MAGIC.len()..]; // both magics are 6 bytes
        let nl = rest
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("{path:?}: truncated header"))?;
        let header = json::parse(std::str::from_utf8(&rest[..nl])?)?;
        if header.req("kind")?.as_str()? != "sparse-adapter" {
            bail!("{path:?}: wrong kind");
        }
        let model = header.req("model")?.as_str()?.to_string();
        let n_params = header.req("n_params")?.as_usize()?;
        let nnz = header.req("nnz")?.as_usize()?;
        if model != expect.name || n_params != expect.n_params {
            bail!(
                "adapter is for model '{model}' ({n_params} params), server hosts '{}' ({})",
                expect.name,
                expect.n_params
            );
        }
        let payload = &rest[nl + 1..];
        let (n_chunks, page_floats) = if v2 {
            let c = header.req("n_chunks")?.as_usize()?;
            let pf = header.req("page_floats")?.as_usize()?;
            if pf == 0 {
                bail!("{path:?}: page_floats must be positive");
            }
            (c, pf)
        } else {
            (0, 0)
        };
        // length before checksum, so truncation reports as truncation
        let want =
            if v2 { n_chunks * 8 + nnz * 8 } else { bitset::words(n_params) * 8 + nnz * 4 };
        if payload.len() != want {
            bail!("{path:?}: payload {} bytes, expected {want}", payload.len());
        }
        let checksum = header.req("checksum")?.as_str()?.to_string();
        let got = format!("{:016x}", fnv64(payload));
        if got != checksum {
            bail!("{path:?}: checksum mismatch ({got} != {checksum})");
        }
        let (idx, val) = if v2 {
            decode_chunked(path, payload, n_params, nnz, n_chunks, page_floats)?
        } else {
            decode_bitset(path, payload, n_params, nnz)?
        };
        Ok(SparseDelta {
            model,
            n_params,
            idx,
            val,
            meta: header.get("meta").cloned().unwrap_or(Json::Null),
        })
    }
}

/// Decode the version-1 payload: support bitset words then values.
fn decode_bitset(
    path: &Path,
    payload: &[u8],
    n_params: usize,
    nnz: usize,
) -> Result<(Vec<u32>, Vec<f32>)> {
    let words = bitset::words(n_params);
    let mut bits = Vec::with_capacity(words);
    for chunk in payload[..words * 8].chunks_exact(8) {
        bits.push(u64::from_le_bytes(chunk.try_into().unwrap()));
    }
    if bitset::count(&bits) != nnz {
        bail!("{path:?}: support popcount {} != nnz {nnz}", bitset::count(&bits));
    }
    let idx = bitset::indices(&bits, n_params);
    let mut val = Vec::with_capacity(nnz);
    for chunk in payload[words * 8..].chunks_exact(4) {
        val.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((idx, val))
}

/// Decode the version-2 payload: chunk table, coordinates, values —
/// rejecting chunk pages past the parameter space, starts past `nnz`,
/// non-ascending tables/coordinates, and page/chunk disagreement.
fn decode_chunked(
    path: &Path,
    payload: &[u8],
    n_params: usize,
    nnz: usize,
    n_chunks: usize,
    page_floats: usize,
) -> Result<(Vec<u32>, Vec<f32>)> {
    if (nnz == 0) != (n_chunks == 0) {
        bail!("{path:?}: {n_chunks} chunks for nnz {nnz}");
    }
    let mut chunks = Vec::with_capacity(n_chunks);
    for e in payload[..n_chunks * 8].chunks_exact(8) {
        let page = u32::from_le_bytes(e[..4].try_into().unwrap()) as usize;
        let start = u32::from_le_bytes(e[4..].try_into().unwrap()) as usize;
        chunks.push((page, start));
    }
    for (c, &(page, start)) in chunks.iter().enumerate() {
        if page * page_floats >= n_params {
            bail!("{path:?}: chunk {c} page {page} is past the {n_params}-param space");
        }
        if start >= nnz {
            bail!("{path:?}: chunk {c} start {start} is past nnz {nnz}");
        }
        if c == 0 && start != 0 {
            bail!("{path:?}: first chunk must start at 0, got {start}");
        }
        if c > 0 && (page <= chunks[c - 1].0 || start <= chunks[c - 1].1) {
            bail!("{path:?}: chunk table not strictly ascending at entry {c}");
        }
    }
    let mut idx = Vec::with_capacity(nnz);
    for e in payload[n_chunks * 8..n_chunks * 8 + nnz * 4].chunks_exact(4) {
        idx.push(u32::from_le_bytes(e.try_into().unwrap()));
    }
    for (k, &i) in idx.iter().enumerate() {
        if i as usize >= n_params {
            bail!("{path:?}: coordinate {i} out of range {n_params}");
        }
        if k > 0 && idx[k - 1] >= i {
            bail!("{path:?}: coordinates not strictly ascending at slot {k}");
        }
        // the chunk whose range covers slot k must name this page
        let c = chunks.partition_point(|&(_, s)| s <= k) - 1;
        if chunks[c].0 != i as usize / page_floats {
            bail!(
                "{path:?}: coordinate {i} (slot {k}) lies on page {}, chunk table says {}",
                i as usize / page_floats,
                chunks[c].0
            );
        }
    }
    let mut val = Vec::with_capacity(nnz);
    for e in payload[n_chunks * 8 + nnz * 4..].chunks_exact(4) {
        val.push(f32::from_le_bytes(e.try_into().unwrap()));
    }
    Ok((idx, val))
}

/// FNV-1a over a byte slice (the checkpoint/prng family's hash choice).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{LayoutEntry, ModelInfo};
    use std::collections::BTreeMap;

    fn toy_model(n_params: usize) -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            family: "llama".into(),
            size: "tiny".into(),
            n_layers: 1,
            d_model: 4,
            n_heads: 1,
            d_ff: 8,
            vocab: 16,
            seq_len: 8,
            batch: 2,
            window: 0,
            n_params,
            n_lora_params: 0,
            lora_rank: 0,
            n_entries: 1,
            n_hypers: 8,
            n_metrics: 8,
            layout: vec![LayoutEntry {
                name: "w".into(),
                shape: vec![n_params],
                kind: "matrix".into(),
                offset: 0,
                size: n_params,
                layer_id: 0,
            }],
            lora_layout: vec![],
            programs: BTreeMap::new(),
        }
    }

    #[test]
    fn extract_swap_is_a_bit_exact_involution() {
        let m = toy_model(10);
        let base: Vec<f32> = (0..10).map(|i| (i as f32).sin()).collect();
        let mut tuned = base.clone();
        tuned[2] = 7.25;
        tuned[5] = -base[5]; // sign-only change must count as changed
        tuned[9] = f32::MIN_POSITIVE; // tiny value survives the round trip
        let mut d = SparseDelta::extract(&m, &base, &tuned, None, Json::Null).unwrap();
        assert_eq!(d.indices(), &[2, 5, 9]);
        let mut p = base.clone();
        d.swap(&mut p); // checkout: install tuned
        for i in 0..10 {
            assert_eq!(p[i].to_bits(), tuned[i].to_bits(), "coord {i}");
        }
        d.swap(&mut p); // release: restore base
        for i in 0..10 {
            assert_eq!(p[i].to_bits(), base[i].to_bits(), "coord {i}");
        }
        // and the delta is whole again (tuned values at rest)
        assert_eq!(d.values()[0].to_bits(), 7.25f32.to_bits());
    }

    #[test]
    fn extract_enforces_the_support_certificate() {
        let m = toy_model(8);
        let base = vec![1.0f32; 8];
        let mut tuned = base.clone();
        tuned[3] = 2.0;
        tuned[6] = 3.0;
        let mut ok = bitset::new(8);
        bitset::set(&mut ok, 3);
        bitset::set(&mut ok, 6);
        assert!(SparseDelta::extract(&m, &base, &tuned, Some(&ok), Json::Null).is_ok());
        let mut narrow = bitset::new(8);
        bitset::set(&mut narrow, 3);
        let err = SparseDelta::extract(&m, &base, &tuned, Some(&narrow), Json::Null).unwrap_err();
        assert!(err.to_string().contains("coordinate 6"), "{err:#}");
    }

    #[test]
    fn save_load_round_trips_bit_exactly() {
        let m = toy_model(100);
        let base: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).cos()).collect();
        let mut tuned = base.clone();
        for i in (0..100).step_by(7) {
            tuned[i] = base[i] * 1.5 + 1e-4;
        }
        let d = SparseDelta::extract(
            &m,
            &base,
            &tuned,
            None,
            Json::obj(vec![("task", Json::Str("unit".into()))]),
        )
        .unwrap();
        let dir = std::env::temp_dir().join(format!("smz_delta_{}", std::process::id()));
        let path = dir.join("toy.adapter");
        let written = d.save(&path).unwrap();
        assert_eq!(written, std::fs::metadata(&path).unwrap().len() as usize);
        let back = SparseDelta::load(&path, &m).unwrap();
        assert_eq!(back.indices(), d.indices());
        for (a, b) in back.values().iter().zip(d.values()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.meta.req("task").unwrap().as_str().unwrap(), "unit");
        // wrong model rejected; corrupted payload rejected
        assert!(SparseDelta::load(&path, &toy_model(99)).is_err());
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(SparseDelta::load(&path, &m).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn paged_save_load_round_trips_and_swap_store_is_involution() {
        use crate::runtime::store::{ParamStore, PAGE_BYTES};
        let n = PAGE_FLOATS + 300; // support spans two pages
        let m = toy_model(n);
        let base: Vec<f32> = (0..n).map(|i| ((i % 113) as f32) * 0.03 - 1.5).collect();
        let mut tuned = base.clone();
        for i in (0..n).step_by(977) {
            tuned[i] = base[i] + 0.75;
        }
        let d = SparseDelta::extract(&m, &base, &tuned, None, Json::Null).unwrap();
        let dir = std::env::temp_dir().join(format!("smz_delta2_{}", std::process::id()));
        let path = dir.join("toy.adapter2");
        let written = d.save_paged(&path).unwrap();
        assert_eq!(written, std::fs::metadata(&path).unwrap().len() as usize);
        let mut back = SparseDelta::load(&path, &m).unwrap();
        assert_eq!(back.indices(), d.indices());
        for (a, b) in back.values().iter().zip(d.values()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // swap_store: install / restore against a 1-page-cache store
        let st = ParamStore::file_backed(&base, PAGE_BYTES).unwrap();
        back.swap_store(&st);
        let got = st.to_vec();
        for i in 0..n {
            assert_eq!(got[i].to_bits(), tuned[i].to_bits(), "install coord {i}");
        }
        back.swap_store(&st);
        let got = st.to_vec();
        for i in 0..n {
            assert_eq!(got[i].to_bits(), base[i].to_bits(), "restore coord {i}");
        }
        assert_eq!(back.values()[0].to_bits(), d.values()[0].to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }
}
