//! Dynamic micro-batching + the serving engine.
//!
//! Classify requests queue into a [`MicroBatcher`]; a dispatcher drains
//! it one *adapter group* at a time. A group (all queued requests for
//! one adapter, submission order) flushes when its row count reaches
//! `max_rows` (size trigger) or its oldest request has waited
//! `flush_ms` (deadline trigger) — the classic latency/throughput knob.
//! Grouping by adapter is what makes multi-tenancy cheap: one registry
//! checkout amortizes over every request in the group.
//!
//! [`ServeEngine::classify`] executes one fused group: check the
//! adapter out of the registry (copy-free swap), shard the padded rows
//! across the existing [`WorkerPool`], and re-concatenate per-row
//! logits in row order. Because each output row depends only on its own
//! tokens (the [`logits_rows`](crate::runtime::backend::Backend::logits_rows)
//! contract), the fold is **bit-identical to a serial pass** for any
//! worker count — asserted end-to-end in `tests/serve.rs` — and
//! splitting the fused output back per request in submission order is
//! plain bookkeeping, not arithmetic.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::ServeConfig;
use crate::data::batcher::pad_prompt;
use crate::jobs::JobQueue;
use crate::parallel::{WorkerHub, WorkerPool};
use crate::runtime::store::ParamStore;
use crate::runtime::{ModelInfo, Runtime};

use super::registry::{AdapterRegistry, TenantParams};

/// One-shot response slot a submitter blocks on.
pub struct Ticket {
    slot: Mutex<Option<Result<Vec<Vec<f32>>>>>,
    done: Condvar,
}

impl Ticket {
    fn new() -> Arc<Ticket> {
        Arc::new(Ticket { slot: Mutex::new(None), done: Condvar::new() })
    }

    fn fulfill(&self, result: Result<Vec<Vec<f32>>>) {
        *self.slot.lock().unwrap() = Some(result);
        self.done.notify_all();
    }

    /// Block until the dispatcher answers; returns per-row logits in
    /// the submitted row order.
    pub fn wait(&self) -> Result<Vec<Vec<f32>>> {
        let mut slot = self.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.done.wait(slot).unwrap();
        }
    }
}

/// One queued request: its adapter, rows, enqueue time and responder.
struct Pending {
    adapter: String,
    rows: Vec<Vec<i32>>,
    since: Instant,
    ticket: Arc<Ticket>,
}

/// Queue state behind the batcher lock.
struct Queue {
    pending: Vec<Pending>,
    shutdown: bool,
}

/// The size- and deadline-triggered request queue. See the module docs.
pub struct MicroBatcher {
    inner: Mutex<Queue>,
    ready: Condvar,
    max_rows: usize,
    max_delay: Duration,
}

impl MicroBatcher {
    /// A batcher flushing adapter groups at `max_rows` rows or after
    /// `flush_ms` milliseconds, whichever comes first.
    pub fn new(max_rows: usize, flush_ms: u64) -> MicroBatcher {
        MicroBatcher {
            inner: Mutex::new(Queue { pending: Vec::new(), shutdown: false }),
            ready: Condvar::new(),
            max_rows: max_rows.max(1),
            max_delay: Duration::from_millis(flush_ms),
        }
    }

    /// Enqueue `rows` for `adapter`; the returned ticket resolves when
    /// the dispatcher has run the group this request rode in.
    pub fn submit(&self, adapter: &str, rows: Vec<Vec<i32>>) -> Arc<Ticket> {
        let ticket = Ticket::new();
        let mut q = self.inner.lock().unwrap();
        if q.shutdown {
            ticket.fulfill(Err(anyhow!("server is shutting down")));
            return ticket;
        }
        q.pending.push(Pending {
            adapter: adapter.to_string(),
            rows,
            since: Instant::now(),
            ticket: Arc::clone(&ticket),
        });
        drop(q);
        self.ready.notify_all();
        ticket
    }

    /// Requests currently queued (health reporting).
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// Stop the dispatcher after it drains the queue; subsequent
    /// submits fail fast.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.ready.notify_all();
    }

    /// Extract the ripest adapter group (oldest head first), if any.
    fn take_ripe(&self, q: &mut Queue) -> Option<Vec<Pending>> {
        let now = Instant::now();
        let mut best: Option<(&str, Instant)> = None;
        // per-adapter totals; heads are the first occurrence in queue
        // order, so scanning forward keeps them
        let mut groups: Vec<(&str, usize, Instant)> = Vec::new();
        for p in &q.pending {
            match groups.iter_mut().find(|(name, _, _)| *name == p.adapter.as_str()) {
                Some((_, rows, _)) => *rows += p.rows.len(),
                None => groups.push((p.adapter.as_str(), p.rows.len(), p.since)),
            }
        }
        for (name, rows, head) in groups {
            let ripe = q.shutdown
                || rows >= self.max_rows
                || now.duration_since(head) >= self.max_delay;
            if ripe && best.map(|(_, h)| head < h).unwrap_or(true) {
                best = Some((name, head));
            }
        }
        let name = best.map(|(n, _)| n.to_string())?;
        let (taken, rest): (Vec<Pending>, Vec<Pending>) =
            q.pending.drain(..).partition(|p| p.adapter == name);
        q.pending = rest;
        Some(taken)
    }

    /// Dispatcher loop: drain groups through `exec` until [`shutdown`]
    /// *and* an empty queue. `exec` receives the group's adapter and its
    /// concatenated rows; its output is split back per request in
    /// submission order.
    ///
    /// [`shutdown`]: MicroBatcher::shutdown
    pub fn run<F>(&self, mut exec: F)
    where
        F: FnMut(&str, &[Vec<i32>]) -> Result<Vec<Vec<f32>>>,
    {
        loop {
            let group = {
                let mut q = self.inner.lock().unwrap();
                loop {
                    if let Some(g) = self.take_ripe(&mut q) {
                        break g;
                    }
                    if q.shutdown {
                        return; // shutdown + nothing ripe => queue empty
                    }
                    if q.pending.is_empty() {
                        q = self.ready.wait(q).unwrap();
                    } else {
                        // sleep until the oldest pending request's deadline
                        let oldest = q.pending.iter().map(|p| p.since).min().unwrap();
                        let dur = (oldest + self.max_delay)
                            .saturating_duration_since(Instant::now());
                        if dur == Duration::ZERO {
                            continue;
                        }
                        let (guard, _) = self.ready.wait_timeout(q, dur).unwrap();
                        q = guard;
                    }
                }
            };
            let adapter = group[0].adapter.clone();
            let mut rows: Vec<Vec<i32>> = Vec::new();
            let dispatched = Instant::now();
            let wait_histo = crate::obs::histogram("serve_batch_wait_seconds", &[]);
            for p in &group {
                rows.extend(p.rows.iter().cloned());
                wait_histo.observe(dispatched.duration_since(p.since).as_secs_f64());
            }
            crate::obs::histogram("serve_batch_rows", &[]).observe(rows.len() as f64);
            // a panicking exec (worker-pool scatter re-throws task panics
            // on this thread) must fail this group's tickets, not kill
            // the single dispatcher and wedge every future request
            let exec_span = crate::obs::span("serve.batch_exec");
            let result = catch_unwind(AssertUnwindSafe(|| exec(&adapter, &rows)))
                .unwrap_or_else(|payload| {
                    let msg = crate::util::panic_message(&*payload);
                    Err(anyhow!("classify panicked: {msg}"))
                });
            exec_span.end();
            match result {
                Ok(mut out) => {
                    // fold outputs back per request, submission order
                    for p in group {
                        let rest = out.split_off(p.rows.len());
                        p.ticket.fulfill(Ok(out));
                        out = rest;
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for p in group {
                        p.ticket.fulfill(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
    }
}

/// Jobs wiring attached to a serving engine: the queue the HTTP layer
/// serves (`/v1/jobs`) and the background scheduler drains.
pub struct JobsHandle {
    /// the persistent fine-tuning job queue
    pub queue: Arc<JobQueue>,
    /// default optimizer steps per scheduler slice (0 = scheduler default)
    pub slice_steps: usize,
}

/// The serving engine: runtime + registry + pool + batcher, the shared
/// state every connection handler and the dispatcher borrow.
pub struct ServeEngine {
    rt: Runtime,
    model: ModelInfo,
    /// the adapter registry (one base vector, N tenants)
    pub registry: AdapterRegistry,
    /// the shared scheduler fused forward passes shard across
    pub pool: WorkerPool,
    /// the request queue the HTTP layer submits into
    pub batcher: MicroBatcher,
    /// job orchestration, when enabled (`--jobs-dir`)
    jobs: Option<JobsHandle>,
    /// TCP hub parking remote `worker` processes for the scheduler to
    /// lease, when enabled (`--listen-workers`)
    worker_hub: Option<Arc<WorkerHub>>,
}

impl ServeEngine {
    /// Assemble an engine for `cfg.model` serving from a resident `base`
    /// vector.
    pub fn new(rt: Runtime, cfg: &ServeConfig, base: Vec<f32>) -> Result<ServeEngine> {
        Self::with_store(rt, cfg, Arc::new(ParamStore::resident(base)))
    }

    /// Assemble an engine serving from a shared [`ParamStore`] handle —
    /// paged when the store is file-backed (`--page-cache-bytes`).
    /// Paged tenants are served as overlay views on the native row
    /// path, so only the native backend supports a paged base.
    pub fn with_store(rt: Runtime, cfg: &ServeConfig, base: Arc<ParamStore>) -> Result<ServeEngine> {
        cfg.validate()?;
        let model = rt.model(&cfg.model)?.clone();
        if base.is_paged() && rt.backend().platform() != "native" {
            bail!("paged serving (--page-cache-bytes) requires the native backend");
        }
        let registry =
            AdapterRegistry::with_store(model.clone(), base, cfg.max_adapters, cfg.adapter_budget)?;
        Ok(ServeEngine {
            rt,
            model,
            registry,
            pool: WorkerPool::new(cfg.workers),
            batcher: MicroBatcher::new(cfg.max_batch_rows, cfg.flush_ms),
            jobs: None,
            worker_hub: None,
        })
    }

    /// Attach a job queue: the HTTP layer exposes `/v1/jobs` and
    /// [`http::serve`](super::http::serve) runs a background
    /// [`Scheduler`](crate::jobs::Scheduler) draining it over this
    /// engine's pool. Call before wrapping the engine in an [`Arc`].
    pub fn with_jobs(mut self, queue: Arc<JobQueue>, slice_steps: usize) -> ServeEngine {
        self.jobs = Some(JobsHandle { queue, slice_steps });
        self
    }

    /// The jobs wiring, when enabled.
    pub fn jobs(&self) -> Option<&JobsHandle> {
        self.jobs.as_ref()
    }

    /// Attach a worker hub: multi-shard job slices lease remote replicas
    /// from it (see [`crate::parallel::transport`]). Call before
    /// wrapping the engine in an [`Arc`].
    pub fn with_worker_hub(mut self, hub: Arc<WorkerHub>) -> ServeEngine {
        self.worker_hub = Some(hub);
        self
    }

    /// The TCP worker hub, when enabled.
    pub fn worker_hub(&self) -> Option<&Arc<WorkerHub>> {
        self.worker_hub.as_ref()
    }

    /// The served model's ABI description.
    pub fn model(&self) -> &ModelInfo {
        &self.model
    }

    /// The runtime (and through it, the compute backend).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Execute one fused classify for `adapter` over raw prompt rows:
    /// checkout, pad every row to `seq_len`, shard the ragged batch
    /// across the pool, fold per-row logits back in row order, release.
    /// Bit-identical to a serial pass over the same rows for any worker
    /// count.
    pub fn classify(&self, adapter: &str, rows: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        if rows.is_empty() {
            bail!("classify: no rows");
        }
        let _sp = crate::obs::span("serve.classify");
        let _mem = crate::obs::mem_scope("serve.batch");
        let vocab = self.model.vocab as i32;
        for (r, row) in rows.iter().enumerate() {
            if let Some(&t) = row.iter().find(|&&t| t < 0 || t >= vocab) {
                bail!("classify: row {r} token {t} outside vocab 0..{vocab}");
            }
        }
        let seq = self.model.seq_len;
        let n = rows.len();
        let co = self.registry.checkout(adapter)?;
        let tenant = co.tenant();
        let chunks = self.pool.parallelism().min(n).max(1);
        let per = (n + chunks - 1) / chunks;
        let parts = self.pool.scatter(chunks, |c| -> Result<Vec<f32>> {
            let lo = (c * per).min(n);
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                return Ok(Vec::new());
            }
            let mut tokens = Vec::with_capacity((hi - lo) * seq);
            for row in &rows[lo..hi] {
                tokens.extend(pad_prompt(row, seq));
            }
            // both arms feed the forward identical f32 values in
            // identical order, so logits are bitwise equal across tiers
            match &tenant {
                TenantParams::Flat(params) => {
                    self.rt.backend().logits_rows(&self.model, params, &tokens)
                }
                TenantParams::Paged(ov) => {
                    crate::runtime::native::logits_rows_src(&self.model, ov, &tokens)
                }
            }
        });
        let mut out = Vec::with_capacity(n);
        for part in parts {
            for row in part?.chunks(self.model.vocab) {
                out.push(row.to_vec());
            }
        }
        drop(co); // revert-on-release: the base is whole again
        if out.len() != n {
            bail!("classify: folded {} rows for {n} requests", out.len());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    /// Echo executor: logit row = [first token as f32]; records calls.
    fn echo(adapter: &str, rows: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        if adapter == "boom" {
            bail!("no such tenant");
        }
        Ok(rows.iter().map(|r| vec![r.first().copied().unwrap_or(-1) as f32]).collect())
    }

    #[test]
    fn groups_flush_by_size_and_split_in_submission_order() {
        let b = Arc::new(MicroBatcher::new(4, 60_000)); // deadline far away
        let calls = Arc::new(AtomicUsize::new(0));
        let dispatcher = {
            let b = Arc::clone(&b);
            let calls = Arc::clone(&calls);
            thread::spawn(move || {
                b.run(|a, rows| {
                    calls.fetch_add(1, Ordering::SeqCst);
                    echo(a, rows)
                })
            })
        };
        // 2 + 1 rows for "a" stay parked (size 3 < 4) until the fourth row
        let t1 = b.submit("a", vec![vec![10], vec![11]]);
        let t2 = b.submit("a", vec![vec![12]]);
        let t3 = b.submit("a", vec![vec![13]]);
        assert_eq!(t1.wait().unwrap(), vec![vec![10.0], vec![11.0]]);
        assert_eq!(t2.wait().unwrap(), vec![vec![12.0]]);
        assert_eq!(t3.wait().unwrap(), vec![vec![13.0]]);
        // the whole group ran as ONE fused exec
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        b.shutdown();
        dispatcher.join().unwrap();
    }

    #[test]
    fn deadline_flushes_undersized_groups_and_errors_propagate() {
        let b = Arc::new(MicroBatcher::new(1024, 1)); // size trigger unreachable
        let dispatcher = {
            let b = Arc::clone(&b);
            thread::spawn(move || b.run(echo))
        };
        let t = b.submit("a", vec![vec![7]]);
        assert_eq!(t.wait().unwrap(), vec![vec![7.0]]);
        let e = b.submit("boom", vec![vec![1]]);
        assert!(e.wait().unwrap_err().to_string().contains("no such tenant"));
        b.shutdown();
        dispatcher.join().unwrap();
        // post-shutdown submits fail fast instead of hanging
        let late = b.submit("a", vec![vec![1]]);
        assert!(late.wait().is_err());
    }

    #[test]
    fn panicking_exec_fails_the_group_but_not_the_dispatcher() {
        let b = Arc::new(MicroBatcher::new(1, 60_000));
        let dispatcher = {
            let b = Arc::clone(&b);
            thread::spawn(move || {
                b.run(|a, rows| {
                    if a == "kaboom" {
                        panic!("backend exploded");
                    }
                    echo(a, rows)
                })
            })
        };
        let boom = b.submit("kaboom", vec![vec![1]]);
        let err = boom.wait().unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err:#}");
        // the dispatcher survived and still serves other tenants
        let ok = b.submit("a", vec![vec![9]]);
        assert_eq!(ok.wait().unwrap(), vec![vec![9.0]]);
        b.shutdown();
        dispatcher.join().unwrap();
    }

    #[test]
    fn different_adapters_never_share_a_fused_batch() {
        let b = Arc::new(MicroBatcher::new(2, 60_000));
        let seen: Arc<Mutex<Vec<(String, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let dispatcher = {
            let b = Arc::clone(&b);
            let seen = Arc::clone(&seen);
            thread::spawn(move || {
                b.run(|a, rows| {
                    seen.lock().unwrap().push((a.to_string(), rows.len()));
                    echo(a, rows)
                })
            })
        };
        let ta = b.submit("a", vec![vec![1], vec![2]]);
        let tb = b.submit("b", vec![vec![3], vec![4]]);
        ta.wait().unwrap();
        tb.wait().unwrap();
        b.shutdown();
        dispatcher.join().unwrap();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 2, "{seen:?}");
        assert!(seen.iter().all(|(_, n)| *n == 2), "{seen:?}");
    }
}
