//! The serving layer: sparse adapters as first-class artifacts.
//!
//! A Sparse-MeZO fine-tune only ever moves masked coordinates, so a
//! finished run is exactly `base + sparse_delta` — a compact,
//! task-specific adapter in the spirit of the paper's §3.3 mask-as-bits
//! memory argument, orders of magnitude smaller than the per-task full
//! parameter copies dense MeZO would hand you. This subsystem turns
//! that property into a **batched multi-tenant inference server**:
//!
//! * [`delta`] — extract / certify / save / load / swap sparse adapter
//!   deltas. Export checks the exact-sparsity invariant (delta support
//!   ⊆ the run's mask union, certified by the PR-2 journal replay), and
//!   `swap` installs or reverts an adapter in place, bit-for-bit, with
//!   zero parameter copies.
//! * [`registry`] — one base (resident vector *or* a paged
//!   [`ParamStore`](crate::runtime::store::ParamStore) bounded by
//!   `--page-cache-bytes`) + N named adapters with checkout/release
//!   guards, LRU eviction under a count cap and a byte budget accounted
//!   via
//!   [`memory::sparse_adapter_bytes`](crate::coordinator::memory::sparse_adapter_bytes).
//!   Paged checkouts are overlay views: the base is never mutated and
//!   serving's resident footprint stays at the page-cache budget.
//! * [`batching`] — the dynamic micro-batching queue (size- and
//!   deadline-triggered flush, same-adapter grouping) and the
//!   [`ServeEngine`](batching::ServeEngine) that shards fused forward
//!   passes across the crate's one scheduler, the
//!   [`WorkerPool`](crate::parallel::WorkerPool), folding per-row
//!   logits back in request order — bit-identical to a serial pass.
//! * [`http`] — a std-only HTTP/1.1 loopback server (`POST
//!   /v1/classify`, `GET|POST /v1/adapters`, the `/v1/jobs` lifecycle,
//!   `GET /healthz`) with keep-alive connections under a bounded
//!   connection pool, plus the curl-free clients (persistent
//!   [`LoopbackClient`](http::LoopbackClient) and one-shot
//!   [`loopback_request`](http::loopback_request)), driven by the
//!   `serve` CLI subcommand. With `--jobs-dir` it also hosts the
//!   [`jobs`](crate::jobs) scheduler, so submitted fine-tuning jobs
//!   train in the background and publish straight into the registry.
//!
//! End-to-end contract (locked by `tests/serve.rs`): train → journal →
//! materialize adapter by replay → register → classify over HTTP, and
//! the served logits equal offline evaluation of the tuned parameters
//! **bit-for-bit**, under concurrent requests to different adapters.

pub mod batching;
pub mod delta;
pub mod http;
pub mod registry;

pub use batching::{JobsHandle, MicroBatcher, ServeEngine};
pub use delta::SparseDelta;
pub use http::LoopbackClient;
pub use registry::{AdapterRegistry, TenantParams};
