//! The shared 512-token synthetic vocabulary.
//!
//! Layout (must stay in sync with `python/compile/configs.py::VOCAB` only
//! in total size; the *structure* below is purely a data-layer concern):
//!
//! ```text
//!   0          PAD (also the attention-mask sentinel in the model)
//!   1          SEP        segment separator
//!   2          QRY        question marker (boolq/multirc)
//!   3..=5      YES / NO / MAYBE answer tokens
//!   6..=15     DIGIT(0..9) answer tokens (aqua)
//!   16         PLUS   17 EQ   18 CAUSE   19 EFFECT   20..=31 reserved
//!   32..=63    polysemous "words" for WIC (each tied to 2 sense clusters)
//!   64..=127   positive-sentiment lexicon
//!   128..=191  negative-sentiment lexicon
//!   192..=447  8 topic clusters x 32 tokens (copa/piqa/siqa/rte content)
//!   448..=511  neutral filler
//! ```

/// total vocabulary size
pub const SIZE: usize = 512;

/// padding + attention-mask sentinel
pub const PAD: i32 = 0;
/// segment separator
pub const SEP: i32 = 1;
/// question marker (boolq/multirc)
pub const QRY: i32 = 2;
/// "yes" answer token
pub const YES: i32 = 3;
/// "no" answer token
pub const NO: i32 = 4;
/// "maybe" answer token (reserved)
pub const MAYBE: i32 = 5;
/// "+" token (aqua)
pub const PLUS: i32 = 16;
/// "=" token (aqua)
pub const EQ: i32 = 17;
/// "because" marker (copa)
pub const CAUSE: i32 = 18;
/// "so" marker (copa)
pub const EFFECT: i32 = 19;

/// first digit token; `DIGIT(d) = DIGIT_BASE + d`
pub const DIGIT_BASE: i32 = 6; // DIGIT(d) = 6 + d, d in 0..10

/// polysemous WIC words
pub const WIC_WORDS: std::ops::Range<i32> = 32..64;
/// positive-sentiment lexicon
pub const POS_LEX: std::ops::Range<i32> = 64..128;
/// negative-sentiment lexicon
pub const NEG_LEX: std::ops::Range<i32> = 128..192;
/// first topic-cluster token
pub const CLUSTER_BASE: i32 = 192;
/// tokens per topic cluster
pub const CLUSTER_SIZE: i32 = 32;
/// topic cluster count
pub const N_CLUSTERS: i32 = 8;
/// neutral filler tokens
pub const FILLER: std::ops::Range<i32> = 448..512;

/// The answer token for digit `d`.
pub fn digit(d: u32) -> i32 {
    debug_assert!(d < 10);
    DIGIT_BASE + d as i32
}

/// Tokens of topic cluster `c` (0..8).
pub fn cluster(c: i32) -> std::ops::Range<i32> {
    debug_assert!((0..N_CLUSTERS).contains(&c));
    let lo = CLUSTER_BASE + c * CLUSTER_SIZE;
    lo..lo + CLUSTER_SIZE
}

/// The two sense clusters of a WIC word: deterministic, distinct.
pub fn wic_senses(word: i32) -> (i32, i32) {
    debug_assert!(WIC_WORDS.contains(&word));
    let a = (word - WIC_WORDS.start) % N_CLUSTERS;
    let b = (a + 1 + (word - WIC_WORDS.start) / N_CLUSTERS % (N_CLUSTERS - 1)) % N_CLUSTERS;
    (a, b)
}

/// Human-readable token names for report/debug output.
pub fn name(tok: i32) -> String {
    match tok {
        PAD => "<pad>".into(),
        SEP => "<sep>".into(),
        QRY => "<qry>".into(),
        YES => "yes".into(),
        NO => "no".into(),
        MAYBE => "maybe".into(),
        PLUS => "+".into(),
        EQ => "=".into(),
        CAUSE => "because".into(),
        EFFECT => "so".into(),
        d if (DIGIT_BASE..DIGIT_BASE + 10).contains(&d) => format!("d{}", d - DIGIT_BASE),
        w if WIC_WORDS.contains(&w) => format!("w{}", w - WIC_WORDS.start),
        p if POS_LEX.contains(&p) => format!("pos{}", p - POS_LEX.start),
        n if NEG_LEX.contains(&n) => format!("neg{}", n - NEG_LEX.start),
        c if (CLUSTER_BASE..CLUSTER_BASE + N_CLUSTERS * CLUSTER_SIZE).contains(&c) => {
            let rel = c - CLUSTER_BASE;
            format!("c{}t{}", rel / CLUSTER_SIZE, rel % CLUSTER_SIZE)
        }
        f if FILLER.contains(&f) => format!("fill{}", f - FILLER.start),
        other => format!("tok{other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_are_disjoint_and_cover() {
        // end of clusters == start of filler; all regions in-vocab
        assert_eq!(CLUSTER_BASE + N_CLUSTERS * CLUSTER_SIZE, FILLER.start);
        assert_eq!(FILLER.end as usize, SIZE);
        assert!(POS_LEX.end <= NEG_LEX.start);
    }

    #[test]
    fn wic_senses_distinct() {
        for w in WIC_WORDS {
            let (a, b) = wic_senses(w);
            assert_ne!(a, b, "word {w}");
            assert!((0..N_CLUSTERS).contains(&a));
            assert!((0..N_CLUSTERS).contains(&b));
        }
    }

    #[test]
    fn names_unique_over_vocab() {
        let mut seen = std::collections::HashSet::new();
        for t in 0..SIZE as i32 {
            assert!(seen.insert(name(t)), "dup name for {t}");
        }
    }

    #[test]
    fn cluster_ranges() {
        assert_eq!(cluster(0).start, 192);
        assert_eq!(cluster(7).end, 448);
    }
}
