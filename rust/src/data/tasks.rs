//! Planted-rule synthetic task generators (SuperGLUE analogs).
//!
//! Each generator produces i.i.d. examples from a fixed rule with
//! controlled difficulty (distractors, lengths) and balanced labels, then
//! splits into train/dev/test with fingerprint-based leakage removal.
//! Prompts are capped at [`MAX_PROMPT`] tokens so they fit every exported
//! sequence length.
//!
//! | analog  | planted rule |
//! |---------|--------------|
//! | sst2    | majority sentiment polarity of the lexicon tokens present |
//! | rte     | hypothesis tokens are a subset of premise tokens |
//! | boolq   | queried token occurs in the passage |
//! | wic     | the two contexts draw from the same sense cluster of the word |
//! | multirc | candidate answer occurs within distance 2 of the question token |
//! | copa    | pick the candidate sharing the premise's topic cluster |
//! | piqa    | pick the "action" from the object's cluster (more distractors) |
//! | siqa    | pick the in-cluster candidate under cross-cluster noise |
//! | aqua    | answer (a + b) mod 10 as a digit token (10-way) |

use std::collections::HashSet;

use anyhow::{bail, Result};

use super::vocab as V;
use super::{Dataset, Example};
use crate::util::prng::Pcg32;

/// Longest prompt any generator may emit (min exported seq_len is 32).
pub const MAX_PROMPT: usize = 30;

/// Every task name [`generate`] understands.
pub const ALL_TASKS: [&str; 9] =
    ["sst2", "rte", "boolq", "wic", "multirc", "copa", "piqa", "siqa", "aqua"];

/// Paper-matching split sizes (1,000 training examples; §4.1).
pub const N_TRAIN: usize = 1000;
/// dev split size (model selection)
pub const N_DEV: usize = 500;
/// test split size (reported accuracy)
pub const N_TEST: usize = 1000;

/// Generate a dataset for `task` with canonical split sizes.
pub fn generate(task: &str, seed: u64) -> Result<Dataset> {
    generate_sized(task, seed, N_TRAIN, N_DEV, N_TEST)
}

/// Generate a dataset with explicit split sizes.
pub fn generate_sized(
    task: &str,
    seed: u64,
    n_train: usize,
    n_dev: usize,
    n_test: usize,
) -> Result<Dataset> {
    let gen: fn(&mut Pcg32) -> Example = match task {
        "sst2" => gen_sst2,
        "rte" => gen_rte,
        "boolq" => gen_boolq,
        "wic" => gen_wic,
        "multirc" => gen_multirc,
        "copa" => gen_copa,
        "piqa" => gen_piqa,
        "siqa" => gen_siqa,
        "aqua" => gen_aqua,
        other => bail!("unknown task '{other}' (known: {})", ALL_TASKS.join(", ")),
    };
    let mut rng = Pcg32::from_name(seed, task);
    let total = n_train + n_dev + n_test;
    let mut seen = HashSet::new();
    let mut examples = Vec::with_capacity(total);
    let mut attempts = 0usize;
    while examples.len() < total {
        attempts += 1;
        if attempts > total * 200 {
            bail!("task '{task}': cannot generate {total} distinct examples");
        }
        let e = gen(&mut rng);
        debug_assert!(e.prompt.len() <= MAX_PROMPT, "{task} prompt too long: {}", e.prompt.len());
        debug_assert!(e.candidates.contains(&e.label));
        if seen.insert(e.fingerprint()) {
            examples.push(e);
        }
    }
    let test = examples.split_off(n_train + n_dev);
    let dev = examples.split_off(n_train);
    Ok(Dataset { task: task.to_string(), train: examples, dev, test })
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn pick_range(rng: &mut Pcg32, r: std::ops::Range<i32>) -> i32 {
    r.start + rng.below((r.end - r.start) as u32) as i32
}

fn pick_n_distinct(rng: &mut Pcg32, r: std::ops::Range<i32>, n: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(n);
    let mut guard = 0;
    while out.len() < n {
        let t = pick_range(rng, r.clone());
        if !out.contains(&t) {
            out.push(t);
        }
        guard += 1;
        assert!(guard < 10_000, "range too small for {n} distinct tokens");
    }
    out
}

fn yesno(label: bool) -> (i32, Vec<i32>) {
    (if label { V::YES } else { V::NO }, vec![V::YES, V::NO])
}

// ---------------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------------

/// SST-2 analog: 8–14 tokens; k_pos from the positive lexicon, k_neg from
/// the negative, rest neutral filler, shuffled. Label = majority polarity
/// (counts never tie).
fn gen_sst2(rng: &mut Pcg32) -> Example {
    let len = 8 + rng.below(7) as usize;
    let label = rng.chance(0.5);
    // majority margin of at least 1, both polarities may appear (realistic
    // mixed reviews)
    let minor = rng.below(3) as usize;
    let major = minor + 1 + rng.below(2) as usize;
    let (n_pos, n_neg) = if label { (major, minor) } else { (minor, major) };
    let mut toks = Vec::with_capacity(len);
    for _ in 0..n_pos {
        toks.push(pick_range(rng, V::POS_LEX));
    }
    for _ in 0..n_neg {
        toks.push(pick_range(rng, V::NEG_LEX));
    }
    while toks.len() < len {
        toks.push(pick_range(rng, V::FILLER));
    }
    rng.shuffle(&mut toks);
    let (lab, candidates) = yesno(label);
    Example { prompt: toks, label: lab, candidates }
}

/// RTE analog: premise (8–12 distinct content tokens) SEP hypothesis
/// (3–4 tokens). Entailed: hypothesis sampled from the premise. Not
/// entailed: at least one hypothesis token swapped for an out-of-premise
/// token from the same cluster (so surface statistics stay close).
fn gen_rte(rng: &mut Pcg32) -> Example {
    let c = rng.below(V::N_CLUSTERS as u32) as i32;
    let c2 = (c + 1 + rng.below(V::N_CLUSTERS as u32 - 1) as i32) % V::N_CLUSTERS;
    let np = 8 + rng.below(5) as usize;
    let mut premise = pick_n_distinct(rng, V::cluster(c), np.min(20));
    // sprinkle 2 tokens from a second cluster for diversity
    premise.extend(pick_n_distinct(rng, V::cluster(c2), 2));
    rng.shuffle(&mut premise);

    let nh = 3 + rng.below(2) as usize;
    let mut hyp: Vec<i32> = Vec::new();
    let mut idxs: Vec<usize> = (0..premise.len()).collect();
    rng.shuffle(&mut idxs);
    for i in idxs.into_iter().take(nh) {
        hyp.push(premise[i]);
    }
    let label = rng.chance(0.5);
    if !label {
        // corrupt 1-2 hypothesis slots with tokens absent from the premise.
        // Mostly (80%) the corruption comes from a FOREIGN cluster — a
        // topical-consistency cue a small model can learn — and sometimes
        // (20%) from the premise's own clusters, the hard exact-membership
        // case that keeps ceiling below 100%.
        let c3 = (c + 2 + rng.below(V::N_CLUSTERS as u32 - 3) as i32) % V::N_CLUSTERS;
        let n_corrupt = 1 + rng.below(2) as usize;
        for _ in 0..n_corrupt {
            let slot = rng.below(hyp.len() as u32) as usize;
            let mut guard = 0;
            loop {
                let pick_c = if rng.chance(0.8) {
                    c3
                } else if rng.chance(0.5) {
                    c
                } else {
                    c2
                };
                let t = pick_range(rng, V::cluster(pick_c));
                if !premise.contains(&t) {
                    hyp[slot] = t;
                    break;
                }
                guard += 1;
                if guard > 1000 {
                    break;
                }
            }
        }
    }
    let mut prompt = premise;
    prompt.push(V::SEP);
    prompt.extend(hyp);
    let (lab, candidates) = yesno(label);
    Example { prompt, label: lab, candidates }
}

/// BoolQ analog: passage (12–18 tokens) SEP QRY w. Yes iff w occurs in the
/// passage. Negatives query a token from the same cluster that is absent.
fn gen_boolq(rng: &mut Pcg32) -> Example {
    let c = rng.below(V::N_CLUSTERS as u32) as i32;
    let np = 12 + rng.below(7) as usize;
    let mut passage = Vec::with_capacity(np);
    for _ in 0..np {
        let r = if rng.chance(0.7) { V::cluster(c) } else { V::FILLER };
        passage.push(pick_range(rng, r));
    }
    let label = rng.chance(0.5);
    let w = if label {
        *rng.choose(&passage)
    } else if rng.chance(0.6) {
        // easy negative: query from a foreign cluster (topical mismatch)
        let c_far = (c + 1 + rng.below(V::N_CLUSTERS as u32 - 1) as i32) % V::N_CLUSTERS;
        let mut guard = 0;
        loop {
            let t = pick_range(rng, V::cluster(c_far));
            if !passage.contains(&t) {
                break t;
            }
            guard += 1;
            if guard > 1000 {
                break V::FILLER.start;
            }
        }
    } else {
        // hard negative: same cluster, absent from the passage
        let mut guard = 0;
        loop {
            let t = pick_range(rng, V::cluster(c));
            if !passage.contains(&t) {
                break t;
            }
            guard += 1;
            if guard > 1000 {
                break V::FILLER.start; // filler token surely absent enough
            }
        }
    };
    let mut prompt = passage;
    prompt.push(V::SEP);
    prompt.push(V::QRY);
    prompt.push(w);
    let (lab, candidates) = yesno(label);
    Example { prompt, label: lab, candidates }
}

/// WIC analog: w SEP ctx1 SEP ctx2 where each context draws 4–5 tokens
/// from one of w's two sense clusters (plus filler noise). Yes iff both
/// contexts use the same sense.
fn gen_wic(rng: &mut Pcg32) -> Example {
    let w = pick_range(rng, V::WIC_WORDS);
    let (sa, sb) = V::wic_senses(w);
    let label = rng.chance(0.5);
    let (c1, c2) = if label {
        let s = if rng.chance(0.5) { sa } else { sb };
        (s, s)
    } else if rng.chance(0.5) {
        (sa, sb)
    } else {
        (sb, sa)
    };
    let ctx = |c: i32, rng: &mut Pcg32| -> Vec<i32> {
        let n = 4 + rng.below(2) as usize;
        let mut out = pick_n_distinct(rng, V::cluster(c), n);
        if rng.chance(0.5) {
            out.push(pick_range(rng, V::FILLER));
        }
        rng.shuffle(&mut out);
        out
    };
    let mut prompt = vec![w, V::SEP];
    prompt.extend(ctx(c1, rng));
    prompt.push(V::SEP);
    prompt.extend(ctx(c2, rng));
    let (lab, candidates) = yesno(label);
    Example { prompt, label: lab, candidates }
}

/// MultiRC analog: paragraph containing the question token q somewhere;
/// candidate answer a. Yes iff a occurs within distance 2 of q.
fn gen_multirc(rng: &mut Pcg32) -> Example {
    let c = rng.below(V::N_CLUSTERS as u32) as i32;
    let np = 12 + rng.below(5) as usize;
    let mut para: Vec<i32> = (0..np)
        .map(|_| {
            if rng.chance(0.75) {
                pick_range(rng, V::cluster(c))
            } else {
                pick_range(rng, V::FILLER)
            }
        })
        .collect();
    let q = pick_range(rng, V::cluster(c));
    let qpos = 1 + rng.below(np as u32 - 2) as usize;
    para[qpos] = q;
    let label = rng.chance(0.5);
    let a = if label {
        // answer adjacent to q (distance 1 or 2)
        let d = 1 + rng.below(2) as i64;
        let side = if rng.chance(0.5) { 1i64 } else { -1 };
        let pos = (qpos as i64 + side * d).clamp(0, np as i64 - 1) as usize;
        if pos == qpos {
            para[(qpos + 1).min(np - 1)]
        } else {
            para[pos]
        }
    } else if rng.chance(0.6) {
        // easy negative: answer from a foreign cluster
        let c_far = (c + 1 + rng.below(V::N_CLUSTERS as u32 - 1) as i32) % V::N_CLUSTERS;
        pick_range(rng, V::cluster(c_far))
    } else {
        // hard negative: in-cluster token far from q
        let near: Vec<i32> = para
            [qpos.saturating_sub(2)..(qpos + 3).min(np)]
            .to_vec();
        let mut guard = 0;
        loop {
            let t = pick_range(rng, V::cluster(c));
            if !near.contains(&t) {
                break t;
            }
            guard += 1;
            if guard > 1000 {
                break pick_range(rng, V::FILLER);
            }
        }
    };
    let mut prompt = para;
    prompt.push(V::SEP);
    prompt.push(V::QRY);
    prompt.push(q);
    prompt.push(V::SEP);
    prompt.push(a);
    let (lab, candidates) = yesno(label);
    Example { prompt, label: lab, candidates }
}

/// Two-candidate topic-match scoring shared by copa/piqa/siqa.
fn two_candidate(rng: &mut Pcg32, marker: i32, n_premise: usize, noise: f64) -> Example {
    let c = rng.below(V::N_CLUSTERS as u32) as i32;
    let c_wrong = (c + 1 + rng.below(V::N_CLUSTERS as u32 - 1) as i32) % V::N_CLUSTERS;
    let mut premise: Vec<i32> = (0..n_premise)
        .map(|_| {
            if rng.chance(1.0 - noise) {
                pick_range(rng, V::cluster(c))
            } else {
                pick_range(rng, V::FILLER)
            }
        })
        .collect();
    rng.shuffle(&mut premise);
    let right = pick_range(rng, V::cluster(c));
    let wrong = pick_range(rng, V::cluster(c_wrong));
    // candidate order randomized; answer = the correct token itself
    let (c1, c2) = if rng.chance(0.5) { (right, wrong) } else { (wrong, right) };
    let mut prompt = premise;
    prompt.push(marker);
    prompt.push(c1);
    prompt.push(V::SEP);
    prompt.push(c2);
    prompt.push(V::SEP);
    Example { prompt, label: right, candidates: vec![c1, c2] }
}

/// COPA analog: premise + CAUSE/EFFECT marker, choose the continuation
/// from the premise's topic cluster.
fn gen_copa(rng: &mut Pcg32) -> Example {
    let marker = if rng.chance(0.5) { V::CAUSE } else { V::EFFECT };
    let n = 6 + rng.below(4) as usize;
    two_candidate(rng, marker, n, 0.2)
}

/// PIQA analog: like copa, longer "physical context", more filler noise.
fn gen_piqa(rng: &mut Pcg32) -> Example {
    let n = 9 + rng.below(5) as usize;
    two_candidate(rng, V::EFFECT, n, 0.35)
}

/// SIQA analog: shorter context, highest noise — the hardest 2-way task.
fn gen_siqa(rng: &mut Pcg32) -> Example {
    let n = 5 + rng.below(3) as usize;
    two_candidate(rng, V::CAUSE, n, 0.45)
}

/// AQuA analog: small-operand addition, 10-way classification.
/// prompt = d(a) PLUS d(b) EQ, answer = digit((a+b) mod 10). Operands are
/// restricted to 0..=4 (25 patterns, carry-free) — full mod-10 arithmetic
/// shows grokking-style delayed generalization that tiny models don't
/// reach in a CPU-budget run; this keeps the task 10-way but learnable.
fn gen_aqua(rng: &mut Pcg32) -> Example {
    let a = rng.below(5);
    let b = rng.below(5);
    // pad with filler context so sequences aren't degenerate 4-token runs
    let mut prompt = Vec::new();
    let n_ctx = rng.below(4) as usize;
    for _ in 0..n_ctx {
        prompt.push(pick_range(rng, V::FILLER));
    }
    prompt.extend([V::digit(a), V::PLUS, V::digit(b), V::EQ]);
    Example {
        prompt,
        label: V::digit((a + b) % 10),
        candidates: (0..10).map(V::digit).collect(),
    }
}

// ---------------------------------------------------------------------------
// in-context prompt construction (ICL baseline, paper Tables 1/11/13)
// ---------------------------------------------------------------------------

/// Build a k-shot prompt: `demo1 answer1 SEP ... query`. Truncates shots
/// (keeping the query intact) to fit `max_len` — with seq_len 32 this is
/// effectively one-shot, which EXPERIMENTS.md notes.
pub fn icl_prompt(shots: &[Example], query: &Example, max_len: usize) -> Vec<i32> {
    let mut out = Vec::new();
    for s in shots {
        let mut segment = s.prompt.clone();
        segment.push(s.label);
        segment.push(V::SEP);
        if out.len() + segment.len() + query.prompt.len() > max_len {
            break;
        }
        out.extend(segment);
    }
    out.extend(&query.prompt);
    // if even the bare query overflows, keep its tail (answer cues are
    // rightmost in every task format)
    if out.len() > max_len {
        out.drain(..out.len() - max_len);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for t in ALL_TASKS {
            let ds = generate_sized(t, 7, 50, 20, 50).unwrap();
            assert_eq!(ds.train.len(), 50, "{t}");
            assert_eq!(ds.dev.len(), 20, "{t}");
            assert_eq!(ds.test.len(), 50, "{t}");
        }
    }

    #[test]
    fn prompts_fit_and_labels_valid() {
        for t in ALL_TASKS {
            let ds = generate_sized(t, 3, 200, 0, 0).unwrap();
            for e in &ds.train {
                assert!(e.prompt.len() <= MAX_PROMPT, "{t}: {}", e.prompt.len());
                assert!(!e.prompt.is_empty());
                assert!(e.candidates.contains(&e.label), "{t}");
                assert!(e.prompt.iter().all(|&tok| tok > 0 && (tok as usize) < V::SIZE), "{t}");
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        for t in ["sst2", "rte", "boolq", "wic", "multirc"] {
            let ds = generate_sized(t, 11, 600, 0, 0).unwrap();
            let yes = ds.train.iter().filter(|e| e.label == V::YES).count();
            assert!(
                (yes as f64 / 600.0 - 0.5).abs() < 0.08,
                "{t}: yes fraction {}",
                yes as f64 / 600.0
            );
        }
    }

    #[test]
    fn no_split_leakage() {
        for t in ALL_TASKS {
            let ds = generate_sized(t, 5, 150, 50, 150).unwrap();
            let train: std::collections::HashSet<u64> =
                ds.train.iter().map(|e| e.fingerprint()).collect();
            for e in ds.test.iter().chain(ds.dev.iter()) {
                assert!(!train.contains(&e.fingerprint()), "{t}: leak");
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_sized("rte", 42, 20, 5, 20).unwrap();
        let b = generate_sized("rte", 42, 20, 5, 20).unwrap();
        assert_eq!(a.train, b.train);
        let c = generate_sized("rte", 43, 20, 5, 20).unwrap();
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn rules_are_consistent() {
        // verify the planted rule by re-deriving labels
        let ds = generate_sized("boolq", 9, 300, 0, 0).unwrap();
        for e in &ds.train {
            let sep = e.prompt.iter().rposition(|&t| t == V::QRY).unwrap();
            let w = e.prompt[sep + 1];
            let passage = &e.prompt[..sep - 1];
            let present = passage.contains(&w);
            assert_eq!(e.label == V::YES, present);
        }
        let ds = generate_sized("aqua", 9, 200, 0, 0).unwrap();
        for e in &ds.train {
            let eq = e.prompt.iter().rposition(|&t| t == V::EQ).unwrap();
            let a = e.prompt[eq - 3] - V::DIGIT_BASE;
            let b = e.prompt[eq - 1] - V::DIGIT_BASE;
            assert_eq!(e.label, V::digit(((a + b) % 10) as u32));
        }
    }

    #[test]
    fn copa_candidates_contain_answer_in_prompt() {
        let ds = generate_sized("copa", 2, 100, 0, 0).unwrap();
        for e in &ds.train {
            assert_eq!(e.candidates.len(), 2);
            // both candidates appear in the prompt (the scoring format)
            for c in &e.candidates {
                assert!(e.prompt.contains(c));
            }
        }
    }

    #[test]
    fn majority_baseline_near_half() {
        let ds = generate_sized("rte", 1, 100, 10, 400).unwrap();
        let mb = ds.majority_baseline();
        assert!(mb < 0.6, "degenerate labels: {mb}");
    }

    #[test]
    fn icl_prompt_respects_budget() {
        let ds = generate_sized("rte", 4, 10, 0, 10).unwrap();
        let p = icl_prompt(&ds.train[..4], &ds.test[0], 32);
        assert!(p.len() <= 32);
        // query tail is preserved
        let q = &ds.test[0].prompt;
        assert_eq!(&p[p.len() - q.len().min(p.len())..], &q[q.len() - q.len().min(p.len())..]);
    }

    #[test]
    fn unknown_task_errors() {
        assert!(generate("nope", 0).is_err());
    }
}
