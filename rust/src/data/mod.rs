//! Data layer: synthetic SuperGLUE-analog tasks, pretraining corpus,
//! batching.
//!
//! The paper fine-tunes on SuperGLUE (RTE, BoolQ, WIC, SST-2, MultiRC,
//! COPA) plus PIQA/SIQA/AQuA; those datasets are network-gated here, so
//! each is replaced by a *planted-rule* task over a shared 512-token
//! vocabulary (DESIGN.md §2). Every task keeps the paper's interface —
//! prompt tokens in, an answer token out of a per-example candidate set —
//! so the optimizer comparison exercises the same code path as the paper's
//! classification-as-LM protocol.

pub mod batcher;
pub mod corpus;
pub mod tasks;
pub mod vocab;

/// One classification example: prompt tokens (unpadded), the gold answer
/// token, and the candidate answer tokens the evaluator scores over.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    /// prompt tokens (unpadded)
    pub prompt: Vec<i32>,
    /// gold answer token
    pub label: i32,
    /// candidate answer tokens the evaluator scores over
    pub candidates: Vec<i32>,
}

impl Example {
    /// Stable content hash used for train/test leakage checks.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |v: i64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        for t in &self.prompt {
            eat(*t as i64);
        }
        eat(-1);
        eat(self.label as i64);
        h
    }
}

/// A generated dataset with canonical splits (paper setting: 1,000 train
/// examples; dev for model selection; test for reported accuracy).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// task name
    pub task: String,
    /// training split
    pub train: Vec<Example>,
    /// model-selection split
    pub dev: Vec<Example>,
    /// reported-accuracy split
    pub test: Vec<Example>,
}

impl Dataset {
    /// Majority-class accuracy — the floor every method must beat.
    pub fn majority_baseline(&self) -> f64 {
        let mut counts = std::collections::HashMap::new();
        for e in &self.test {
            *counts.entry(e.label).or_insert(0usize) += 1;
        }
        let max = counts.values().copied().max().unwrap_or(0);
        max as f64 / self.test.len().max(1) as f64
    }
}
