//! Batching: fixed-shape [B, T] token tensors for the AOT executables.
//!
//! The exported step/logits programs have *static* shapes, so the batcher's
//! contract is strict: every batch is exactly B x T i32, left-padded with
//! PAD=0 (the model is left-padding invariant — tested in
//! python/tests/test_model.py), labels are length B. Epoch order is
//! shuffled with a deterministic per-epoch seed; the final partial batch
//! wraps around (training) or is masked by `real` counts (evaluation).

use anyhow::{bail, Result};

use super::{vocab as V, Example};
use crate::util::prng::Pcg32;

/// One fixed-shape batch.
#[derive(Debug, Clone)]
pub struct Batch {
    /// row-major [B, T]
    pub tokens: Vec<i32>,
    /// [B]
    pub labels: Vec<i32>,
    /// number of non-duplicated examples (== B except the eval tail batch)
    pub real: usize,
    /// per-row candidate sets (evaluation scoring)
    pub candidates: Vec<Vec<i32>>,
}

/// Left-pad (or tail-truncate) a prompt to `t` tokens.
pub fn pad_prompt(prompt: &[i32], t: usize) -> Vec<i32> {
    let mut row = vec![V::PAD; t];
    if prompt.len() >= t {
        row.copy_from_slice(&prompt[prompt.len() - t..]);
    } else {
        row[t - prompt.len()..].copy_from_slice(prompt);
    }
    row
}

/// Assemble a batch from explicit examples (duplicating the last to fill).
pub fn make_batch(examples: &[&Example], b: usize, t: usize) -> Result<Batch> {
    if examples.is_empty() || examples.len() > b {
        bail!("make_batch: got {} examples for batch size {b}", examples.len());
    }
    let mut tokens = Vec::with_capacity(b * t);
    let mut labels = Vec::with_capacity(b);
    let mut candidates = Vec::with_capacity(b);
    for i in 0..b {
        let e = examples[i.min(examples.len() - 1)];
        tokens.extend(pad_prompt(&e.prompt, t));
        labels.push(e.label);
        candidates.push(e.candidates.clone());
    }
    Ok(Batch { tokens, labels, real: examples.len(), candidates })
}

/// Deterministic epoch-shuffled training batch stream.
pub struct TrainLoader<'a> {
    examples: &'a [Example],
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
    /// batch size `B`
    pub b: usize,
    /// sequence length `T`
    pub t: usize,
}

impl<'a> TrainLoader<'a> {
    /// A loader over `examples` with deterministic epoch shuffling.
    pub fn new(examples: &'a [Example], b: usize, t: usize, seed: u64) -> Result<TrainLoader<'a>> {
        if examples.is_empty() {
            bail!("TrainLoader: empty dataset");
        }
        let mut loader = TrainLoader {
            examples,
            order: (0..examples.len()).collect(),
            cursor: 0,
            epoch: 0,
            seed,
            b,
            t,
        };
        loader.reshuffle();
        Ok(loader)
    }

    /// Completed epoch count.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Advance past the first `batches` draws without assembling them —
    /// positions a fresh loader exactly where an uninterrupted run's
    /// loader would be after `batches` steps (the slice-resume path).
    /// Only the cursor/epoch bookkeeping runs, so skipping is O(examples
    /// per epoch), not O(tokens).
    pub fn skip(&mut self, batches: usize) {
        for _ in 0..batches * self.b {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            self.cursor += 1;
        }
    }

    fn reshuffle(&mut self) {
        let mut rng = Pcg32::new(self.seed ^ 0x5eed, self.epoch.wrapping_add(1));
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch; rolls into a fresh epoch (reshuffled) when exhausted.
    pub fn next_batch(&mut self) -> Batch {
        let mut picked = Vec::with_capacity(self.b);
        for _ in 0..self.b {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            picked.push(&self.examples[self.order[self.cursor]]);
            self.cursor += 1;
        }
        make_batch(&picked, self.b, self.t).expect("make_batch invariants")
    }

    /// Two disjoint half-batches from the same draw — the Fig-2b probe's
    /// B_t = {B_t^1, B_t^2} split (paper §3.1).
    pub fn next_half_batches(&mut self) -> (Batch, Batch) {
        let full = {
            let mut picked = Vec::with_capacity(2 * self.b);
            for _ in 0..2 * self.b {
                if self.cursor >= self.order.len() {
                    self.epoch += 1;
                    self.reshuffle();
                }
                picked.push(&self.examples[self.order[self.cursor]]);
                self.cursor += 1;
            }
            picked
        };
        let b1 = make_batch(&full[..self.b], self.b, self.t).unwrap();
        let b2 = make_batch(&full[self.b..], self.b, self.t).unwrap();
        (b1, b2)
    }
}

/// Evaluation batches in dataset order, last batch padded with `real` set.
pub fn eval_batches(examples: &[Example], b: usize, t: usize) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < examples.len() {
        let hi = (i + b).min(examples.len());
        let refs: Vec<&Example> = examples[i..hi].iter().collect();
        out.push(make_batch(&refs, b, t).expect("eval batch"));
        i = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks;

    fn ds() -> crate::data::Dataset {
        tasks::generate_sized("rte", 3, 37, 0, 11).unwrap()
    }

    #[test]
    fn pad_left_and_truncate() {
        let p = pad_prompt(&[5, 6, 7], 6);
        assert_eq!(p, vec![0, 0, 0, 5, 6, 7]);
        let q = pad_prompt(&[1, 2, 3, 4, 5], 3);
        assert_eq!(q, vec![3, 4, 5]);
    }

    #[test]
    fn batches_always_fixed_shape() {
        let d = ds();
        let mut loader = TrainLoader::new(&d.train, 8, 32, 1).unwrap();
        for _ in 0..20 {
            let b = loader.next_batch();
            assert_eq!(b.tokens.len(), 8 * 32);
            assert_eq!(b.labels.len(), 8);
            assert_eq!(b.real, 8);
        }
        // 20 batches of 8 over 37 examples => epoch advanced
        assert!(loader.epoch() >= 3);
    }

    #[test]
    fn epoch_reshuffles_deterministically() {
        let d = ds();
        let mut a = TrainLoader::new(&d.train, 4, 32, 9).unwrap();
        let mut b = TrainLoader::new(&d.train, 4, 32, 9).unwrap();
        for _ in 0..30 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
        let mut c = TrainLoader::new(&d.train, 4, 32, 10).unwrap();
        let same: bool = (0..10).all(|_| a.next_batch().tokens == c.next_batch().tokens);
        assert!(!same);
    }

    #[test]
    fn each_epoch_covers_all_examples() {
        let d = ds();
        let mut loader = TrainLoader::new(&d.train, 1, 32, 5).unwrap();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..37 {
            let b = loader.next_batch();
            seen.insert(b.tokens.clone());
        }
        assert_eq!(seen.len(), 37, "epoch must visit each example once");
    }

    #[test]
    fn eval_tail_batch_real_count() {
        let d = ds();
        let batches = eval_batches(&d.test, 4, 32);
        assert_eq!(batches.len(), 3); // 11 examples -> 4+4+3
        assert_eq!(batches[2].real, 3);
        assert_eq!(batches[2].tokens.len(), 4 * 32);
    }

    #[test]
    fn skip_matches_draining_across_epochs() {
        let d = ds();
        let mut drained = TrainLoader::new(&d.train, 4, 32, 9).unwrap();
        for _ in 0..25 {
            drained.next_batch(); // 100 draws over 37 examples: epoch rolls
        }
        let mut skipped = TrainLoader::new(&d.train, 4, 32, 9).unwrap();
        skipped.skip(25);
        assert_eq!(skipped.epoch(), drained.epoch());
        for _ in 0..10 {
            assert_eq!(skipped.next_batch().tokens, drained.next_batch().tokens);
        }
    }

    #[test]
    fn half_batches_disjoint() {
        let d = ds();
        let mut loader = TrainLoader::new(&d.train, 8, 32, 2).unwrap();
        let (a, b) = loader.next_half_batches();
        assert_ne!(a.tokens, b.tokens);
        assert_eq!(a.real, 8);
        assert_eq!(b.real, 8);
    }
}
