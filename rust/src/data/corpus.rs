//! Pretraining corpus: cluster-coherent synthetic "text".
//!
//! S-MeZO's magnitude mask presupposes a *pretrained* weight distribution,
//! and the zero-shot/ICL baselines presuppose a model that has seen the
//! task formats. The corpus therefore mixes:
//!
//!   * cluster random-walk sentences (gives embeddings/attention real
//!     co-occurrence structure to learn),
//!   * repeated-pattern segments (`a b c ... a b c`) that are the known
//!     trigger for induction heads — the mechanism behind ICL,
//!   * task-formatted snippets *with answers*, drawn from the same planted
//!     rules but fresh random instances (not the fine-tuning splits;
//!     fingerprint overlap is tested).

use super::tasks;
use super::vocab as V;
use crate::util::prng::Pcg32;

/// Streaming generator of packed LM training batches.
pub struct Corpus {
    rng: Pcg32,
    seq_len: usize,
    /// fraction of sequences that are task-formatted snippets
    task_frac: f64,
    /// fraction that are repeated-pattern (induction) sequences
    induction_frac: f64,
}

impl Corpus {
    /// A corpus stream of `seq_len`-token packed sequences.
    pub fn new(seed: u64, seq_len: usize) -> Corpus {
        Corpus { rng: Pcg32::from_name(seed, "corpus"), seq_len, task_frac: 0.25, induction_frac: 0.25 }
    }

    /// One packed sequence of exactly seq_len tokens (no padding).
    pub fn sequence(&mut self) -> Vec<i32> {
        let u = self.rng.unit_f32() as f64;
        if u < self.task_frac {
            self.task_snippets()
        } else if u < self.task_frac + self.induction_frac {
            self.induction_sequence()
        } else {
            self.cluster_walk()
        }
    }

    /// [B, T] batch, flattened row-major.
    pub fn batch(&mut self, batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            out.extend(self.sequence());
        }
        out
    }

    fn cluster_walk(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.seq_len);
        let mut c = self.rng.below(V::N_CLUSTERS as u32) as i32;
        while out.len() < self.seq_len {
            // sentence of 4-9 tokens from the current cluster + filler
            let n = 4 + self.rng.below(6) as usize;
            for _ in 0..n {
                if out.len() >= self.seq_len {
                    break;
                }
                let r = if self.rng.chance(0.8) { V::cluster(c) } else { V::FILLER };
                out.push(r.start + self.rng.below((r.end - r.start) as u32) as i32);
            }
            if out.len() < self.seq_len {
                out.push(V::SEP);
            }
            // random walk over clusters: mostly stay, sometimes hop
            if self.rng.chance(0.35) {
                c = self.rng.below(V::N_CLUSTERS as u32) as i32;
            }
        }
        out.truncate(self.seq_len);
        out
    }

    fn induction_sequence(&mut self) -> Vec<i32> {
        // pattern of length 3-6 repeated to fill: induction-head chow
        let plen = 3 + self.rng.below(4) as usize;
        let c = self.rng.below(V::N_CLUSTERS as u32) as i32;
        let pattern: Vec<i32> = (0..plen)
            .map(|_| {
                let r = V::cluster(c);
                r.start + self.rng.below((r.end - r.start) as u32) as i32
            })
            .collect();
        let mut out = Vec::with_capacity(self.seq_len);
        while out.len() < self.seq_len {
            out.extend(&pattern);
        }
        out.truncate(self.seq_len);
        out
    }

    fn task_snippets(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.seq_len);
        let mut guard = 0;
        while out.len() < self.seq_len && guard < 32 {
            guard += 1;
            let task = *self.rng.choose(&tasks::ALL_TASKS);
            // fresh instance from an rng forked off this corpus stream
            let sub_seed = self.rng.next_u32() as u64;
            if let Ok(ds) = tasks::generate_sized(task, sub_seed, 1, 0, 0) {
                let e = &ds.train[0];
                if out.len() + e.prompt.len() + 2 > self.seq_len {
                    break;
                }
                out.extend(&e.prompt);
                out.push(e.label);
                out.push(V::SEP);
            }
        }
        // fill remainder with a cluster walk tail
        if out.len() < self.seq_len {
            let tail = self.cluster_walk();
            out.extend(&tail[..self.seq_len - out.len()]);
        }
        out.truncate(self.seq_len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_exact_length_no_pad() {
        let mut c = Corpus::new(1, 32);
        for _ in 0..50 {
            let s = c.sequence();
            assert_eq!(s.len(), 32);
            assert!(s.iter().all(|&t| t != V::PAD && (t as usize) < V::SIZE));
        }
    }

    #[test]
    fn batch_shape() {
        let mut c = Corpus::new(2, 64);
        let b = c.batch(8);
        assert_eq!(b.len(), 8 * 64);
    }

    #[test]
    fn deterministic() {
        let a: Vec<i32> = Corpus::new(7, 32).batch(4);
        let b: Vec<i32> = Corpus::new(7, 32).batch(4);
        assert_eq!(a, b);
        let c: Vec<i32> = Corpus::new(8, 32).batch(4);
        assert_ne!(a, c);
    }

    #[test]
    fn mixes_sequence_kinds() {
        // over many draws we should see SEP-bearing walks, exact repeats,
        // and yes/no answer tokens from task snippets
        let mut c = Corpus::new(3, 32);
        let (mut any_sep, mut any_answer, mut any_repeat) = (false, false, false);
        for _ in 0..200 {
            let s = c.sequence();
            any_sep |= s.contains(&V::SEP);
            any_answer |= s.contains(&V::YES) || s.contains(&V::NO);
            // repeated pattern: s[i] == s[i + p] for some small p over a run
            for p in 3..7 {
                if s.len() > 2 * p && (0..p).all(|i| s[i] == s[i + p]) {
                    any_repeat = true;
                }
            }
        }
        assert!(any_sep && any_answer && any_repeat);
    }
}
