//! Job specifications: what a tenant asks the orchestrator to fine-tune.
//!
//! A [`JobSpec`] is the entire user-facing surface of a fine-tuning job:
//! the task/optimizer cell to train (the Zhang-et-al. benchmark matrix a
//! queue is expected to multiplex), the sparsity/mask knobs, the step
//! budget, the data-parallel width, and the scheduling knobs (priority,
//! slice size). Specs cross the wire as JSON (`POST /v1/jobs`) and rest
//! on disk inside the queue's per-job state files, so they round-trip
//! exactly through [`to_json`](JobSpec::to_json) /
//! [`from_json`](JobSpec::from_json).

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::parallel::dp::dp_supported;
use crate::util::json::Json;

/// Everything a fine-tuning job needs, as submitted by a tenant.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// adapter name published into the serve registry on completion
    /// (also the `.adapter` artifact filename — restricted charset)
    pub name: String,
    /// task to fine-tune on (see `data::tasks`)
    pub task: String,
    /// optimizer (must be DP-supported: the mezo/smezo/rmezo/zo_* family)
    pub optimizer: String,
    /// total optimizer steps
    pub steps: usize,
    /// data-parallel worker count (must divide the model batch)
    pub workers: usize,
    /// scheduling priority — higher runs first; ties round-robin
    pub priority: i64,
    /// steps per cooperative scheduler slice (0 = scheduler default)
    pub slice_steps: usize,
    /// recompute §8.2 thresholds every N steps (0 = fixed at init)
    pub mask_refresh: usize,
    /// data + noise seed
    pub seed: u64,
    /// dataset seed override (None = `seed`). The repro harness pins
    /// its tables' dataset seed independently of the run seed, and a
    /// grid cell must train on the exact batches the serial sweep saw
    /// for its results to be bit-comparable.
    pub data_seed: Option<u64>,
    /// learning-rate override (None = task/optimizer preset)
    pub lr: Option<f32>,
    /// perturbation-scale override
    pub eps: Option<f32>,
    /// sparsity override
    pub sparsity: Option<f32>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            name: String::new(),
            task: "rte".into(),
            optimizer: "smezo".into(),
            steps: 100,
            workers: 1,
            priority: 0,
            slice_steps: 0,
            mask_refresh: 0,
            seed: 42,
            data_seed: None,
            lr: None,
            eps: None,
            sparsity: None,
        }
    }
}

impl JobSpec {
    /// Reject specs the scheduler could never run — bad names (the name
    /// becomes a filename and a registry key), zero steps, optimizers
    /// outside the DP family.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() || self.name.len() > 64 {
            bail!("job name must be 1..=64 characters");
        }
        if !self
            .name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.'))
        {
            bail!("job name '{}' may only contain [A-Za-z0-9_.-]", self.name);
        }
        if self.steps == 0 {
            bail!("job steps must be > 0");
        }
        if self.workers == 0 {
            bail!("job workers must be >= 1");
        }
        if !dp_supported(&self.optimizer) {
            bail!(
                "optimizer '{}' is not slice-runnable (jobs support the \
                 mezo/smezo/smezo_large/rmezo/zo_mom/zo_adam/zo_adamu family)",
                self.optimizer
            );
        }
        Ok(())
    }

    /// The seed the job's dataset is generated from (`data_seed`
    /// override, else the run seed).
    pub fn dataset_seed(&self) -> u64 {
        self.data_seed.unwrap_or(self.seed)
    }

    /// Resolve the fully-validated [`TrainConfig`] this job trains under:
    /// task/optimizer presets for `model`, then the spec's overrides.
    /// Deterministic — every slice of a job resolves the identical
    /// config, which is what keeps resume bit-exact.
    pub fn train_config(&self, model: &str) -> Result<TrainConfig> {
        self.validate()?;
        let mut cfg = TrainConfig::resolve(model, &self.task, &self.optimizer, None)?;
        cfg.steps = self.steps;
        cfg.workers = self.workers;
        cfg.seed = self.seed;
        cfg.eval_every = 0;
        cfg.eval_cap = 0;
        if let Some(lr) = self.lr {
            cfg.hypers.lr = lr;
        }
        if let Some(eps) = self.eps {
            cfg.hypers.eps = eps;
        }
        if let Some(sp) = self.sparsity {
            cfg.hypers.sparsity = sp;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize for the wire and the queue's state files.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("task", Json::Str(self.task.clone())),
            ("optimizer", Json::Str(self.optimizer.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("priority", Json::Num(self.priority as f64)),
            ("slice_steps", Json::Num(self.slice_steps as f64)),
            ("mask_refresh", Json::Num(self.mask_refresh as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if let Some(ds) = self.data_seed {
            fields.push(("data_seed", Json::Num(ds as f64)));
        }
        if let Some(lr) = self.lr {
            fields.push(("lr", Json::Num(lr as f64)));
        }
        if let Some(eps) = self.eps {
            fields.push(("eps", Json::Num(eps as f64)));
        }
        if let Some(sp) = self.sparsity {
            fields.push(("sparsity", Json::Num(sp as f64)));
        }
        Json::obj(fields)
    }

    /// Parse a spec from a submit body / state file. Only `name` is
    /// required; everything else has the [`Default`] values. Unknown
    /// keys are ignored (forward compatibility for state files).
    pub fn from_json(doc: &Json) -> Result<JobSpec> {
        let mut spec = JobSpec {
            name: doc.req("name")?.as_str()?.to_string(),
            ..JobSpec::default()
        };
        if let Some(v) = doc.get("task") {
            spec.task = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("optimizer") {
            spec.optimizer = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("steps") {
            spec.steps = v.as_usize()?;
        }
        if let Some(v) = doc.get("workers") {
            spec.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("priority") {
            spec.priority = v.as_f64()? as i64;
        }
        if let Some(v) = doc.get("slice_steps") {
            spec.slice_steps = v.as_usize()?;
        }
        if let Some(v) = doc.get("mask_refresh") {
            spec.mask_refresh = v.as_usize()?;
        }
        if let Some(v) = doc.get("seed") {
            spec.seed = v.as_f64()? as u64;
        }
        if let Some(v) = doc.get("data_seed") {
            spec.data_seed = Some(v.as_f64()? as u64);
        }
        if let Some(v) = doc.get("lr") {
            spec.lr = Some(v.as_f64()? as f32);
        }
        if let Some(v) = doc.get("eps") {
            spec.eps = Some(v.as_f64()? as f32);
        }
        if let Some(v) = doc.get("sparsity") {
            spec.sparsity = Some(v.as_f64()? as f32);
        }
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str) -> JobSpec {
        JobSpec { name: name.into(), steps: 8, ..JobSpec::default() }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let mut s = spec("tenant-a.v2");
        s.priority = -3;
        s.workers = 2;
        s.slice_steps = 4;
        s.mask_refresh = 3;
        s.lr = Some(2.5e-4);
        s.sparsity = Some(0.6);
        s.data_seed = Some(1234);
        let back = JobSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.priority, -3);
        assert_eq!(back.workers, 2);
        assert_eq!(back.slice_steps, 4);
        assert_eq!(back.mask_refresh, 3);
        assert_eq!(back.lr.unwrap().to_bits(), s.lr.unwrap().to_bits());
        assert_eq!(back.sparsity.unwrap().to_bits(), s.sparsity.unwrap().to_bits());
        assert!(back.eps.is_none());
        assert_eq!(back.data_seed, Some(1234));
        assert_eq!(back.dataset_seed(), 1234);
        assert_eq!(spec("d").dataset_seed(), 42, "data_seed defaults to the run seed");
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(spec("").validate().is_err());
        assert!(spec("has space").validate().is_err());
        assert!(spec("has/slash").validate().is_err());
        let mut s = spec("ok");
        s.steps = 0;
        assert!(s.validate().is_err());
        let mut s = spec("ok");
        s.workers = 0;
        assert!(s.validate().is_err());
        let mut s = spec("ok");
        s.optimizer = "smezo_const".into(); // stored-mask: serial only
        assert!(s.validate().is_err());
        assert!(spec("fine_name-1.0").validate().is_ok());
    }

    #[test]
    fn train_config_applies_overrides() {
        let mut s = spec("cfg");
        s.lr = Some(1e-5);
        s.mask_refresh = 2;
        s.workers = 2;
        s.seed = 7;
        let cfg = s.train_config("llama_tiny").unwrap();
        assert_eq!(cfg.steps, 8);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.hypers.lr, 1e-5);
        assert_eq!(cfg.eval_every, 0);
        // no override: the preset value survives
        assert!(cfg.hypers.sparsity > 0.0);
    }
}
