//! The persistent job queue: lifecycle state machine + priority pick.
//!
//! Every job is one state file (`job-<id>.json`: spec + lifecycle) plus
//! one step journal (`job-<id>.journal.jsonl`, the PR-2 format) and an
//! optional slice checkpoint (`job-<id>.ckpt`) under the queue
//! directory. The state file is rewritten on every transition and after
//! every slice, so a crashed or restarted orchestrator reopens the
//! directory and finds every job where it left off — `Running` jobs
//! (interrupted mid-slice) downgrade to `Queued` and resume from their
//! journal, which is the whole point of the seed-replay property: a
//! job's entire training state is a few bytes per step.
//!
//! Scheduling policy (see [`JobQueue::next_runnable`]): highest
//! `priority` first; within a priority level, least-recently-scheduled
//! first — so equal-priority jobs interleave slice-by-slice and a long
//! job cannot starve a short one.
//!
//! Lifecycle: `Queued → Running → {Completed, Failed, Cancelled}`, with
//! `Running → Queued` at every slice boundary (cooperative
//! time-slicing) and `{Failed, Cancelled} → Queued` via
//! [`resume`](JobQueue::resume).
//!
//! Sweep grids ([`GridSpec`](super::grid::GridSpec)) fan out into
//! ordinary child jobs at submit time plus one parent record
//! (`grid-<id>.json`, same id space). Children carry `parent` and
//! interleave under the normal priority/round-robin policy;
//! [`cancel_grid`](JobQueue::cancel_grid) /
//! [`resume_grid`](JobQueue::resume_grid) fan out to every
//! non-terminal (resp. resumable) child; and the moment the last child
//! goes terminal the queue aggregates per-cell results into
//! `grid-<id>.summary.json` — the serial sweep table's rows, durable
//! across restarts.
//!
//! Locking: every entry point recovers from a poisoned state mutex
//! ([`JobQueue::lock_inner`]) — a panic inside one critical section
//! (a crashing slice thread, a bug poked over HTTP) must not wedge
//! every subsequent jobs endpoint on a live server. The per-transition
//! state files are the durable source of truth, so recovery is safe:
//! the in-memory map holds independent whole records, and anything a
//! panicking thread left stale is re-established from disk on reopen.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

use super::grid::{grid_status_json, grid_summary_json, Grid, GridSpec};
use super::spec::JobSpec;

/// Lifecycle state of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// waiting for (more) scheduler slices
    Queued,
    /// a scheduler is currently running one of its slices
    Running,
    /// all steps done, adapter published
    Completed,
    /// training errored or diverged (see `error`)
    Failed,
    /// cancelled by the tenant; journal retained, resumable
    Cancelled,
}

impl JobState {
    /// Wire/state-file name.
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Parse a state-file name.
    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "completed" => JobState::Completed,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            other => bail!("unknown job state '{other}'"),
        })
    }

    /// Whether the job can never be scheduled again without a `resume`.
    pub fn terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Failed | JobState::Cancelled)
    }
}

/// One job: spec + lifecycle bookkeeping.
#[derive(Debug, Clone)]
pub struct Job {
    /// queue-assigned id (monotonic)
    pub id: u64,
    /// the submitted spec
    pub spec: JobSpec,
    /// lifecycle state
    pub state: JobState,
    /// optimizer steps completed across all slices
    pub steps_done: usize,
    /// scheduler slices executed
    pub slices_run: usize,
    /// failure reason (Failed only)
    pub error: Option<String>,
    /// the adapter was registered in the serve registry
    pub published: bool,
    /// tenant asked for cancellation; honored at the next step boundary
    pub cancel_requested: bool,
    /// the grid this job is a cell of, if any
    pub parent: Option<u64>,
    /// training loss at the last completed step (NaN before any step;
    /// an f32 loss widened exactly, so the grid summary's
    /// `final_train_loss` is bit-comparable to the serial sweep's)
    pub last_loss: f64,
    /// divergence detection fired during a slice
    pub diverged: bool,
    /// trace context for cross-node stitching: minted deterministically
    /// at submission (FNV-1a over id/name/seed, never 0), rendered as
    /// 16 hex digits in HTTP bodies and `SMEZO_TRACE` events, and
    /// carried to remote workers in the `Welcome`/`Step` frames
    pub trace_id: u64,
    /// alert rules active after the last slice (the scheduler copies
    /// [`obs::alerts`](crate::obs::alerts) evaluation results here so
    /// `jobs show` and `GET /v1/jobs/{id}` carry them)
    pub alerts: Vec<String>,
    /// scheduler clock stamp of the last slice (round-robin fairness)
    last_scheduled: u64,
}

/// Deterministic per-job trace id: FNV-1a 64 over the job id, spec name
/// and seed. No wall clock or PRNG involved — resubmitting the same
/// queue directory reproduces the same ids, and minting consumes
/// nothing the training path could observe. Never 0 (0 = "no trace"
/// on the wire).
pub fn mint_trace_id(id: u64, spec: &JobSpec) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for chunk in [&id.to_le_bytes()[..], spec.name.as_bytes(), &spec.seed.to_le_bytes()[..]] {
        for &b in chunk {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    if h == 0 {
        1
    } else {
        h
    }
}

impl Job {
    /// Serialize the full job (state file + `GET /v1/jobs/{id}` body).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("state", Json::Str(self.state.as_str().into())),
            ("steps_done", Json::Num(self.steps_done as f64)),
            ("slices_run", Json::Num(self.slices_run as f64)),
            (
                "error",
                self.error.as_ref().map(|e| Json::Str(e.clone())).unwrap_or(Json::Null),
            ),
            ("published", Json::Bool(self.published)),
            ("cancel_requested", Json::Bool(self.cancel_requested)),
            (
                "parent",
                self.parent.map(|p| Json::Num(p as f64)).unwrap_or(Json::Null),
            ),
            // NaN serializes as null and parses back to NaN
            ("last_loss", Json::Num(self.last_loss)),
            ("diverged", Json::Bool(self.diverged)),
            // hex string: 2^53-exact f64 JSON numbers can't hold a u64
            ("trace_id", Json::Str(format!("{:016x}", self.trace_id))),
            (
                "alerts",
                Json::Arr(self.alerts.iter().map(|a| Json::Str(a.clone())).collect()),
            ),
            ("last_scheduled", Json::Num(self.last_scheduled as f64)),
            ("spec", self.spec.to_json()),
        ])
    }

    /// Parse a state file back.
    pub fn from_json(doc: &Json) -> Result<Job> {
        let error = match doc.get("error") {
            Some(Json::Str(s)) => Some(s.clone()),
            _ => None,
        };
        let id = doc.req("id")?.as_f64()? as u64;
        let spec = JobSpec::from_json(doc.req("spec")?)?;
        // pre-PR-8 state files carry no trace_id: re-mint it (the mint
        // is a pure function of id/name/seed, so it lands on the same
        // id a live submission would have gotten)
        let trace_id = match doc.get("trace_id") {
            Some(Json::Str(s)) => u64::from_str_radix(s, 16)
                .with_context(|| format!("job {id}: bad trace_id {s:?}"))?,
            _ => mint_trace_id(id, &spec),
        };
        let alerts = match doc.get("alerts") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .filter_map(|x| x.as_str().ok().map(str::to_string))
                .collect(),
            _ => Vec::new(),
        };
        Ok(Job {
            id,
            spec,
            trace_id,
            alerts,
            state: JobState::parse(doc.req("state")?.as_str()?)?,
            steps_done: doc.req("steps_done")?.as_usize()?,
            slices_run: doc.req("slices_run")?.as_usize()?,
            error,
            published: matches!(doc.get("published"), Some(Json::Bool(true))),
            cancel_requested: matches!(doc.get("cancel_requested"), Some(Json::Bool(true))),
            parent: match doc.get("parent") {
                Some(Json::Num(p)) => Some(*p as u64),
                _ => None,
            },
            last_loss: match doc.get("last_loss") {
                Some(Json::Num(x)) => *x,
                _ => f64::NAN,
            },
            diverged: matches!(doc.get("diverged"), Some(Json::Bool(true))),
            last_scheduled: doc
                .get("last_scheduled")
                .map(|v| v.as_f64().map(|x| x as u64))
                .transpose()?
                .unwrap_or(0),
        })
    }
}

/// What one scheduler slice reports back at its boundary: updated
/// progress plus the next lifecycle state (back to `Queued` mid-run,
/// or terminal). Passed whole to [`JobQueue::finish_slice`].
#[derive(Debug, Clone)]
pub struct SliceOutcome {
    /// optimizer steps completed across all slices so far
    pub steps_done: usize,
    /// next lifecycle state
    pub state: JobState,
    /// failure reason (Failed only)
    pub error: Option<String>,
    /// the adapter was published during this slice
    pub published: bool,
    /// training loss at the last completed step (NaN when no step ran
    /// this slice — the job's recorded loss is then left untouched)
    pub last_loss: f64,
    /// divergence detection fired during this slice
    pub diverged: bool,
}

impl Default for SliceOutcome {
    fn default() -> Self {
        SliceOutcome {
            steps_done: 0,
            state: JobState::Queued,
            error: None,
            published: false,
            last_loss: f64::NAN,
            diverged: false,
        }
    }
}

/// Queue state behind the lock.
struct Inner {
    jobs: BTreeMap<u64, Job>,
    grids: BTreeMap<u64, Grid>,
    next_id: u64,
    clock: u64,
}

/// The persistent job queue. See the module docs for the contract.
pub struct JobQueue {
    dir: PathBuf,
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl JobQueue {
    /// Open (or create) the queue directory and load every persisted
    /// job. Jobs found `Running` were interrupted mid-slice by a crash
    /// or shutdown; they re-enter the queue as `Queued` and resume from
    /// their journals.
    pub fn open(dir: &Path) -> Result<JobQueue> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating jobs dir {dir:?}"))?;
        let mut jobs = BTreeMap::new();
        let mut grids = BTreeMap::new();
        let mut next_id = 1u64;
        let mut clock = 0u64;
        // never recycle a quarantined record's id: its journal,
        // checkpoint and children survive, and a new job under the
        // same id would silently resume from them
        let reserve_id = |name: &str, prefix: &str, next_id: &mut u64| {
            if let Some(id) = name
                .strip_prefix(prefix)
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                *next_id = (*next_id).max(id + 1);
            }
        };
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            let is_job = name.starts_with("job-") && name.ends_with(".json");
            let is_grid = name.starts_with("grid-")
                && name.ends_with(".json")
                && !name.ends_with(".summary.json");
            if is_grid {
                let text = std::fs::read_to_string(&path)?;
                match json::parse(&text).and_then(|doc| Grid::from_json(&doc)) {
                    Ok(grid) => {
                        next_id = next_id.max(grid.id + 1);
                        grids.insert(grid.id, grid);
                    }
                    Err(e) => {
                        crate::info!("[jobs] quarantining unreadable grid {path:?}: {e:#}");
                        let _ = std::fs::rename(&path, path.with_extension("json.corrupt"));
                        reserve_id(name, "grid-", &mut next_id);
                    }
                }
                continue;
            }
            if !is_job {
                continue;
            }
            let text = std::fs::read_to_string(&path)?;
            // a corrupt state file must not brick the whole queue (the
            // subsystem's pitch is crash recovery): quarantine it and
            // keep loading the healthy jobs. Writes are atomic
            // (temp+rename), so this only catches external damage.
            let mut job = match json::parse(&text).and_then(|doc| Job::from_json(&doc)) {
                Ok(job) => job,
                Err(e) => {
                    crate::info!("[jobs] quarantining unreadable state {path:?}: {e:#}");
                    let _ = std::fs::rename(&path, path.with_extension("json.corrupt"));
                    reserve_id(name, "job-", &mut next_id);
                    continue;
                }
            };
            if job.state == JobState::Running {
                // crash recovery: an interrupted slice re-queues — unless
                // cancellation was already requested, which now completes
                job.state =
                    if job.cancel_requested { JobState::Cancelled } else { JobState::Queued };
            } else if job.state == JobState::Queued && job.cancel_requested {
                job.state = JobState::Cancelled;
            }
            next_id = next_id.max(job.id + 1);
            clock = clock.max(job.last_scheduled);
            jobs.insert(job.id, job);
        }
        let queue = JobQueue {
            dir: dir.to_path_buf(),
            inner: Mutex::new(Inner { jobs, grids, next_id, clock }),
            ready: Condvar::new(),
        };
        // persist the Running->Queued downgrade so a second crash
        // before any slice still sees consistent state, and write any
        // grid summary a crash raced past (last child terminal but the
        // aggregate not yet on disk)
        {
            let inner = queue.lock_inner();
            for job in inner.jobs.values() {
                queue.persist(job)?;
            }
            for &id in inner.grids.keys() {
                if !queue.summary_path(id).exists() {
                    queue.maybe_finish_grid(&inner, id)?;
                }
            }
        }
        Ok(queue)
    }

    /// Lock the queue state, recovering from a poisoned mutex. A panic
    /// inside one critical section must not permanently wedge every
    /// subsequent jobs endpoint on a live server: the map holds whole,
    /// independent records (no multi-step invariant a panic can tear),
    /// and the per-transition state files are the durable source of
    /// truth that reopen re-establishes — so continuing past the
    /// poison is strictly better than refusing all future service.
    fn lock_inner(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The queue directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Step-journal path for a job (the PR-2 JSONL format).
    pub fn journal_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.journal.jsonl"))
    }

    /// Slice-checkpoint path for a job (fast resume; journal replay is
    /// the fallback and audit path).
    pub fn checkpoint_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.ckpt"))
    }

    /// On-disk adapter artifact path for a published job.
    pub fn adapter_path(&self, name: &str) -> PathBuf {
        self.dir.join("adapters").join(format!("{name}.adapter"))
    }

    /// Aggregated per-cell results of a finished grid (written once
    /// every child is terminal; removed when a child is resumed).
    pub fn summary_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("grid-{id}.summary.json"))
    }

    fn state_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.json"))
    }

    fn grid_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("grid-{id}.json"))
    }

    /// Rewrite one job's state file (called on every transition).
    /// Write-to-temp + rename, so a crash mid-write can never leave a
    /// truncated state file — the queue's reopen path must not find one.
    fn persist(&self, job: &Job) -> Result<()> {
        let path = self.state_path(job.id);
        let tmp = self.dir.join(format!("job-{}.json.tmp", job.id));
        std::fs::write(&tmp, format!("{}\n", job.to_json().to_string()))
            .with_context(|| format!("persisting job state {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing job state {path:?}"))
    }

    /// Rewrite a grid's parent state file (write-to-temp + rename, like
    /// [`persist`](JobQueue::persist)).
    fn persist_grid(&self, grid: &Grid) -> Result<()> {
        let path = self.grid_path(grid.id);
        let tmp = self.dir.join(format!("grid-{}.json.tmp", grid.id));
        std::fs::write(&tmp, format!("{}\n", grid.to_json().to_string()))
            .with_context(|| format!("persisting grid state {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing grid state {path:?}"))
    }

    /// A freshly-submitted job record.
    fn fresh_job(id: u64, spec: JobSpec, parent: Option<u64>) -> Job {
        let trace_id = mint_trace_id(id, &spec);
        Job {
            id,
            spec,
            trace_id,
            alerts: Vec::new(),
            state: JobState::Queued,
            steps_done: 0,
            slices_run: 0,
            error: None,
            published: false,
            cancel_requested: false,
            parent,
            last_loss: f64::NAN,
            diverged: false,
            last_scheduled: 0,
        }
    }

    /// Submit a new job; returns its id. The spec is validated first.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        spec.validate()?;
        let mut inner = self.lock_inner();
        let id = inner.next_id;
        inner.next_id += 1;
        let job = Self::fresh_job(id, spec, None);
        self.persist(&job)?;
        inner.jobs.insert(id, job);
        drop(inner);
        self.ready.notify_all();
        Ok(id)
    }

    /// Submit a sweep grid: expand the spec into its child jobs, assign
    /// the parent id then one id per cell (expansion order), persist
    /// everything, and wake the schedulers. Children are ordinary
    /// queued jobs — they interleave with everything else under the
    /// priority/round-robin policy.
    pub fn submit_grid(&self, spec: GridSpec) -> Result<Grid> {
        let child_specs = spec.expand()?;
        let mut inner = self.lock_inner();
        if let Some(g) = inner.grids.values().find(|g| g.spec.name == spec.name) {
            bail!(
                "grid '{}' already exists (id {}); resume it or pick a new name",
                spec.name,
                g.id
            );
        }
        let parent_id = inner.next_id;
        inner.next_id += 1;
        let mut children = Vec::with_capacity(child_specs.len());
        let mut jobs = Vec::with_capacity(child_specs.len());
        for cs in child_specs {
            let id = inner.next_id;
            inner.next_id += 1;
            children.push(id);
            jobs.push(Self::fresh_job(id, cs, Some(parent_id)));
        }
        let grid = Grid { id: parent_id, spec, children };
        // parent first: a crash mid-submit leaves a grid whose missing
        // children read as terminal, never orphan children whose parent
        // id resolves to nothing
        self.persist_grid(&grid)?;
        for job in &jobs {
            self.persist(job)?;
        }
        for job in jobs {
            inner.jobs.insert(job.id, job);
        }
        inner.grids.insert(parent_id, grid.clone());
        drop(inner);
        self.ready.notify_all();
        Ok(grid)
    }

    /// Whether `id` names a grid parent (vs. a plain job).
    pub fn has_grid(&self, id: u64) -> bool {
        self.lock_inner().grids.contains_key(&id)
    }

    /// Look a grid up by its spec name (the repro harness's resume
    /// path: a killed table reopens the queue dir and finds its grid
    /// instead of resubmitting).
    pub fn find_grid(&self, name: &str) -> Option<Grid> {
        self.lock_inner().grids.values().find(|g| g.spec.name == name).cloned()
    }

    /// Snapshot every grid parent record, id order.
    pub fn grids(&self) -> Vec<Grid> {
        self.lock_inner().grids.values().cloned().collect()
    }

    /// The parent-status body for a grid id: derived state, per-state
    /// child counts, aggregate progress, one row per child.
    pub fn grid_status(&self, id: u64) -> Result<Json> {
        let inner = self.lock_inner();
        let Some(grid) = inner.grids.get(&id) else { bail!("no grid {id}") };
        Ok(grid_status_json(grid, &inner.jobs, self.summary_path(id).exists()))
    }

    /// Cancel a grid: fan out to every non-terminal child (`Queued`
    /// cells cancel immediately, `Running` cells get the cooperative
    /// flag). Returns how many children were affected; errors when
    /// every child is already terminal.
    pub fn cancel_grid(&self, id: u64) -> Result<usize> {
        let mut inner = self.lock_inner();
        let Some(grid) = inner.grids.get(&id).cloned() else { bail!("no grid {id}") };
        let mut affected = 0usize;
        for cid in &grid.children {
            let Some(job) = inner.jobs.get_mut(cid) else { continue };
            match job.state {
                JobState::Queued => {
                    job.state = JobState::Cancelled;
                    job.cancel_requested = true;
                }
                JobState::Running => job.cancel_requested = true,
                _ => continue,
            }
            affected += 1;
            let snap = job.clone();
            self.persist(&snap)?;
        }
        if affected == 0 {
            bail!("grid {id} has no cancellable children (all terminal)");
        }
        // queued-only grids are now fully terminal; running cells keep
        // the summary pending until their slices observe the flag
        self.maybe_finish_grid(&inner, id)?;
        Ok(affected)
    }

    /// Resume a grid: fan out to every `Cancelled`/`Failed` child,
    /// re-queueing them to continue bit-identically from their
    /// journals. Returns how many children were re-queued; errors when
    /// none was resumable. The stale summary (if any) is removed — it
    /// regenerates when the grid finishes again.
    pub fn resume_grid(&self, id: u64) -> Result<usize> {
        let mut inner = self.lock_inner();
        let Some(grid) = inner.grids.get(&id).cloned() else { bail!("no grid {id}") };
        let mut affected = 0usize;
        for cid in &grid.children {
            let Some(job) = inner.jobs.get_mut(cid) else { continue };
            match job.state {
                JobState::Cancelled | JobState::Failed => {
                    job.state = JobState::Queued;
                    job.cancel_requested = false;
                    job.error = None;
                }
                _ => continue,
            }
            affected += 1;
            let snap = job.clone();
            self.persist(&snap)?;
        }
        if affected == 0 {
            bail!("grid {id} has no resumable children");
        }
        let _ = std::fs::remove_file(self.summary_path(id));
        drop(inner);
        self.ready.notify_all();
        Ok(affected)
    }

    /// Write `grid-<id>.summary.json` iff every child of `id` is
    /// terminal (a child whose state file was quarantined counts as
    /// terminal — nothing will ever run it). Idempotent; called from
    /// every transition that can terminate a grid's last child.
    fn maybe_finish_grid(&self, inner: &Inner, id: u64) -> Result<()> {
        let Some(grid) = inner.grids.get(&id) else { return Ok(()) };
        let all_terminal = grid
            .children
            .iter()
            .all(|cid| inner.jobs.get(cid).map(|j| j.state.terminal()).unwrap_or(true));
        if !all_terminal {
            return Ok(());
        }
        let path = self.summary_path(id);
        let tmp = self.dir.join(format!("grid-{id}.summary.json.tmp"));
        std::fs::write(&tmp, format!("{}\n", grid_summary_json(grid, &inner.jobs).to_string()))
            .with_context(|| format!("writing grid summary {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing grid summary {path:?}"))?;
        crate::info!("[jobs] grid {id} '{}' finished — summary at {path:?}", grid.spec.name);
        Ok(())
    }

    /// Snapshot one job.
    pub fn get(&self, id: u64) -> Result<Job> {
        self.lock_inner()
            .jobs
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no job {id}"))
    }

    /// Snapshot every job, id order.
    pub fn list(&self) -> Vec<Job> {
        self.lock_inner().jobs.values().cloned().collect()
    }

    /// Request cancellation. A `Queued` job cancels immediately; a
    /// `Running` job gets the flag and the scheduler honors it at the
    /// next step boundary (cooperative). Terminal jobs error.
    pub fn cancel(&self, id: u64) -> Result<Job> {
        let mut inner = self.lock_inner();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        match job.state {
            JobState::Queued => {
                job.state = JobState::Cancelled;
                job.cancel_requested = true;
            }
            JobState::Running => job.cancel_requested = true,
            s => bail!("job {id} is {} and cannot be cancelled", s.as_str()),
        }
        let snap = job.clone();
        self.persist(&snap)?;
        // cancelling the last live cell of a grid finishes the grid
        if snap.state.terminal() {
            if let Some(pid) = snap.parent {
                self.maybe_finish_grid(&inner, pid)?;
            }
        }
        Ok(snap)
    }

    /// Re-queue a `Cancelled` or `Failed` job: it keeps its journal and
    /// continues from the exact step it stopped at (bit-identically, by
    /// the seed-replay property).
    pub fn resume(&self, id: u64) -> Result<Job> {
        let mut inner = self.lock_inner();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        match job.state {
            JobState::Cancelled | JobState::Failed => {
                job.state = JobState::Queued;
                job.cancel_requested = false;
                job.error = None;
            }
            s => bail!("job {id} is {} and cannot be resumed", s.as_str()),
        }
        let snap = job.clone();
        self.persist(&snap)?;
        // a re-queued cell invalidates its grid's aggregate; the
        // summary regenerates when the grid finishes again
        if let Some(pid) = snap.parent {
            let _ = std::fs::remove_file(self.summary_path(pid));
        }
        drop(inner);
        self.ready.notify_all();
        Ok(snap)
    }

    /// Claim the next runnable job for one slice: highest priority
    /// first, then least-recently-scheduled (round-robin within a
    /// priority level), then lowest id. The job transitions to
    /// `Running` and gets a fresh fairness stamp.
    pub fn next_runnable(&self) -> Option<Job> {
        self.next_runnable_where(|_| true)
    }

    /// [`next_runnable`](JobQueue::next_runnable) restricted to the
    /// given job ids — the targeted-drain primitive: a caller draining
    /// one grid must not claim (and train against its own base)
    /// unrelated jobs that happen to share the queue directory.
    pub fn next_runnable_among(&self, ids: &[u64]) -> Option<Job> {
        self.next_runnable_where(|id| ids.contains(&id))
    }

    fn next_runnable_where(&self, eligible: impl Fn(u64) -> bool) -> Option<Job> {
        let mut inner = self.lock_inner();
        let pick = inner
            .jobs
            .values()
            .filter(|j| j.state == JobState::Queued && !j.cancel_requested && eligible(j.id))
            .map(|j| (std::cmp::Reverse(j.spec.priority), j.last_scheduled, j.id))
            .min()?;
        let id = pick.2;
        inner.clock += 1;
        let stamp = inner.clock;
        let job = inner.jobs.get_mut(&id).unwrap();
        job.state = JobState::Running;
        job.last_scheduled = stamp;
        let snap = job.clone();
        let _ = self.persist(&snap);
        Some(snap)
    }

    /// Whether cancellation was requested for `id` (the scheduler's
    /// per-step cooperative stop poll).
    pub fn cancel_requested(&self, id: u64) -> bool {
        self.lock_inner().jobs.get(&id).map(|j| j.cancel_requested).unwrap_or(true)
    }

    /// Record the outcome of one slice ([`SliceOutcome`]): updated
    /// progress plus the next lifecycle state (back to `Queued`
    /// mid-run, or terminal). A cancel that raced the end of the slice
    /// (requested after the scheduler's in-slice check) is honored here
    /// instead of leaving the job parked as
    /// unschedulable-but-unresumable `Queued + cancel_requested`. A
    /// terminal transition of a grid cell checks the parent: when it
    /// was the last live cell, the grid summary is written.
    pub fn finish_slice(&self, id: u64, outcome: SliceOutcome) -> Result<Job> {
        let mut inner = self.lock_inner();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        job.steps_done = outcome.steps_done;
        job.slices_run += 1;
        job.state = if outcome.state == JobState::Queued && job.cancel_requested {
            JobState::Cancelled
        } else {
            outcome.state
        };
        job.error = outcome.error;
        job.published = outcome.published || job.published;
        if outcome.last_loss.is_finite() {
            job.last_loss = outcome.last_loss;
        }
        job.diverged = job.diverged || outcome.diverged;
        let requeued = job.state == JobState::Queued;
        match job.state {
            JobState::Completed => crate::obs::counter("jobs_completed_total", &[]).inc(),
            JobState::Failed => crate::obs::counter("jobs_failed_total", &[]).inc(),
            _ => {}
        }
        let snap = job.clone();
        self.persist(&snap)?;
        if snap.state.terminal() {
            if let Some(pid) = snap.parent {
                self.maybe_finish_grid(&inner, pid)?;
            }
        }
        drop(inner);
        if requeued {
            self.ready.notify_all();
        }
        Ok(snap)
    }

    /// Annotate a job with its currently-active alert rule names (the
    /// scheduler calls this with [`obs::alerts::evaluate_slice`]
    /// results at every slice boundary). Persisted, so `jobs show` and
    /// the HTTP body carry the annotation across restarts. A no-op
    /// when the annotation is already current (skips the disk write).
    ///
    /// [`obs::alerts::evaluate_slice`]: crate::obs::alerts::evaluate_slice
    pub fn set_alerts(&self, id: u64, rules: &[&str]) -> Result<()> {
        let mut inner = self.lock_inner();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        if job.alerts.iter().map(String::as_str).eq(rules.iter().copied()) {
            return Ok(());
        }
        job.alerts = rules.iter().map(|r| r.to_string()).collect();
        let snap = job.clone();
        self.persist(&snap)
    }

    /// Number of jobs in non-terminal states (queue depth gauge).
    pub fn active(&self) -> usize {
        self.lock_inner().jobs.values().filter(|j| !j.state.terminal()).count()
    }

    /// Queue depth by `(state, priority class)` — every combination,
    /// zeros included, so gauge refreshes overwrite stale values. The
    /// priority axis is classed (`low` < 0 < `high`, else `normal`) to
    /// keep the metric's label arity statically bounded.
    pub fn depth_stats(&self) -> Vec<(&'static str, &'static str, usize)> {
        const STATES: [JobState; 5] = [
            JobState::Queued,
            JobState::Running,
            JobState::Completed,
            JobState::Failed,
            JobState::Cancelled,
        ];
        const CLASSES: [&str; 3] = ["low", "normal", "high"];
        let class_of = |p: i64| {
            if p < 0 {
                "low"
            } else if p > 0 {
                "high"
            } else {
                "normal"
            }
        };
        let inner = self.lock_inner();
        let mut out = Vec::with_capacity(STATES.len() * CLASSES.len());
        for state in STATES {
            for class in CLASSES {
                let n = inner
                    .jobs
                    .values()
                    .filter(|j| j.state == state && class_of(j.spec.priority) == class)
                    .count();
                out.push((state.as_str(), class, n));
            }
        }
        out
    }

    /// Block up to `timeout` for a runnable job to appear. Returns
    /// whether one exists (spurious wakeups surface as `false` and the
    /// scheduler loop just re-polls).
    pub fn wait_for_work(&self, timeout: Duration) -> bool {
        let inner = self.lock_inner();
        let has = |i: &Inner| {
            i.jobs
                .values()
                .any(|j| j.state == JobState::Queued && !j.cancel_requested)
        };
        if has(&inner) {
            return true;
        }
        let (inner, _) = self
            .ready
            .wait_timeout(inner, timeout)
            .unwrap_or_else(PoisonError::into_inner);
        has(&inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, priority: i64) -> JobSpec {
        JobSpec { name: name.into(), steps: 4, priority, ..JobSpec::default() }
    }

    /// A mid-run slice outcome: +`steps` done, back to the queue.
    fn requeue(steps: usize) -> SliceOutcome {
        SliceOutcome { steps_done: steps, ..SliceOutcome::default() }
    }

    /// A terminal slice outcome.
    fn done(steps: usize, state: JobState) -> SliceOutcome {
        SliceOutcome {
            steps_done: steps,
            state,
            published: state == JobState::Completed,
            ..SliceOutcome::default()
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("smz_queue_{tag}_{}", std::process::id()))
    }

    #[test]
    fn submit_pick_order_honors_priority_then_round_robin() {
        let dir = tmp_dir("prio");
        let q = JobQueue::open(&dir).unwrap();
        let low = q.submit(spec("low", 0)).unwrap();
        let hi_a = q.submit(spec("hi-a", 5)).unwrap();
        let hi_b = q.submit(spec("hi-b", 5)).unwrap();
        // both high-priority jobs slice before the low one, round-robin
        let first = q.next_runnable().unwrap();
        assert_eq!(first.id, hi_a);
        q.finish_slice(hi_a, requeue(1)).unwrap();
        let second = q.next_runnable().unwrap();
        assert_eq!(second.id, hi_b, "round-robin within the priority level");
        q.finish_slice(hi_b, requeue(1)).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, hi_a, "alternates, no starvation");
        q.finish_slice(hi_a, done(2, JobState::Completed)).unwrap();
        q.finish_slice(hi_b, done(2, JobState::Completed)).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, low);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fairness_stamp_survives_reopen_then_alternates() {
        // the round-robin stamp is a scheduler-clock value; if the
        // clock restarted at zero on reopen, a restarted server would
        // hand every slice to the never-scheduled job until its stamp
        // caught up — or, worse, starve previously-waiting jobs whose
        // stamps now look far in the future. The reload path restores
        // the clock to max(last_scheduled), so same-priority jobs keep
        // alternating across the restart.
        let dir = tmp_dir("fair");
        let (a, b) = {
            let q = JobQueue::open(&dir).unwrap();
            let a = q.submit(spec("a", 3)).unwrap();
            let b = q.submit(spec("b", 3)).unwrap();
            // "a" slices once (stamp 1), then the server dies
            assert_eq!(q.next_runnable().unwrap().id, a);
            q.finish_slice(a, requeue(1)).unwrap();
            (a, b)
        };
        let q = JobQueue::open(&dir).unwrap();
        // the waiting job goes first after the restart...
        assert_eq!(q.next_runnable().unwrap().id, b, "reopen must not reset fairness");
        q.finish_slice(b, requeue(1)).unwrap();
        // ...and the pair keeps alternating (a fresh stamp is issued
        // past the restored clock, not from zero)
        assert_eq!(q.next_runnable().unwrap().id, a);
        q.finish_slice(a, requeue(2)).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn poisoned_lock_still_serves_the_jobs_api() {
        // one panic while holding the queue lock must not wedge every
        // subsequent endpoint (the PR-4 guarantee that a panicking
        // slice can't take the queue down extends to the lock itself)
        let dir = tmp_dir("poison");
        let q = JobQueue::open(&dir).unwrap();
        let id = q.submit(spec("p", 0)).unwrap();
        let poisoner = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = q.inner.lock().unwrap();
            panic!("poisoning the queue lock");
        }));
        assert!(poisoner.is_err());
        assert!(q.inner.is_poisoned(), "the panic above must have poisoned the mutex");
        // every entry point recovers: list, pick, slice, cancel, resume
        assert_eq!(q.list().len(), 1);
        assert_eq!(q.next_runnable().unwrap().id, id);
        q.finish_slice(id, requeue(1)).unwrap();
        let j = q.cancel(id).unwrap();
        assert_eq!(j.state, JobState::Cancelled);
        q.resume(id).unwrap();
        assert!(q.wait_for_work(Duration::from_millis(1)));
        assert_eq!(q.active(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_fans_out_children_that_interleave() {
        let dir = tmp_dir("grid_rr");
        let q = JobQueue::open(&dir).unwrap();
        let g = q
            .submit_grid(GridSpec {
                name: "g".into(),
                lrs: vec![1e-4, 3e-4],
                steps: 4,
                ..GridSpec::default()
            })
            .unwrap();
        assert_eq!(g.children.len(), 2);
        assert!(q.has_grid(g.id));
        assert!(!q.has_grid(g.children[0]));
        assert_eq!(q.find_grid("g").unwrap().id, g.id);
        // duplicate grid names are rejected (resume instead)
        assert!(q
            .submit_grid(GridSpec { name: "g".into(), steps: 4, ..GridSpec::default() })
            .is_err());
        // same-priority cells round-robin slice-by-slice
        let first = q.next_runnable().unwrap();
        assert_eq!(first.id, g.children[0]);
        assert_eq!(first.parent, Some(g.id));
        q.finish_slice(first.id, requeue(1)).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, g.children[1]);
        q.finish_slice(g.children[1], requeue(1)).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, g.children[0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_cancel_resume_fan_out_and_summary_lands_on_all_terminal() {
        let dir = tmp_dir("grid_fan");
        let q = JobQueue::open(&dir).unwrap();
        let g = q
            .submit_grid(GridSpec {
                name: "fan".into(),
                lrs: vec![1e-4, 3e-4],
                steps: 4,
                ..GridSpec::default()
            })
            .unwrap();
        // finish one cell; no summary yet (a cell is still live)
        let first = q.next_runnable().unwrap();
        q.finish_slice(
            first.id,
            SliceOutcome { last_loss: 0.5, ..done(4, JobState::Completed) },
        )
        .unwrap();
        assert!(!q.summary_path(g.id).exists());
        // parent cancel fans out to the one non-terminal cell...
        assert_eq!(q.cancel_grid(g.id).unwrap(), 1);
        // ...which makes every cell terminal -> the summary is written
        let text = std::fs::read_to_string(q.summary_path(g.id)).unwrap();
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.req("completed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.req("cancelled").unwrap().as_usize().unwrap(), 1);
        let cells = doc.req("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].req("final_train_loss").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(
            cells[1].req("final_train_loss").unwrap(),
            &Json::Null,
            "a never-run cell has no loss"
        );
        // derived parent state + counts
        let st = q.grid_status(g.id).unwrap();
        assert_eq!(st.req("state").unwrap().as_str().unwrap(), "cancelled");
        assert_eq!(st.req("completed").unwrap().as_usize().unwrap(), 1);
        assert!(matches!(st.req("summary_written").unwrap(), Json::Bool(true)));
        // nothing cancellable remains
        assert!(q.cancel_grid(g.id).is_err());
        // parent resume re-queues the cancelled cell and drops the
        // stale summary
        assert_eq!(q.resume_grid(g.id).unwrap(), 1);
        assert!(!q.summary_path(g.id).exists());
        let st = q.grid_status(g.id).unwrap();
        assert_eq!(st.req("state").unwrap().as_str().unwrap(), "queued");
        // completed cells are not resumable -> nothing left to resume
        q.finish_slice(q.next_runnable().unwrap().id, done(4, JobState::Completed)).unwrap();
        assert!(q.summary_path(g.id).exists(), "summary regenerates on re-completion");
        assert!(q.resume_grid(g.id).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_survives_reopen() {
        let dir = tmp_dir("grid_reopen");
        let (gid, children) = {
            let q = JobQueue::open(&dir).unwrap();
            let g = q
                .submit_grid(GridSpec {
                    name: "boot".into(),
                    lrs: vec![1e-4, 3e-4],
                    steps: 4,
                    ..GridSpec::default()
                })
                .unwrap();
            // one cell Running on disk at the "crash"
            q.next_runnable().unwrap();
            (g.id, g.children)
        };
        let q = JobQueue::open(&dir).unwrap();
        let g = q.find_grid("boot").unwrap();
        assert_eq!((g.id, g.children.clone()), (gid, children.clone()));
        // the interrupted cell re-queued; parent state reflects it
        let st = q.grid_status(gid).unwrap();
        assert_eq!(st.req("state").unwrap().as_str().unwrap(), "queued");
        assert_eq!(st.req("queued").unwrap().as_usize().unwrap(), 2);
        // ids keep increasing past the grid's block
        let next = q.submit(spec("after", 0)).unwrap();
        assert!(next > gid && children.iter().all(|&c| next > c));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lifecycle_and_persistence_survive_reopen() {
        let dir = tmp_dir("persist");
        {
            let q = JobQueue::open(&dir).unwrap();
            let a = q.submit(spec("a", 1)).unwrap();
            let b = q.submit(spec("b", 0)).unwrap();
            let picked = q.next_runnable().unwrap();
            assert_eq!(picked.id, a);
            // crash here: "a" is Running on disk, "b" Queued
            let _ = b;
        }
        let q = JobQueue::open(&dir).unwrap();
        let jobs = q.list();
        assert_eq!(jobs.len(), 2);
        // the interrupted Running job came back Queued
        assert!(jobs.iter().all(|j| j.state == JobState::Queued), "{jobs:?}");
        // ids keep increasing after reopen
        let c = q.submit(spec("c", 0)).unwrap();
        assert!(c > jobs.iter().map(|j| j.id).max().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cancel_and_resume_transitions() {
        let dir = tmp_dir("cancel");
        let q = JobQueue::open(&dir).unwrap();
        let id = q.submit(spec("x", 0)).unwrap();
        // queued job cancels immediately and is no longer runnable
        let j = q.cancel(id).unwrap();
        assert_eq!(j.state, JobState::Cancelled);
        assert!(q.next_runnable().is_none());
        // cancelling again errors (terminal)
        assert!(q.cancel(id).is_err());
        // resume re-queues it
        let j = q.resume(id).unwrap();
        assert_eq!(j.state, JobState::Queued);
        assert!(!j.cancel_requested);
        // running job: cancel sets the flag, scheduler observes it
        let picked = q.next_runnable().unwrap();
        assert_eq!(picked.id, id);
        let j = q.cancel(id).unwrap();
        assert_eq!(j.state, JobState::Running);
        assert!(q.cancel_requested(id));
        q.finish_slice(id, done(2, JobState::Cancelled)).unwrap();
        assert_eq!(q.get(id).unwrap().state, JobState::Cancelled);
        // a completed job cannot be resumed
        let finished = q.submit(spec("done", 0)).unwrap();
        q.next_runnable().unwrap();
        q.finish_slice(finished, done(4, JobState::Completed)).unwrap();
        assert!(q.resume(finished).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn job_json_round_trips() {
        let dir = tmp_dir("json");
        let q = JobQueue::open(&dir).unwrap();
        let id = q.submit(spec("rt", 2)).unwrap();
        q.next_runnable().unwrap();
        let j = q
            .finish_slice(
                id,
                SliceOutcome {
                    steps_done: 3,
                    state: JobState::Failed,
                    error: Some("diverged".into()),
                    last_loss: 1.25,
                    diverged: true,
                    ..SliceOutcome::default()
                },
            )
            .unwrap();
        let back = Job::from_json(&j.to_json()).unwrap();
        assert_eq!(back.id, j.id);
        assert_eq!(back.state, JobState::Failed);
        assert_eq!(back.error.as_deref(), Some("diverged"));
        assert_eq!(back.steps_done, 3);
        assert_eq!(back.slices_run, 1);
        assert_eq!(back.last_loss.to_bits(), 1.25f64.to_bits());
        assert!(back.diverged);
        assert_eq!(back.parent, None);
        assert_ne!(back.trace_id, 0, "trace ids are never 0 (0 = no trace)");
        assert_eq!(back.trace_id, j.trace_id, "trace_id must survive the hex round-trip");
        // a pre-PR-8 state file (no trace_id key) re-mints the same id
        let mut doc = j.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.remove("trace_id");
            fields.remove("alerts");
        }
        let legacy = Job::from_json(&doc).unwrap();
        assert_eq!(legacy.trace_id, j.trace_id);
        assert!(legacy.alerts.is_empty());
        // alert annotations persist through the state file
        q.set_alerts(id, &["stall", "worker-flap"]).unwrap();
        let annotated = q.get(id).unwrap();
        let back = Job::from_json(&annotated.to_json()).unwrap();
        assert_eq!(back.alerts, vec!["stall".to_string(), "worker-flap".to_string()]);
        // NaN loss crosses the state file as null and comes back NaN
        let fresh = JobQueue::fresh_job(9, spec("nan", 0), Some(3));
        let back = Job::from_json(&fresh.to_json()).unwrap();
        assert!(back.last_loss.is_nan());
        assert_eq!(back.parent, Some(3));
        std::fs::remove_dir_all(&dir).ok();
    }
}
