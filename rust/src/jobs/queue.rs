//! The persistent job queue: lifecycle state machine + priority pick.
//!
//! Every job is one state file (`job-<id>.json`: spec + lifecycle) plus
//! one step journal (`job-<id>.journal.jsonl`, the PR-2 format) and an
//! optional slice checkpoint (`job-<id>.ckpt`) under the queue
//! directory. The state file is rewritten on every transition and after
//! every slice, so a crashed or restarted orchestrator reopens the
//! directory and finds every job where it left off — `Running` jobs
//! (interrupted mid-slice) downgrade to `Queued` and resume from their
//! journal, which is the whole point of the seed-replay property: a
//! job's entire training state is a few bytes per step.
//!
//! Scheduling policy (see [`JobQueue::next_runnable`]): highest
//! `priority` first; within a priority level, least-recently-scheduled
//! first — so equal-priority jobs interleave slice-by-slice and a long
//! job cannot starve a short one.
//!
//! Lifecycle: `Queued → Running → {Completed, Failed, Cancelled}`, with
//! `Running → Queued` at every slice boundary (cooperative
//! time-slicing) and `{Failed, Cancelled} → Queued` via
//! [`resume`](JobQueue::resume).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

use super::spec::JobSpec;

/// Lifecycle state of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// waiting for (more) scheduler slices
    Queued,
    /// a scheduler is currently running one of its slices
    Running,
    /// all steps done, adapter published
    Completed,
    /// training errored or diverged (see `error`)
    Failed,
    /// cancelled by the tenant; journal retained, resumable
    Cancelled,
}

impl JobState {
    /// Wire/state-file name.
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Parse a state-file name.
    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "completed" => JobState::Completed,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            other => bail!("unknown job state '{other}'"),
        })
    }

    /// Whether the job can never be scheduled again without a `resume`.
    pub fn terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Failed | JobState::Cancelled)
    }
}

/// One job: spec + lifecycle bookkeeping.
#[derive(Debug, Clone)]
pub struct Job {
    /// queue-assigned id (monotonic)
    pub id: u64,
    /// the submitted spec
    pub spec: JobSpec,
    /// lifecycle state
    pub state: JobState,
    /// optimizer steps completed across all slices
    pub steps_done: usize,
    /// scheduler slices executed
    pub slices_run: usize,
    /// failure reason (Failed only)
    pub error: Option<String>,
    /// the adapter was registered in the serve registry
    pub published: bool,
    /// tenant asked for cancellation; honored at the next step boundary
    pub cancel_requested: bool,
    /// scheduler clock stamp of the last slice (round-robin fairness)
    last_scheduled: u64,
}

impl Job {
    /// Serialize the full job (state file + `GET /v1/jobs/{id}` body).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("state", Json::Str(self.state.as_str().into())),
            ("steps_done", Json::Num(self.steps_done as f64)),
            ("slices_run", Json::Num(self.slices_run as f64)),
            (
                "error",
                self.error.as_ref().map(|e| Json::Str(e.clone())).unwrap_or(Json::Null),
            ),
            ("published", Json::Bool(self.published)),
            ("cancel_requested", Json::Bool(self.cancel_requested)),
            ("last_scheduled", Json::Num(self.last_scheduled as f64)),
            ("spec", self.spec.to_json()),
        ])
    }

    /// Parse a state file back.
    pub fn from_json(doc: &Json) -> Result<Job> {
        let error = match doc.get("error") {
            Some(Json::Str(s)) => Some(s.clone()),
            _ => None,
        };
        Ok(Job {
            id: doc.req("id")?.as_f64()? as u64,
            spec: JobSpec::from_json(doc.req("spec")?)?,
            state: JobState::parse(doc.req("state")?.as_str()?)?,
            steps_done: doc.req("steps_done")?.as_usize()?,
            slices_run: doc.req("slices_run")?.as_usize()?,
            error,
            published: matches!(doc.get("published"), Some(Json::Bool(true))),
            cancel_requested: matches!(doc.get("cancel_requested"), Some(Json::Bool(true))),
            last_scheduled: doc
                .get("last_scheduled")
                .map(|v| v.as_f64().map(|x| x as u64))
                .transpose()?
                .unwrap_or(0),
        })
    }
}

/// Queue state behind the lock.
struct Inner {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    clock: u64,
}

/// The persistent job queue. See the module docs for the contract.
pub struct JobQueue {
    dir: PathBuf,
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl JobQueue {
    /// Open (or create) the queue directory and load every persisted
    /// job. Jobs found `Running` were interrupted mid-slice by a crash
    /// or shutdown; they re-enter the queue as `Queued` and resume from
    /// their journals.
    pub fn open(dir: &Path) -> Result<JobQueue> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating jobs dir {dir:?}"))?;
        let mut jobs = BTreeMap::new();
        let mut next_id = 1u64;
        let mut clock = 0u64;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if !(name.starts_with("job-") && name.ends_with(".json")) {
                continue;
            }
            let text = std::fs::read_to_string(&path)?;
            // a corrupt state file must not brick the whole queue (the
            // subsystem's pitch is crash recovery): quarantine it and
            // keep loading the healthy jobs. Writes are atomic
            // (temp+rename), so this only catches external damage.
            let mut job = match json::parse(&text).and_then(|doc| Job::from_json(&doc)) {
                Ok(job) => job,
                Err(e) => {
                    crate::info!("[jobs] quarantining unreadable state {path:?}: {e:#}");
                    let _ = std::fs::rename(&path, path.with_extension("json.corrupt"));
                    // never recycle the quarantined job's id: its journal
                    // and checkpoint files survive, and a new job under
                    // the same id would silently resume from them
                    if let Some(id) = name
                        .strip_prefix("job-")
                        .and_then(|s| s.strip_suffix(".json"))
                        .and_then(|s| s.parse::<u64>().ok())
                    {
                        next_id = next_id.max(id + 1);
                    }
                    continue;
                }
            };
            if job.state == JobState::Running {
                // crash recovery: an interrupted slice re-queues — unless
                // cancellation was already requested, which now completes
                job.state =
                    if job.cancel_requested { JobState::Cancelled } else { JobState::Queued };
            } else if job.state == JobState::Queued && job.cancel_requested {
                job.state = JobState::Cancelled;
            }
            next_id = next_id.max(job.id + 1);
            clock = clock.max(job.last_scheduled);
            jobs.insert(job.id, job);
        }
        let queue = JobQueue {
            dir: dir.to_path_buf(),
            inner: Mutex::new(Inner { jobs, next_id, clock }),
            ready: Condvar::new(),
        };
        // persist the Running->Queued downgrade so a second crash
        // before any slice still sees consistent state
        {
            let inner = queue.inner.lock().unwrap();
            for job in inner.jobs.values() {
                queue.persist(job)?;
            }
        }
        Ok(queue)
    }

    /// The queue directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Step-journal path for a job (the PR-2 JSONL format).
    pub fn journal_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.journal.jsonl"))
    }

    /// Slice-checkpoint path for a job (fast resume; journal replay is
    /// the fallback and audit path).
    pub fn checkpoint_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.ckpt"))
    }

    /// On-disk adapter artifact path for a published job.
    pub fn adapter_path(&self, name: &str) -> PathBuf {
        self.dir.join("adapters").join(format!("{name}.adapter"))
    }

    fn state_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id}.json"))
    }

    /// Rewrite one job's state file (called on every transition).
    /// Write-to-temp + rename, so a crash mid-write can never leave a
    /// truncated state file — the queue's reopen path must not find one.
    fn persist(&self, job: &Job) -> Result<()> {
        let path = self.state_path(job.id);
        let tmp = self.dir.join(format!("job-{}.json.tmp", job.id));
        std::fs::write(&tmp, format!("{}\n", job.to_json().to_string()))
            .with_context(|| format!("persisting job state {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing job state {path:?}"))
    }

    /// Submit a new job; returns its id. The spec is validated first.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        spec.validate()?;
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        let job = Job {
            id,
            spec,
            state: JobState::Queued,
            steps_done: 0,
            slices_run: 0,
            error: None,
            published: false,
            cancel_requested: false,
            last_scheduled: 0,
        };
        self.persist(&job)?;
        inner.jobs.insert(id, job);
        drop(inner);
        self.ready.notify_all();
        Ok(id)
    }

    /// Snapshot one job.
    pub fn get(&self, id: u64) -> Result<Job> {
        self.inner
            .lock()
            .unwrap()
            .jobs
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no job {id}"))
    }

    /// Snapshot every job, id order.
    pub fn list(&self) -> Vec<Job> {
        self.inner.lock().unwrap().jobs.values().cloned().collect()
    }

    /// Request cancellation. A `Queued` job cancels immediately; a
    /// `Running` job gets the flag and the scheduler honors it at the
    /// next step boundary (cooperative). Terminal jobs error.
    pub fn cancel(&self, id: u64) -> Result<Job> {
        let mut inner = self.inner.lock().unwrap();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        match job.state {
            JobState::Queued => {
                job.state = JobState::Cancelled;
                job.cancel_requested = true;
            }
            JobState::Running => job.cancel_requested = true,
            s => bail!("job {id} is {} and cannot be cancelled", s.as_str()),
        }
        let snap = job.clone();
        self.persist(&snap)?;
        Ok(snap)
    }

    /// Re-queue a `Cancelled` or `Failed` job: it keeps its journal and
    /// continues from the exact step it stopped at (bit-identically, by
    /// the seed-replay property).
    pub fn resume(&self, id: u64) -> Result<Job> {
        let mut inner = self.inner.lock().unwrap();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        match job.state {
            JobState::Cancelled | JobState::Failed => {
                job.state = JobState::Queued;
                job.cancel_requested = false;
                job.error = None;
            }
            s => bail!("job {id} is {} and cannot be resumed", s.as_str()),
        }
        let snap = job.clone();
        self.persist(&snap)?;
        drop(inner);
        self.ready.notify_all();
        Ok(snap)
    }

    /// Claim the next runnable job for one slice: highest priority
    /// first, then least-recently-scheduled (round-robin within a
    /// priority level), then lowest id. The job transitions to
    /// `Running` and gets a fresh fairness stamp.
    pub fn next_runnable(&self) -> Option<Job> {
        let mut inner = self.inner.lock().unwrap();
        let pick = inner
            .jobs
            .values()
            .filter(|j| j.state == JobState::Queued && !j.cancel_requested)
            .map(|j| (std::cmp::Reverse(j.spec.priority), j.last_scheduled, j.id))
            .min()?;
        let id = pick.2;
        inner.clock += 1;
        let stamp = inner.clock;
        let job = inner.jobs.get_mut(&id).unwrap();
        job.state = JobState::Running;
        job.last_scheduled = stamp;
        let snap = job.clone();
        let _ = self.persist(&snap);
        Some(snap)
    }

    /// Whether cancellation was requested for `id` (the scheduler's
    /// per-step cooperative stop poll).
    pub fn cancel_requested(&self, id: u64) -> bool {
        self.inner
            .lock()
            .unwrap()
            .jobs
            .get(&id)
            .map(|j| j.cancel_requested)
            .unwrap_or(true)
    }

    /// Record the outcome of one slice: updated progress plus the next
    /// lifecycle state (back to `Queued` mid-run, or terminal). A
    /// cancel that raced the end of the slice (requested after the
    /// scheduler's in-slice check) is honored here instead of leaving
    /// the job parked as unschedulable-but-unresumable
    /// `Queued + cancel_requested`.
    pub fn finish_slice(
        &self,
        id: u64,
        steps_done: usize,
        state: JobState,
        error: Option<String>,
        published: bool,
    ) -> Result<Job> {
        let mut inner = self.inner.lock().unwrap();
        let Some(job) = inner.jobs.get_mut(&id) else { bail!("no job {id}") };
        job.steps_done = steps_done;
        job.slices_run += 1;
        job.state = if state == JobState::Queued && job.cancel_requested {
            JobState::Cancelled
        } else {
            state
        };
        job.error = error;
        job.published = published || job.published;
        let requeued = job.state == JobState::Queued;
        let snap = job.clone();
        self.persist(&snap)?;
        drop(inner);
        if requeued {
            self.ready.notify_all();
        }
        Ok(snap)
    }

    /// Number of jobs in non-terminal states (queue depth gauge).
    pub fn active(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .jobs
            .values()
            .filter(|j| !j.state.terminal())
            .count()
    }

    /// Block up to `timeout` for a runnable job to appear. Returns
    /// whether one exists (spurious wakeups surface as `false` and the
    /// scheduler loop just re-polls).
    pub fn wait_for_work(&self, timeout: Duration) -> bool {
        let inner = self.inner.lock().unwrap();
        let has = |i: &Inner| {
            i.jobs
                .values()
                .any(|j| j.state == JobState::Queued && !j.cancel_requested)
        };
        if has(&inner) {
            return true;
        }
        let (inner, _) = self.ready.wait_timeout(inner, timeout).unwrap();
        has(&inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, priority: i64) -> JobSpec {
        JobSpec { name: name.into(), steps: 4, priority, ..JobSpec::default() }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("smz_queue_{tag}_{}", std::process::id()))
    }

    #[test]
    fn submit_pick_order_honors_priority_then_round_robin() {
        let dir = tmp_dir("prio");
        let q = JobQueue::open(&dir).unwrap();
        let low = q.submit(spec("low", 0)).unwrap();
        let hi_a = q.submit(spec("hi-a", 5)).unwrap();
        let hi_b = q.submit(spec("hi-b", 5)).unwrap();
        // both high-priority jobs slice before the low one, round-robin
        let first = q.next_runnable().unwrap();
        assert_eq!(first.id, hi_a);
        q.finish_slice(hi_a, 1, JobState::Queued, None, false).unwrap();
        let second = q.next_runnable().unwrap();
        assert_eq!(second.id, hi_b, "round-robin within the priority level");
        q.finish_slice(hi_b, 1, JobState::Queued, None, false).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, hi_a, "alternates, no starvation");
        q.finish_slice(hi_a, 2, JobState::Completed, None, true).unwrap();
        q.finish_slice(hi_b, 2, JobState::Completed, None, true).unwrap();
        assert_eq!(q.next_runnable().unwrap().id, low);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lifecycle_and_persistence_survive_reopen() {
        let dir = tmp_dir("persist");
        {
            let q = JobQueue::open(&dir).unwrap();
            let a = q.submit(spec("a", 1)).unwrap();
            let b = q.submit(spec("b", 0)).unwrap();
            let picked = q.next_runnable().unwrap();
            assert_eq!(picked.id, a);
            // crash here: "a" is Running on disk, "b" Queued
            let _ = b;
        }
        let q = JobQueue::open(&dir).unwrap();
        let jobs = q.list();
        assert_eq!(jobs.len(), 2);
        // the interrupted Running job came back Queued
        assert!(jobs.iter().all(|j| j.state == JobState::Queued), "{jobs:?}");
        // ids keep increasing after reopen
        let c = q.submit(spec("c", 0)).unwrap();
        assert!(c > jobs.iter().map(|j| j.id).max().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cancel_and_resume_transitions() {
        let dir = tmp_dir("cancel");
        let q = JobQueue::open(&dir).unwrap();
        let id = q.submit(spec("x", 0)).unwrap();
        // queued job cancels immediately and is no longer runnable
        let j = q.cancel(id).unwrap();
        assert_eq!(j.state, JobState::Cancelled);
        assert!(q.next_runnable().is_none());
        // cancelling again errors (terminal)
        assert!(q.cancel(id).is_err());
        // resume re-queues it
        let j = q.resume(id).unwrap();
        assert_eq!(j.state, JobState::Queued);
        assert!(!j.cancel_requested);
        // running job: cancel sets the flag, scheduler observes it
        let picked = q.next_runnable().unwrap();
        assert_eq!(picked.id, id);
        let j = q.cancel(id).unwrap();
        assert_eq!(j.state, JobState::Running);
        assert!(q.cancel_requested(id));
        q.finish_slice(id, 2, JobState::Cancelled, None, false).unwrap();
        assert_eq!(q.get(id).unwrap().state, JobState::Cancelled);
        // a completed job cannot be resumed
        let done = q.submit(spec("done", 0)).unwrap();
        q.next_runnable().unwrap();
        q.finish_slice(done, 4, JobState::Completed, None, true).unwrap();
        assert!(q.resume(done).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn job_json_round_trips() {
        let dir = tmp_dir("json");
        let q = JobQueue::open(&dir).unwrap();
        let id = q.submit(spec("rt", 2)).unwrap();
        q.next_runnable().unwrap();
        let j =
            q.finish_slice(id, 3, JobState::Failed, Some("diverged".into()), false).unwrap();
        let back = Job::from_json(&j.to_json()).unwrap();
        assert_eq!(back.id, j.id);
        assert_eq!(back.state, JobState::Failed);
        assert_eq!(back.error.as_deref(), Some("diverged"));
        assert_eq!(back.steps_done, 3);
        assert_eq!(back.slices_run, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
