//! The slice scheduler: cooperative multiplexing of fine-tuning jobs
//! over the serving engine's worker pool, closing the train→serve loop.
//!
//! One scheduler drains the [`JobQueue`] one **slice** at a time: claim
//! the highest-priority runnable job, advance it by a bounded number of
//! optimizer steps through the slice-resumable
//! [`DpTrainer`](crate::parallel::DpTrainer) entry point, checkpoint,
//! and put it back in the queue. Because every slice re-enters the
//! scheduling decision, a long job can never starve a short one at the
//! same priority (round-robin), and a higher-priority submission
//! preempts at the next slice boundary without losing a step.
//!
//! Checkpoint/resume is the seed-replay property made operational: a
//! job's training state *is* its `(seed, g)` step journal, so pausing
//! costs one buffered-write flush and resuming costs either an O(P)
//! checkpoint load (fast path) or a forward-pass-free journal replay
//! (fallback + audit) — both land on bit-identical parameters.
//!
//! On completion the scheduler replays the full journal, **verifies the
//! replay reproduces the live parameters bit-for-bit**, extracts the
//! sparse delta under the replay's exact-sparsity mask-union
//! certificate, saves the `.adapter` artifact, and publishes it into
//! the serve [`Registry`](crate::serve::AdapterRegistry) — the adapter
//! is classifiable the moment the job finishes, with no operator step
//! in between.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::data::{tasks, Dataset};
use crate::parallel::{is_worker_lost, protocol, DpTrainer, RemoteHandle, SliceState};
use crate::runtime::store::ParamStore;
use crate::runtime::ModelInfo;
use crate::serve::{ServeEngine, SparseDelta};
use crate::util::json::Json;

use super::queue::{Job, JobQueue, JobState, SliceOutcome};

/// Default steps per scheduler slice when a spec leaves `slice_steps` 0.
pub const DEFAULT_SLICE_STEPS: usize = 25;

/// The job scheduler. See the module docs for the policy.
pub struct Scheduler {
    engine: Arc<ServeEngine>,
    queue: Arc<JobQueue>,
    default_slice: usize,
    /// the engine's base as a shared store handle — no O(P) snapshot at
    /// construction. A slice materializes a flat copy only at the points
    /// that genuinely need one (begin/resume/publish), each a short
    /// `to_vec` rather than holding the resident base mutex across a
    /// long replay behind in-flight classify checkouts
    base: Arc<ParamStore>,
    /// datasets are deterministic in `(task, seed)`; caching them keeps
    /// per-slice bookkeeping from regenerating the same data every slice
    datasets: Mutex<BTreeMap<(String, u64), Arc<Dataset>>>,
}

impl Scheduler {
    /// A scheduler draining `queue` over `engine`'s pool/registry.
    /// `default_slice` (0 = [`DEFAULT_SLICE_STEPS`]) bounds a slice for
    /// specs that don't set their own.
    pub fn new(engine: Arc<ServeEngine>, queue: Arc<JobQueue>, default_slice: usize) -> Scheduler {
        let default_slice = if default_slice == 0 { DEFAULT_SLICE_STEPS } else { default_slice };
        let base = engine.registry.base_store();
        Scheduler { engine, queue, default_slice, base, datasets: Mutex::new(BTreeMap::new()) }
    }

    /// The (deterministic) dataset for a `(task, seed)` cell. Cached so
    /// consecutive slices of one job don't regenerate identical data;
    /// bounded (generation is cheap, so on overflow the cache simply
    /// resets rather than growing with every distinct tenant submission
    /// over a long-uptime server's life).
    fn dataset_for(&self, task: &str, seed: u64) -> Result<Arc<Dataset>> {
        const CACHE_CAP: usize = 8;
        let mut cache = self.datasets.lock().unwrap();
        if let Some(ds) = cache.get(&(task.to_string(), seed)) {
            return Ok(Arc::clone(ds));
        }
        let ds = Arc::new(
            tasks::generate(task, seed).with_context(|| format!("generating task '{task}'"))?,
        );
        if cache.len() >= CACHE_CAP {
            cache.clear();
        }
        cache.insert((task.to_string(), seed), Arc::clone(&ds));
        Ok(ds)
    }

    /// The queue this scheduler drains.
    pub fn queue(&self) -> &Arc<JobQueue> {
        &self.queue
    }

    /// Claim and run exactly one slice. Returns `false` when nothing is
    /// runnable. A failing (or panicking) slice marks its job `Failed`
    /// and never takes the scheduler down — one poisoned job cannot
    /// wedge the queue.
    pub fn run_one_slice(&self) -> bool {
        self.run_one_slice_stop(None)
    }

    /// [`run_one_slice`](Scheduler::run_one_slice) with a server stop
    /// flag threaded into the per-step cooperative poll, so shutdown
    /// interrupts an in-flight slice at the next step boundary (the
    /// journal/state pair stays consistent and the job simply
    /// re-queues) instead of blocking for the whole slice.
    fn run_one_slice_stop(&self, server_stop: Option<&AtomicBool>) -> bool {
        let Some(job) = self.queue.next_runnable() else {
            return false;
        };
        self.run_claimed_slice(job, server_stop);
        true
    }

    /// Run one slice of an already-claimed (`Running`) job and record
    /// its outcome, feed the job's flight recorder, and evaluate the
    /// alert rule catalog at the slice boundary.
    fn run_claimed_slice(&self, job: Job, server_stop: Option<&AtomicBool>) {
        // the trace scope outlives the span so the span's JSONL event
        // (emitted at drop) carries this job's trace id — the coordinator
        // half of the cross-node stitch (the worker half adopts the same
        // id from its Welcome frame)
        let _trace = crate::obs::trace_scope(job.trace_id);
        let slice_span = crate::obs::span("jobs.slice");
        // bracket the slice with the global heap window: its high-water
        // mark feeds the job timeline and the mem-budget alert rule
        // (slices are serialized per scheduler, so last-reset-wins is
        // exact here)
        crate::obs::mem::reset_window();
        let mem = crate::obs::mem_scope("jobs.slice");
        let recorder = crate::obs::recorder::for_job(job.id);
        let result = catch_unwind(AssertUnwindSafe(|| self.slice_job(&job, server_stop)));
        mem.end();
        let slice_mem_peak = crate::obs::mem::window_peak();
        recorder.note_mem_peak(slice_mem_peak);
        slice_span.end();
        let failed = |error: String| SliceOutcome {
            steps_done: job.steps_done,
            state: JobState::Failed,
            error: Some(error),
            ..SliceOutcome::default()
        };
        let outcome = match result {
            Ok(Ok(outcome)) => outcome,
            Ok(Err(e)) if is_worker_lost(&e) => {
                // a remote worker died mid-slice: not the job's fault.
                // Re-queue instead of failing — the journal was flushed
                // before the error surfaced, so the retry resumes
                // bit-identically from replay (with the dead session
                // severed, possibly all-local).
                crate::info!(
                    "[jobs] job {} '{}' lost a remote worker ({e:#}); re-queued",
                    job.id,
                    job.spec.name
                );
                crate::obs::counter("jobs_requeued_total", &[]).inc();
                // remotes own the top shard ranks, so charge the flap to
                // the highest rank (exact when one remote was leased)
                recorder.note_worker_lost(job.spec.workers.max(1) as u32 - 1);
                SliceOutcome { steps_done: job.steps_done, ..SliceOutcome::default() }
            }
            Ok(Err(e)) => failed(format!("{e:#}")),
            Err(payload) => {
                let msg = crate::util::panic_message(&*payload);
                failed(format!("slice panicked: {msg}"))
            }
        };
        if let Some(e) = &outcome.error {
            crate::info!("[jobs] job {} '{}' failed: {e}", job.id, job.spec.name);
        }
        let slice_diverged = outcome.diverged;
        let Ok(updated) = self.queue.finish_slice(job.id, outcome) else { return };

        // alert rules at the slice boundary: cheap, O(1) per rule over
        // the recorder snapshot. Active rule names are copied into the
        // job record so `jobs show` / `GET /v1/jobs/{id}` carry them.
        let obs = crate::obs::alerts::SliceObs {
            job: job.id,
            committed: updated.steps_done.saturating_sub(job.steps_done) as u64,
            runnable: updated.state == JobState::Queued,
            diverged: slice_diverged,
            mask_refresh: job.spec.mask_refresh,
            mem_peak_bytes: slice_mem_peak,
        };
        let rules = crate::obs::alerts::evaluate_slice(&obs, &recorder.snapshot());
        let _ = self.queue.set_alerts(job.id, &rules);
        if updated.state.terminal() {
            // release the active gauges — a dead job must not hold
            // `/healthz` degraded forever. The persisted annotation
            // above keeps the record of what was firing.
            crate::obs::alerts::clear_job(job.id);
        }
    }

    /// Run slices until the queue has nothing runnable; returns the
    /// number of slices executed (the CLI `jobs drain` path and the
    /// test harness).
    pub fn run_until_idle(&self) -> usize {
        let mut slices = 0;
        while self.run_one_slice() {
            slices += 1;
        }
        slices
    }

    /// Run slices of the given jobs only, until none of them is
    /// runnable; returns the number of slices executed. The targeted
    /// drain `sweep_via_queue` uses: other jobs sharing the queue
    /// directory are left untouched — claiming them here would train
    /// them against *this* scheduler's base, corrupting their
    /// journals' `init_fnv` and published deltas.
    pub fn drain_jobs(&self, ids: &[u64]) -> usize {
        let mut slices = 0;
        while let Some(job) = self.queue.next_runnable_among(ids) {
            self.run_claimed_slice(job, None);
            slices += 1;
        }
        slices
    }

    /// The background scheduler loop the HTTP server runs: drain slices,
    /// park briefly when idle, exit when `stop` flips — even mid-slice,
    /// at the next step boundary.
    pub fn run_loop(&self, stop: &AtomicBool) {
        while !stop.load(Ordering::Acquire) {
            if !self.run_one_slice_stop(Some(stop)) {
                self.queue.wait_for_work(Duration::from_millis(50));
            }
        }
    }

    /// Re-register the saved `.adapter` artifacts of already-published
    /// jobs — a restarted server starts with an empty registry, but the
    /// artifacts under `<dir>/adapters/` are the durable copies, so a
    /// tenant's completed job stays classifiable across restarts.
    /// Returns how many adapters were restored; an unreadable or
    /// over-budget artifact is logged and skipped, never fatal.
    /// [`http::serve`](crate::serve::http::serve) calls this before the
    /// scheduler loop starts.
    pub fn reload_published(&self) -> usize {
        let mut restored = 0;
        for job in self.queue.list() {
            if !(job.published && job.state == JobState::Completed) {
                continue;
            }
            let name = &job.spec.name;
            if self.engine.registry.contains(name) {
                continue;
            }
            let path = self.queue.adapter_path(name);
            match SparseDelta::load(&path, self.engine.model())
                .and_then(|delta| self.engine.registry.insert(name, delta))
            {
                Ok(_) => restored += 1,
                Err(e) => crate::info!(
                    "[jobs] could not restore published adapter '{name}' from {path:?}: {e:#}"
                ),
            }
        }
        restored
    }

    /// The fallible slice body: resolve config, restore state, advance
    /// one slice, checkpoint, and decide the next lifecycle state.
    fn slice_job(&self, job: &Job, server_stop: Option<&AtomicBool>) -> Result<SliceOutcome> {
        let spec = &job.spec;
        let model: ModelInfo = self.engine.model().clone();
        let cfg = spec.train_config(&model.name)?;
        // the dataset seed can differ from the run seed (grid cells
        // must train on the exact batches the serial sweep saw)
        let dataset = self.dataset_for(&spec.task, spec.dataset_seed())?;
        let journal = self.queue.journal_path(job.id);
        let mut trainer =
            DpTrainer::new(self.engine.runtime(), &self.engine.pool, cfg.clone())
                .with_journal(&journal);
        trainer.eval_test = false;
        trainer.mask_refresh = spec.mask_refresh;
        trainer.recorder = Some(crate::obs::recorder::for_job(job.id));
        // multi-shard cells may lease TCP workers parked at the engine's
        // hub; each slice hands the top shard ranks to whatever remotes
        // are connected (zero = all-local, bit-identical either way)
        if cfg.workers.max(1) > 1 {
            if let Some(hub) = self.engine.worker_hub() {
                trainer.remote = Some(RemoteHandle {
                    hub: Arc::clone(hub),
                    data_seed: spec.dataset_seed(),
                    trace_id: job.trace_id,
                });
            }
        }

        // jobs always train from the server's base (borrowed through the
        // shared store handle), so the published delta is valid against
        // the parameters classify serves
        let mut state = if !journal.exists() {
            trainer.begin_slices_store(&model, &self.base)?
        } else {
            match self.restore_from_checkpoint(job.id, &model, &journal) {
                Some(st) => st,
                None => {
                    let t0 = std::time::Instant::now();
                    let st = trainer.resume_slices_store(&model, &self.base)?;
                    if let Some(rec) = &trainer.recorder {
                        rec.note_replay(t0.elapsed().as_secs_f64());
                    }
                    st
                }
            }
        };

        let slice = if spec.slice_steps > 0 { spec.slice_steps } else { self.default_slice };
        let queue = &self.queue;
        let id = job.id;
        let stop = move || {
            queue.cancel_requested(id)
                || server_stop.map(|s| s.load(Ordering::Acquire)).unwrap_or(false)
        };
        let report = trainer.run_slice(&model, &dataset, &mut state, slice, Some(&stop))?;
        if !report.diverged {
            // a diverged slice leaves no checkpoint: its state stopped
            // mid-step (no record was journaled), and a checkpoint whose
            // step count matches the journal would shadow the
            // authoritative replay on a later resume
            self.save_checkpoint(job.id, &model, &state)?;
        }
        crate::debug!(
            "[jobs] job {id} '{}' slice {}: +{} steps ({}/{}), loss {:.4}",
            spec.name,
            job.slices_run + 1,
            report.steps_run,
            state.step,
            spec.steps,
            report.last_loss
        );

        let outcome = |st: JobState, error: Option<String>, published: bool| SliceOutcome {
            steps_done: state.step,
            state: st,
            error,
            published,
            last_loss: report.last_loss as f64,
            diverged: report.diverged,
        };
        if report.diverged {
            return Ok(outcome(
                JobState::Failed,
                Some(format!("diverged at step {}", state.step)),
                false,
            ));
        }
        if self.queue.cancel_requested(job.id) {
            return Ok(outcome(JobState::Cancelled, None, false));
        }
        if report.done {
            let base = self.base.to_vec();
            self.publish(job, &model, &base, &state, &cfg)?;
            return Ok(outcome(JobState::Completed, None, true));
        }
        Ok(outcome(JobState::Queued, None, false))
    }

    /// Fast resume: the slice checkpoint, accepted only when it matches
    /// the journal's record count exactly (a crash between the journal
    /// flush and the checkpoint write leaves them desynced — then the
    /// journal replay below is authoritative). The count check parses
    /// no records, so this path stays O(P + journal bytes) per slice.
    fn restore_from_checkpoint(
        &self,
        id: u64,
        model: &ModelInfo,
        journal: &std::path::Path,
    ) -> Option<SliceState> {
        let records = protocol::journal_record_count(journal).ok()?;
        let ck = Checkpoint::load_if_matching(&self.queue.checkpoint_path(id), model, records)?;
        let mask_epoch = ck.meta.get("mask_epoch")?.as_f64().ok()? as u32;
        let thresholds = ck
            .meta
            .get("thresholds")?
            .as_arr()
            .ok()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Result<Vec<f32>>>()
            .ok()?;
        Some(SliceState {
            step: ck.step,
            mask_epoch,
            params: ck.params,
            slots: ck.slots,
            thresholds,
        })
    }

    /// Persist the slice state as a checkpoint (params/slots binary,
    /// epoch + thresholds in the sidecar — all bit-exact round trips).
    fn save_checkpoint(&self, id: u64, model: &ModelInfo, state: &SliceState) -> Result<()> {
        Checkpoint {
            model: model.name.clone(),
            n_params: state.params.len(),
            step: state.step,
            params: state.params.clone(),
            slots: state.slots.clone(),
            meta: Json::obj(vec![
                ("kind", Json::Str("job-slice".into())),
                ("mask_epoch", Json::Num(state.mask_epoch as f64)),
                ("thresholds", Json::from_f32s(&state.thresholds)),
            ]),
        }
        .save(&self.queue.checkpoint_path(id))
    }

    /// Completion: replay the full journal, verify it reproduces the
    /// live parameters bit-for-bit, extract the delta under the
    /// mask-union certificate, save the `.adapter` artifact and publish
    /// it into the serve registry.
    fn publish(
        &self,
        job: &Job,
        model: &ModelInfo,
        base: &[f32],
        live: &SliceState,
        cfg: &crate::config::TrainConfig,
    ) -> Result<()> {
        let journal = self.queue.journal_path(job.id);
        let verify_span = crate::obs::span("jobs.replay_verify");
        let verify_mem = crate::obs::mem_scope("jobs.replay_verify");
        let verify_t0 = std::time::Instant::now();
        let (header, records) = protocol::load_journal(&journal)?;
        let outcome =
            protocol::replay_full(self.engine.runtime(), model, cfg, &header, base, &records)?;
        for (i, (a, b)) in outcome.params.iter().zip(&live.params).enumerate() {
            if a.to_bits() != b.to_bits() {
                bail!(
                    "journal replay diverges from live training state at coordinate {i} \
                     ({a} vs {b}) — refusing to publish job {}",
                    job.id
                );
            }
        }
        verify_span.end();
        verify_mem.end();
        if let Some(rec) = crate::obs::recorder::get(job.id) {
            rec.note_replay(verify_t0.elapsed().as_secs_f64());
        }
        let meta = Json::obj(vec![
            ("source", Json::Str(format!("job:{}", job.id))),
            ("task", Json::Str(job.spec.task.clone())),
            ("optimizer", Json::Str(job.spec.optimizer.clone())),
            ("steps", Json::Num(outcome.steps as f64)),
            ("seed", Json::Num(job.spec.seed as f64)),
        ]);
        let delta =
            SparseDelta::extract(model, base, &outcome.params, Some(&outcome.mask_union), meta)?;
        let apath = self.queue.adapter_path(&job.spec.name);
        delta
            .save(&apath)
            .with_context(|| format!("saving adapter artifact {apath:?}"))?;
        let evicted = self
            .engine
            .registry
            .insert(&job.spec.name, delta)
            .with_context(|| format!("publishing adapter '{}'", job.spec.name))?;
        crate::info!(
            "[jobs] job {} published adapter '{}' ({} steps{})",
            job.id,
            job.spec.name,
            outcome.steps,
            if evicted.is_empty() {
                String::new()
            } else {
                format!(", evicted {}", evicted.join(", "))
            }
        );
        Ok(())
    }
}
