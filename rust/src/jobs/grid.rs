//! Sweep-grid jobs: one spec fanning out to N queued cells.
//!
//! The paper's experimental workload is *grids* — tasks × optimizers ×
//! sparsity levels (§4 of Sparse MeZO, and the ZO benchmark matrix of
//! Zhang et al. 2024 at larger scale) — but a grid run in-process
//! (`coordinator::sweep`) has no pause, no priority and no crash
//! recovery. A [`GridSpec`] closes that gap: it [`expand`]s
//! deterministically into N child [`JobSpec`]s at submit time, each an
//! ordinary queue citizen (priority pick, round-robin fairness, slice
//! checkpointing, journal resume), while the parent [`Grid`] record
//! tracks child completion and aggregates per-cell results into
//! `grid-<id>.summary.json` — the same rows the serial sweep table
//! prints, surviving kills because every cell's training state is its
//! `(seed, g)` journal.
//!
//! Determinism contract: `expand` iterates task → optimizer → sparsity
//! → lr → eps in the order the axes were given, and cell `i` is always
//! named `<name>.c<i>` — so a resubmitted or reopened grid maps cells
//! to axis values identically, which is what lets the repro harness
//! resume a killed table instead of restarting it
//! ([`sweep_via_queue`](crate::coordinator::sweep::sweep_via_queue)).
//!
//! [`expand`]: GridSpec::expand

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

use super::queue::Job;
use super::spec::JobSpec;

/// Hard cap on cells per grid — a fat-fingered axis list must not fan
/// out into thousands of queued jobs.
pub const MAX_GRID_CELLS: usize = 256;

/// One sweep grid as submitted: axis values plus the settings every
/// cell shares. Empty hyper axes (`lrs`/`epss`/`sparsities`) mean "use
/// the task/optimizer preset" — one implicit cell on that axis.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// grid name; cell `i` becomes job `<name>.c<i>` (restricted
    /// charset — the cell name is an adapter/registry key)
    pub name: String,
    /// task axis (at least one)
    pub tasks: Vec<String>,
    /// optimizer axis (at least one; each must be slice-runnable)
    pub optimizers: Vec<String>,
    /// learning-rate axis (empty = preset)
    pub lrs: Vec<f64>,
    /// perturbation-scale axis (empty = preset)
    pub epss: Vec<f64>,
    /// sparsity axis (empty = preset)
    pub sparsities: Vec<f64>,
    /// optimizer steps per cell
    pub steps: usize,
    /// data-parallel width per cell
    pub workers: usize,
    /// shared scheduling priority — cells interleave round-robin
    pub priority: i64,
    /// steps per scheduler slice (0 = scheduler default)
    pub slice_steps: usize,
    /// threshold-refresh cadence per cell (0 = fixed at init)
    pub mask_refresh: usize,
    /// noise/run seed shared by every cell (paired runs)
    pub seed: u64,
    /// dataset seed override (None = `seed`; the repro harness pins
    /// its tables' dataset seed independently of the run seed)
    pub data_seed: Option<u64>,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            name: String::new(),
            tasks: vec!["rte".into()],
            optimizers: vec!["smezo".into()],
            lrs: Vec::new(),
            epss: Vec::new(),
            sparsities: Vec::new(),
            steps: 100,
            workers: 1,
            priority: 0,
            slice_steps: 0,
            mask_refresh: 0,
            seed: 42,
            data_seed: None,
        }
    }
}

/// An empty hyper axis is one implicit "use the preset" cell.
fn hyper_axis(vals: &[f64]) -> Vec<Option<f32>> {
    if vals.is_empty() {
        vec![None]
    } else {
        vals.iter().map(|&v| Some(v as f32)).collect()
    }
}

impl GridSpec {
    /// Number of cells this grid expands to.
    pub fn cells(&self) -> usize {
        self.tasks.len()
            * self.optimizers.len()
            * self.lrs.len().max(1)
            * self.epss.len().max(1)
            * self.sparsities.len().max(1)
    }

    /// Reject grids the queue could never run. Child specs are
    /// re-validated individually by [`expand`](GridSpec::expand) (bad
    /// optimizers etc. surface there with the cell's context).
    pub fn validate(&self) -> Result<()> {
        // ".c255" costs 5 chars of the 64-char job-name budget
        if self.name.is_empty() || self.name.len() > 58 {
            bail!("grid name must be 1..=58 characters");
        }
        if !self
            .name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.'))
        {
            bail!("grid name '{}' may only contain [A-Za-z0-9_.-]", self.name);
        }
        if self.tasks.is_empty() || self.optimizers.is_empty() {
            bail!("a grid needs at least one task and one optimizer");
        }
        if self.steps == 0 {
            bail!("grid steps must be > 0");
        }
        if self.workers == 0 {
            bail!("grid workers must be >= 1");
        }
        let cells = self.cells();
        if cells > MAX_GRID_CELLS {
            bail!("grid expands to {cells} cells (cap {MAX_GRID_CELLS})");
        }
        Ok(())
    }

    /// Deterministically fan the grid out into its child job specs:
    /// task → optimizer → sparsity → lr → eps, axes in submission
    /// order, cell `i` named `<name>.c<i>`. Every child passes
    /// [`JobSpec::validate`], so a grid either expands whole or not at
    /// all.
    pub fn expand(&self) -> Result<Vec<JobSpec>> {
        self.validate()?;
        let lrs = hyper_axis(&self.lrs);
        let epss = hyper_axis(&self.epss);
        let sparsities = hyper_axis(&self.sparsities);
        let mut out = Vec::with_capacity(self.cells());
        for task in &self.tasks {
            for optimizer in &self.optimizers {
                for &sparsity in &sparsities {
                    for &lr in &lrs {
                        for &eps in &epss {
                            let spec = JobSpec {
                                name: format!("{}.c{}", self.name, out.len()),
                                task: task.clone(),
                                optimizer: optimizer.clone(),
                                steps: self.steps,
                                workers: self.workers,
                                priority: self.priority,
                                slice_steps: self.slice_steps,
                                mask_refresh: self.mask_refresh,
                                seed: self.seed,
                                data_seed: self.data_seed,
                                lr,
                                eps,
                                sparsity,
                            };
                            spec.validate()?;
                            out.push(spec);
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Serialize for the wire (`POST /v1/jobs/grid`) and the parent
    /// state file.
    pub fn to_json(&self) -> Json {
        let strs = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect());
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("tasks", strs(&self.tasks)),
            ("optimizers", strs(&self.optimizers)),
            ("lrs", Json::from_f64s(&self.lrs)),
            ("epss", Json::from_f64s(&self.epss)),
            ("sparsities", Json::from_f64s(&self.sparsities)),
            ("steps", Json::Num(self.steps as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("priority", Json::Num(self.priority as f64)),
            ("slice_steps", Json::Num(self.slice_steps as f64)),
            ("mask_refresh", Json::Num(self.mask_refresh as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if let Some(ds) = self.data_seed {
            fields.push(("data_seed", Json::Num(ds as f64)));
        }
        Json::obj(fields)
    }

    /// Parse a grid spec from a submit body / state file. Only `name`
    /// is required; everything else has the [`Default`] values.
    pub fn from_json(doc: &Json) -> Result<GridSpec> {
        let mut spec = GridSpec {
            name: doc.req("name")?.as_str()?.to_string(),
            ..GridSpec::default()
        };
        let strs = |v: &Json| -> Result<Vec<String>> {
            v.as_arr()?.iter().map(|x| Ok(x.as_str()?.to_string())).collect()
        };
        let nums = |v: &Json| -> Result<Vec<f64>> {
            v.as_arr()?.iter().map(|x| x.as_f64()).collect()
        };
        if let Some(v) = doc.get("tasks") {
            spec.tasks = strs(v)?;
        }
        if let Some(v) = doc.get("optimizers") {
            spec.optimizers = strs(v)?;
        }
        if let Some(v) = doc.get("lrs") {
            spec.lrs = nums(v)?;
        }
        if let Some(v) = doc.get("epss") {
            spec.epss = nums(v)?;
        }
        if let Some(v) = doc.get("sparsities") {
            spec.sparsities = nums(v)?;
        }
        if let Some(v) = doc.get("steps") {
            spec.steps = v.as_usize()?;
        }
        if let Some(v) = doc.get("workers") {
            spec.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("priority") {
            spec.priority = v.as_f64()? as i64;
        }
        if let Some(v) = doc.get("slice_steps") {
            spec.slice_steps = v.as_usize()?;
        }
        if let Some(v) = doc.get("mask_refresh") {
            spec.mask_refresh = v.as_usize()?;
        }
        if let Some(v) = doc.get("seed") {
            spec.seed = v.as_f64()? as u64;
        }
        if let Some(v) = doc.get("data_seed") {
            spec.data_seed = Some(v.as_f64()? as u64);
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// The parent record of a submitted grid: the spec plus the child job
/// ids it fanned out to (in expansion order — index `i` is cell `i`).
/// A grid has no lifecycle state of its own; its state is derived from
/// its children ([`grid_status_json`]).
#[derive(Debug, Clone)]
pub struct Grid {
    /// queue-assigned id (same id space as jobs)
    pub id: u64,
    /// the submitted spec
    pub spec: GridSpec,
    /// child job ids, expansion order
    pub children: Vec<u64>,
}

impl Grid {
    /// Serialize the parent state file (`grid-<id>.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("grid", Json::Bool(true)),
            ("spec", self.spec.to_json()),
            (
                "children",
                Json::Arr(self.children.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
        ])
    }

    /// Parse a parent state file back.
    pub fn from_json(doc: &Json) -> Result<Grid> {
        Ok(Grid {
            id: doc.req("id")?.as_f64()? as u64,
            spec: GridSpec::from_json(doc.req("spec")?)?,
            children: doc
                .req("children")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|x| x as u64))
                .collect::<Result<_>>()?,
        })
    }
}

/// Derived parent state: `running` if any child runs, else `queued` if
/// any child waits, else `completed`/`failed`/`cancelled` by child
/// outcomes (worst non-success wins over `cancelled`).
fn derived_state(grid: &Grid, jobs: &BTreeMap<u64, Job>) -> &'static str {
    use super::queue::JobState;
    let mut any_running = false;
    let mut any_queued = false;
    let mut any_failed = false;
    let mut any_cancelled = false;
    for cid in &grid.children {
        match jobs.get(cid).map(|j| j.state) {
            Some(JobState::Running) => any_running = true,
            Some(JobState::Queued) => any_queued = true,
            Some(JobState::Failed) => any_failed = true,
            Some(JobState::Cancelled) | None => any_cancelled = true,
            Some(JobState::Completed) => {}
        }
    }
    if any_running {
        "running"
    } else if any_queued {
        "queued"
    } else if any_failed {
        "failed"
    } else if any_cancelled {
        "cancelled"
    } else {
        "completed"
    }
}

/// The parent-status body (`GET /v1/jobs/{id}` for a grid id): derived
/// state, per-state child counts, aggregate progress, and one row per
/// child.
pub(crate) fn grid_status_json(
    grid: &Grid,
    jobs: &BTreeMap<u64, Job>,
    summary_written: bool,
) -> Json {
    use super::queue::JobState;
    let mut counts = [0usize; 5]; // queued/running/completed/failed/cancelled
    let mut steps_done = 0usize;
    let mut children = Vec::with_capacity(grid.children.len());
    for cid in &grid.children {
        let Some(job) = jobs.get(cid) else { continue };
        let slot = match job.state {
            JobState::Queued => 0,
            JobState::Running => 1,
            JobState::Completed => 2,
            JobState::Failed => 3,
            JobState::Cancelled => 4,
        };
        counts[slot] += 1;
        steps_done += job.steps_done;
        children.push(Json::obj(vec![
            ("id", Json::Num(job.id as f64)),
            ("name", Json::Str(job.spec.name.clone())),
            ("state", Json::Str(job.state.as_str().into())),
            ("steps_done", Json::Num(job.steps_done as f64)),
        ]));
    }
    Json::obj(vec![
        ("id", Json::Num(grid.id as f64)),
        ("grid", Json::Bool(true)),
        ("name", Json::Str(grid.spec.name.clone())),
        ("state", Json::Str(derived_state(grid, jobs).into())),
        ("cells", Json::Num(grid.children.len() as f64)),
        ("queued", Json::Num(counts[0] as f64)),
        ("running", Json::Num(counts[1] as f64)),
        ("completed", Json::Num(counts[2] as f64)),
        ("failed", Json::Num(counts[3] as f64)),
        ("cancelled", Json::Num(counts[4] as f64)),
        ("steps_done", Json::Num(steps_done as f64)),
        (
            "steps_total",
            Json::Num((grid.spec.steps * grid.children.len()) as f64),
        ),
        ("summary_written", Json::Bool(summary_written)),
        ("children", Json::Arr(children)),
    ])
}

/// The aggregated per-cell results written to `grid-<id>.summary.json`
/// once every child is terminal: the serial sweep table's rows (axis
/// values, final train loss, divergence) plus each cell's lifecycle
/// outcome. `final_train_loss` serializes through the f64 JSON writer,
/// so a cell's loss round-trips bit-exactly (NaN → `null`).
pub(crate) fn grid_summary_json(grid: &Grid, jobs: &BTreeMap<u64, Job>) -> Json {
    use super::queue::JobState;
    let opt_num = |v: Option<f32>| v.map(|x| Json::Num(x as f64)).unwrap_or(Json::Null);
    let mut cells = Vec::with_capacity(grid.children.len());
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut cancelled = 0usize;
    for cid in &grid.children {
        let Some(job) = jobs.get(cid) else { continue };
        match job.state {
            JobState::Completed => completed += 1,
            JobState::Failed => failed += 1,
            JobState::Cancelled => cancelled += 1,
            _ => {}
        }
        cells.push(Json::obj(vec![
            ("job", Json::Num(job.id as f64)),
            ("name", Json::Str(job.spec.name.clone())),
            ("task", Json::Str(job.spec.task.clone())),
            ("optimizer", Json::Str(job.spec.optimizer.clone())),
            ("lr", opt_num(job.spec.lr)),
            ("eps", opt_num(job.spec.eps)),
            ("sparsity", opt_num(job.spec.sparsity)),
            ("state", Json::Str(job.state.as_str().into())),
            ("steps_done", Json::Num(job.steps_done as f64)),
            ("final_train_loss", Json::Num(job.last_loss)),
            ("diverged", Json::Bool(job.diverged)),
            (
                "error",
                job.error.as_ref().map(|e| Json::Str(e.clone())).unwrap_or(Json::Null),
            ),
            ("published", Json::Bool(job.published)),
        ]));
    }
    Json::obj(vec![
        ("grid", Json::Num(grid.id as f64)),
        ("name", Json::Str(grid.spec.name.clone())),
        ("completed", Json::Num(completed as f64)),
        ("failed", Json::Num(failed as f64)),
        ("cancelled", Json::Num(cancelled as f64)),
        ("cells", Json::Arr(cells)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(name: &str) -> GridSpec {
        GridSpec { name: name.into(), steps: 8, ..GridSpec::default() }
    }

    #[test]
    fn expand_is_deterministic_and_ordered() {
        let mut g = grid("g");
        g.tasks = vec!["rte".into(), "boolq".into()];
        g.lrs = vec![1e-4, 3e-4];
        g.sparsities = vec![0.6];
        let a = g.expand().unwrap();
        let b = g.expand().unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(g.cells(), 4);
        // task-major, then lr, names indexed in order
        let keys: Vec<(String, Option<u32>, String)> = a
            .iter()
            .map(|s| (s.task.clone(), s.lr.map(f32::to_bits), s.name.clone()))
            .collect();
        let key = |t: &str, lr: f32, n: &str| (t.to_string(), Some(lr.to_bits()), n.to_string());
        assert_eq!(keys[0], key("rte", 1e-4, "g.c0"));
        assert_eq!(keys[1], key("rte", 3e-4, "g.c1"));
        assert_eq!(keys[2], key("boolq", 1e-4, "g.c2"));
        assert_eq!(keys[3], key("boolq", 3e-4, "g.c3"));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.lr.map(f32::to_bits), y.lr.map(f32::to_bits));
        }
        // empty hyper axes leave the preset in place (None override)
        assert!(a[0].eps.is_none());
        assert_eq!(a[0].sparsity.map(f32::to_bits), Some(0.6f32.to_bits()));
        // shared knobs propagate
        assert_eq!(a[3].steps, 8);
        assert_eq!(a[3].seed, 42);
    }

    #[test]
    fn validation_rejects_bad_grids() {
        assert!(grid("").validate().is_err());
        assert!(grid("has space").validate().is_err());
        let mut g = grid("x");
        g.steps = 0;
        assert!(g.validate().is_err());
        let mut g = grid("x");
        g.tasks = vec![];
        assert!(g.validate().is_err());
        let mut g = grid("x");
        g.lrs = vec![1e-4; MAX_GRID_CELLS + 1];
        assert!(g.validate().is_err());
        // a bad optimizer passes the grid check but fails expansion
        let mut g = grid("x");
        g.optimizers = vec!["smezo_const".into()];
        assert!(g.validate().is_ok());
        assert!(g.expand().is_err());
    }

    #[test]
    fn grid_spec_json_round_trip_is_lossless() {
        let mut g = grid("rt.grid-1");
        g.tasks = vec!["rte".into(), "wic".into()];
        g.optimizers = vec!["mezo".into(), "smezo".into()];
        g.lrs = vec![1e-4, 3e-4];
        g.sparsities = vec![0.5, 0.75];
        g.priority = -2;
        g.workers = 2;
        g.slice_steps = 4;
        g.data_seed = Some(1234);
        let back = GridSpec::from_json(&g.to_json()).unwrap();
        assert_eq!(back.name, g.name);
        assert_eq!(back.tasks, g.tasks);
        assert_eq!(back.optimizers, g.optimizers);
        assert_eq!(back.lrs, g.lrs);
        assert!(back.epss.is_empty());
        assert_eq!(back.sparsities, g.sparsities);
        assert_eq!(back.priority, -2);
        assert_eq!(back.workers, 2);
        assert_eq!(back.slice_steps, 4);
        assert_eq!(back.data_seed, Some(1234));
        // and the parent record round-trips with its children
        let parent = Grid { id: 7, spec: g, children: vec![8, 9, 10, 11] };
        let back = Grid::from_json(&parent.to_json()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.children, vec![8, 9, 10, 11]);
        assert_eq!(back.spec.cells(), 16);
    }
}
