//! Train-to-serve job orchestration: the async fine-tuning job queue.
//!
//! Sparse-MeZO fine-tunes at inference-level memory, which makes
//! fine-tuning itself cheap enough to offer as a multi-tenant service —
//! this subsystem is that service's control plane, closing the loop
//! between the PR-2 data-parallel trainer and the PR-3 adapter server:
//!
//! * [`spec`] — [`JobSpec`](spec::JobSpec): the tenant-facing job
//!   description (task × optimizer cell, sparsity/mask knobs, step
//!   budget, DP width, priority, slice size), JSON on the wire and at
//!   rest.
//! * [`queue`] — the persistent [`JobQueue`](queue::JobQueue): one
//!   state file + one step journal + one slice checkpoint per job;
//!   survives restarts (interrupted `Running` jobs re-queue and resume
//!   from their journals); priority pick with round-robin fairness
//!   inside a priority level.
//! * [`grid`] — sweep-grid jobs: a [`GridSpec`](grid::GridSpec) fans
//!   one spec out into N child jobs at submit time (tasks × optimizers
//!   × sparsity/lr/eps axes — the paper's §4 experiment shape), a
//!   parent [`Grid`](grid::Grid) record tracks child completion, and
//!   the queue aggregates per-cell results into
//!   `grid-<id>.summary.json` once every cell is terminal.
//! * [`scheduler`] — the [`Scheduler`](scheduler::Scheduler):
//!   cooperative time-slicing of runnable jobs over the serve engine's
//!   [`WorkerPool`](crate::parallel::WorkerPool), per-slice
//!   checkpointing through the step journal, cooperative mid-slice
//!   cancel, and auto-publish of the finished adapter into the serve
//!   registry under the exact-sparsity replay certificate.
//!
//! Why pause/resume is ~free here and impossible for first-order
//! fine-tuning at this cost: a MeZO-family run's entire state is its
//! `(seed, g)` step stream (Malladi et al.'s seed-replay property), so
//! a paused job is a few bytes per completed step plus an O(P)
//! checkpoint, and resumption lands on **bit-identical** parameters —
//! asserted end-to-end, across slice boundaries, cancellations and
//! `mask_refresh` threshold epochs, in `tests/jobs.rs`.
//!
//! The lifecycle is exposed over the serve HTTP server (`POST
//! /v1/jobs`, `GET /v1/jobs`, `GET /v1/jobs/{id}`, `POST
//! /v1/jobs/{id}/cancel`, `POST /v1/jobs/{id}/resume`) and the `jobs`
//! CLI subcommand.

pub mod grid;
pub mod queue;
pub mod scheduler;
pub mod spec;

pub use grid::{Grid, GridSpec};
pub use queue::{Job, JobQueue, JobState, SliceOutcome};
pub use scheduler::Scheduler;
pub use spec::JobSpec;
