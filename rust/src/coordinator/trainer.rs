//! The ZO training loop (paper Algorithm 1, coordinator side).
//!
//! Per step the coordinator: draws a batch, derives the step seed, and
//! dispatches the AOT-compiled step executable with the device-resident
//! state. Everything heavier than the K-float metric readback stays on
//! device. Evaluation snapshots (accuracy on dev) happen every
//! `eval_every` steps and feed the convergence analysis of Fig. 1/3.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::evaluator::{self, EvalResult};
use crate::coordinator::schedule::Schedule;
use crate::data::batcher::TrainLoader;
use crate::data::{tasks, Dataset, Example};
use crate::parallel::{eval as peval, WorkerPool};
use crate::runtime::exec::{Hypers, InitExec, LogitsExec, StepExec, StepMetrics, ThreshExec};
use crate::runtime::{Runtime, TrainState};
use crate::util::json::Json;
use crate::util::log::JsonlWriter;

/// One point on an accuracy-over-steps curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// optimizer step the evaluation ran after
    pub step: usize,
    /// dev-split candidate-scored accuracy
    pub dev_accuracy: f64,
    /// dev-split mean cross-entropy
    pub dev_loss: f64,
    /// smoothed training loss at this step
    pub train_loss_ema: f64,
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// `TrainConfig::label()` of the run
    pub config_label: String,
    /// steps actually executed (may stop early on divergence)
    pub steps_run: usize,
    /// periodic dev evaluations
    pub curve: Vec<CurvePoint>,
    /// last dev evaluation (loss only; kept for report compatibility)
    pub final_dev: Option<EvalResult>,
    /// test-split evaluation (skipped after divergence)
    pub test: Option<EvalResult>,
    /// whether divergence detection fired
    pub diverged: bool,
    /// total wallclock including evaluation pauses
    pub wallclock_s: f64,
    /// mean seconds per optimizer step (excluding eval pauses)
    pub sec_per_step: f64,
    /// final parameters (host) for downstream analysis / checkpointing
    pub params: Vec<f32>,
    /// raw per-step training losses
    pub train_losses: Vec<f32>,
}

impl TrainResult {
    /// Best dev accuracy seen along the curve (the model-selection metric).
    pub fn best_dev_accuracy(&self) -> f64 {
        self.curve.iter().map(|c| c.dev_accuracy).fold(0.0, f64::max)
    }
}

/// Training-loss threshold beyond which a ZO run counts as diverged
/// (Fig. 2a's divergence detection; ln(512) ~ 6.24 is the uniform loss).
pub const DIVERGENCE_LOSS: f32 = 9.0;

/// Resolve a run's initial parameters: explicit override first, then a
/// configured checkpoint, then the deterministic `init` program. Shared
/// by the serial [`Trainer`] and the data-parallel
/// [`DpTrainer`](crate::parallel::dp::DpTrainer) so both start from the
/// same bits for the same config.
pub(crate) fn resolve_initial_params(
    rt: &Runtime,
    cfg: &TrainConfig,
    initial_override: &Option<Vec<f32>>,
    model: &crate::runtime::ModelInfo,
) -> Result<Vec<f32>> {
    if let Some(p) = initial_override {
        if p.len() != model.n_params {
            bail!("initial_override has {} params, model expects {}", p.len(), model.n_params);
        }
        return Ok(p.clone());
    }
    if let Some(path) = &cfg.init_from {
        let ck = Checkpoint::load(&PathBuf::from(path), model)
            .with_context(|| format!("loading init checkpoint {path}"))?;
        crate::info!("initialized from checkpoint {path} (step {})", ck.step);
        Ok(ck.params)
    } else {
        let init = InitExec::load(rt, model)?;
        init.run(rt, (cfg.seed as u32, 0x1717))
    }
}

/// Driver for one training run.
pub struct Trainer<'rt> {
    /// the runtime (and through it, the compute backend) to train on
    pub rt: &'rt Runtime,
    /// fully-resolved run configuration
    pub cfg: TrainConfig,
    /// learning-rate schedule (constant for the ZO family)
    pub schedule: Schedule,
    /// stream per-step metrics here if set
    pub jsonl: Option<JsonlWriter>,
    /// evaluate on test at the end
    pub eval_test: bool,
    /// explicit initial parameters (pretrained weights shared across a
    /// whole experiment table) — takes precedence over cfg.init_from
    pub initial_override: Option<Vec<f32>>,
    /// shard evaluation passes across this pool when set (training steps
    /// stay serial; use [`DpTrainer`](crate::parallel::dp::DpTrainer)
    /// for data-parallel stepping)
    pub pool: Option<&'rt WorkerPool>,
}

impl<'rt> Trainer<'rt> {
    /// A trainer with default policy: constant LR, test eval at the end.
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> Trainer<'rt> {
        Trainer {
            rt,
            cfg,
            schedule: Schedule::Constant,
            jsonl: None,
            eval_test: true,
            initial_override: None,
            pool: None,
        }
    }

    /// Stream per-step metric records to a JSONL file under `path`.
    pub fn with_jsonl(mut self, path: &std::path::Path) -> Result<Self> {
        self.jsonl = Some(JsonlWriter::create(path)?);
        Ok(self)
    }

    /// Shard evaluation passes across `pool` (bit-identical results to
    /// serial evaluation; only the schedule changes).
    pub fn with_pool(mut self, pool: &'rt WorkerPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Evaluate through the pool when one is attached, serially otherwise.
    fn evaluate(
        &self,
        logits: &LogitsExec,
        params: &[f32],
        examples: &[Example],
        cap: usize,
    ) -> Result<EvalResult> {
        let sp = crate::obs::span("train.forward");
        crate::obs::counter("train_evals_total", &[]).inc();
        let out = match self.pool {
            Some(pool) => peval::evaluate_sharded(self.rt, pool, logits, params, examples, cap),
            None => evaluator::evaluate(self.rt, logits, params, examples, cap),
        };
        sp.end();
        out
    }

    /// Resolve initial parameters: checkpoint if configured, else `init`.
    fn initial_params(&self, model: &crate::runtime::ModelInfo) -> Result<Vec<f32>> {
        resolve_initial_params(self.rt, &self.cfg, &self.initial_override, model)
    }

    /// Resolve the model + dataset from the config and run.
    pub fn run(&mut self) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        cfg.validate()?;
        let model = self.rt.model(&cfg.model)?.clone();
        let dataset = tasks::generate(&cfg.task, cfg.seed)?;
        self.run_on(&model, &dataset)
    }

    /// Run against an explicit dataset (the experiment harness shares one
    /// dataset across methods so comparisons are paired).
    pub fn run_on(
        &mut self,
        model: &crate::runtime::ModelInfo,
        dataset: &Dataset,
    ) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        let t_total = std::time::Instant::now();

        // ---- setup ---------------------------------------------------------
        if cfg.optimizer == "mezo_lora" || cfg.optimizer == "lora_fo" {
            bail!("use LoraTrainer for adapter-based optimizers");
        }
        let params = self.initial_params(model)?;
        let thresh = ThreshExec::load(self.rt, model)?;
        let thresholds = {
            let _sp = crate::obs::span("train.threshold_refresh");
            let _mem = crate::obs::mem_scope("train.threshold_refresh");
            thresh.run(self.rt, &params, cfg.hypers.sparsity)?
        };
        let mut step_exec = StepExec::load(self.rt, model, &cfg.optimizer, cfg.hypers, &thresholds)?;
        let logits = LogitsExec::load(self.rt, model)?;
        let prog = model.step_program(&cfg.optimizer)?;
        let slots = prog.slots.unwrap_or(0);
        let mut state = if cfg.page_cache_bytes > 0 {
            // paged tier: the parameter prefix lives in a file-backed
            // store bounded by the cache budget; the stateless ZO family
            // executes against page runs (runtime/native.rs::step_paged),
            // bit-identical to the resident path
            if slots > 0 {
                bail!(
                    "--page-cache-bytes requires a stateless optimizer \
                     (mezo/smezo/smezo_large/rmezo); '{}' keeps {slots} slot floats",
                    cfg.optimizer
                );
            }
            if self.rt.backend().platform() != "native" {
                bail!("--page-cache-bytes requires the native backend");
            }
            TrainState::from_params_paged(&params, slots, model.n_metrics, cfg.page_cache_bytes)?
        } else {
            TrainState::from_params(self.rt, &params, slots, model.n_metrics)?
        };

        let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;

        // ---- loop ----------------------------------------------------------
        let mut curve = Vec::new();
        let mut train_losses = Vec::with_capacity(cfg.steps);
        let mut ema = crate::util::stats::Ema::new(0.95);
        let mut diverged = false;
        let mut step_seconds = 0.0f64;
        let mut current_lr = cfg.hypers.lr;
        let mut g_abs_ewma = 0.0f64;

        for t in 0..cfg.steps {
            let lr = self.schedule.lr_at(cfg.hypers.lr, t);
            if (lr - current_lr).abs() > f32::EPSILON * lr.abs().max(1e-12) {
                step_exec.set_hypers(self.rt, Hypers { lr, ..cfg.hypers })?;
                current_lr = lr;
            }
            let batch = loader.next_batch();
            let seed = (cfg.seed as u32, t as u32);
            // the span and `step_seconds` share one measurement, so the
            // run summary and the metrics registry can never disagree
            let sp = crate::obs::span("train.step");
            let step_mem = crate::obs::mem_scope("train.step");
            step_exec.run(self.rt, &mut state, &batch.tokens, &batch.labels, seed)?;
            let mets = StepMetrics::from_tail(&state.metrics(self.rt)?)?;
            step_mem.end();
            step_seconds += sp.end();
            crate::obs::counter("train_steps_total", &[]).inc();

            let loss = mets.train_loss;
            train_losses.push(loss);
            let smoothed = ema.update(loss as f64);

            // scaled-integer telemetry gauges (the registry Gauge is an
            // AtomicI64, so floats ride in fixed-point units)
            let g_abs = mets.proj_grad.abs() as f64;
            g_abs_ewma = if t == 0 { g_abs } else { 0.9 * g_abs_ewma + 0.1 * g_abs };
            crate::obs::gauge("train_last_loss_milli", &[]).set((loss as f64 * 1e3) as i64);
            crate::obs::gauge("train_g_abs_ewma_micro", &[]).set((g_abs_ewma * 1e6) as i64);
            crate::obs::gauge("train_mask_nonzero", &[])
                .set((mets.masked_frac as f64 * model.n_params as f64).round() as i64);

            if let Some(w) = &mut self.jsonl {
                if cfg.log_every > 0 && t % cfg.log_every == 0 {
                    w.write(&Json::obj(vec![
                        ("step", Json::Num(t as f64)),
                        ("loss", Json::Num(loss as f64)),
                        ("loss_ema", Json::Num(smoothed)),
                        ("l_plus", Json::Num(mets.l_plus as f64)),
                        ("l_minus", Json::Num(mets.l_minus as f64)),
                        ("proj_grad", Json::Num(mets.proj_grad as f64)),
                        ("masked_frac", Json::Num(mets.masked_frac as f64)),
                        ("lr", Json::Num(lr as f64)),
                    ]))?;
                }
            }
            if cfg.log_every > 0 && t % (cfg.log_every * 10) == 0 {
                crate::debug!(
                    "[{}] step {t}/{} loss {loss:.4} (ema {smoothed:.4}) g {:.3}",
                    cfg.label(),
                    cfg.steps,
                    mets.proj_grad
                );
            }

            // divergence detection (Fig. 2a)
            if !loss.is_finite() || loss > DIVERGENCE_LOSS {
                crate::info!("[{}] DIVERGED at step {t} (loss {loss})", cfg.label());
                diverged = true;
                break;
            }

            // periodic dev evaluation
            let is_last = t + 1 == cfg.steps;
            if (cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0) || is_last {
                let p = state.params_host(self.rt)?;
                let dev = self.evaluate(&logits, &p, &dataset.dev, cfg.eval_cap)?;
                curve.push(CurvePoint {
                    step: t + 1,
                    dev_accuracy: dev.accuracy(),
                    dev_loss: dev.mean_loss,
                    train_loss_ema: smoothed,
                });
                if let Some(w) = &mut self.jsonl {
                    w.write(&Json::obj(vec![
                        ("step", Json::Num((t + 1) as f64)),
                        ("dev_accuracy", Json::Num(dev.accuracy())),
                        ("dev_loss", Json::Num(dev.mean_loss)),
                    ]))?;
                }
                crate::info!(
                    "[{}] step {}/{} dev acc {:.3} loss {:.3}",
                    cfg.label(),
                    t + 1,
                    cfg.steps,
                    dev.accuracy(),
                    dev.mean_loss
                );
            }
        }

        // ---- final evaluation ----------------------------------------------
        let params = state.params_host(self.rt)?;
        let final_dev = curve.last().map(|c| EvalResult {
            n: 0,
            correct: 0,
            mean_loss: c.dev_loss,
        });
        let test = if self.eval_test && !diverged {
            Some(self.evaluate(&logits, &params, &dataset.test, 0)?)
        } else {
            None
        };
        if let Some(w) = &mut self.jsonl {
            w.flush()?;
        }
        let steps_run = train_losses.len();
        Ok(TrainResult {
            config_label: cfg.label(),
            steps_run,
            curve,
            final_dev,
            test,
            diverged,
            wallclock_s: t_total.elapsed().as_secs_f64(),
            sec_per_step: step_seconds / steps_run.max(1) as f64,
            params,
            train_losses,
        })
    }
}

/// Zero-shot / in-context baselines share the eval path.
pub fn zero_shot(
    rt: &Runtime,
    model_name: &str,
    dataset: &Dataset,
    params: &[f32],
    cap: usize,
) -> Result<EvalResult> {
    let model = rt.model(model_name)?;
    let logits = LogitsExec::load(rt, model)?;
    evaluator::evaluate(rt, &logits, params, &dataset.test, cap)
}

/// In-context learning: k-shot prompts built from train examples.
pub fn in_context(
    rt: &Runtime,
    model_name: &str,
    dataset: &Dataset,
    params: &[f32],
    shots: usize,
    cap: usize,
) -> Result<EvalResult> {
    let model = rt.model(model_name)?;
    let logits = LogitsExec::load(rt, model)?;
    let slice = if cap > 0 && cap < dataset.test.len() { &dataset.test[..cap] } else { &dataset.test };

    // rebuild each test example with demonstrations prepended
    let demo = &dataset.train[..shots.min(dataset.train.len())];
    let prompted: Vec<crate::data::Example> = slice
        .iter()
        .map(|e| crate::data::Example {
            prompt: tasks::icl_prompt(demo, e, model.seq_len),
            label: e.label,
            candidates: e.candidates.clone(),
        })
        .collect();
    let mut total = EvalResult { n: 0, correct: 0, mean_loss: 0.0 };
    for batch in crate::data::batcher::eval_batches(&prompted, model.batch, model.seq_len) {
        let lg = logits.run(rt, params, &batch.tokens)?;
        let r = evaluator::score_batch(&lg, model.vocab, &batch);
        total.mean_loss = (total.mean_loss * total.n as f64 + r.mean_loss * r.n as f64)
            / (total.n + r.n).max(1) as f64;
        total.n += r.n;
        total.correct += r.correct;
    }
    Ok(total)
}
