//! The half-batch generalization probe on the *real* model — Fig. 2b and
//! Fig. 4.
//!
//! Protocol (paper §3.1): draw a 2B batch, split into halves B1/B2;
//! estimate the update on B1 (one ZO step via the AOT step program, or an
//! FO-SGD step for the Fig-4 contrast); measure the loss change on both
//! halves; keep the update and continue. P(loss increase) per epoch-sized
//! window is the reported series.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::batcher::TrainLoader;
use crate::data::Dataset;
use crate::coordinator::evaluator::batch_loss;
use crate::runtime::exec::{LogitsExec, StepExec, ThreshExec};
use crate::runtime::{Runtime, TrainState};
use crate::util::stats::wilson_interval;

/// One window (epoch analog) of probe statistics.
#[derive(Debug, Clone)]
pub struct ProbeWindow {
    /// window index (epoch analog)
    pub window: usize,
    /// probe steps in this window
    pub n: usize,
    /// count of loss increases on the same half-batch
    pub up_same: usize,
    /// count of loss increases on the held-out half-batch
    pub up_held: usize,
}

impl ProbeWindow {
    /// P(loss increase | same batch).
    pub fn p_up_same(&self) -> f64 {
        self.up_same as f64 / self.n.max(1) as f64
    }
    /// P(loss increase | held-out batch).
    pub fn p_up_held(&self) -> f64 {
        self.up_held as f64 / self.n.max(1) as f64
    }
    /// Wilson interval for the held-out proportion.
    pub fn held_interval(&self) -> (f64, f64) {
        wilson_interval(self.up_held, self.n, 1.96)
    }
}

#[derive(Debug, Clone)]
/// Full probe outcome: per-window statistics.
pub struct ProbeResult {
    /// estimator that drove the probe
    pub optimizer: String,
    /// per-window counts
    pub windows: Vec<ProbeWindow>,
}

impl ProbeResult {
    /// Pooled P(up | same) over all windows.
    pub fn overall_up_same(&self) -> f64 {
        let (u, n): (usize, usize) =
            self.windows.iter().fold((0, 0), |(u, n), w| (u + w.up_same, n + w.n));
        u as f64 / n.max(1) as f64
    }
    /// Pooled P(up | held-out) over all windows.
    pub fn overall_up_held(&self) -> f64 {
        let (u, n): (usize, usize) =
            self.windows.iter().fold((0, 0), |(u, n), w| (u + w.up_held, n + w.n));
        u as f64 / n.max(1) as f64
    }
}

/// Run the probe for `steps` update steps, reporting per-`window` stats.
/// `cfg.optimizer` selects the estimator: any ZO variant, or `fo_sgd` for
/// the Fig-4 exact-gradient arm (both go through their exported step).
pub fn half_batch_probe(
    rt: &Runtime,
    cfg: &TrainConfig,
    dataset: &Dataset,
    init_params: &[f32],
    steps: usize,
    window: usize,
) -> Result<ProbeResult> {
    let model = rt.model(&cfg.model)?.clone();
    let thresh = ThreshExec::load(rt, &model)?;
    let thresholds = thresh.run(rt, init_params, cfg.hypers.sparsity)?;
    let step_exec = StepExec::load(rt, &model, &cfg.optimizer, cfg.hypers, &thresholds)?;
    let logits = LogitsExec::load(rt, &model)?;
    let prog = model.step_program(&cfg.optimizer)?;
    let mut state =
        TrainState::from_params(rt, init_params, prog.slots.unwrap_or(0), model.n_metrics)?;
    let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;

    let mut windows: Vec<ProbeWindow> = Vec::new();
    let mut cur = ProbeWindow { window: 0, n: 0, up_same: 0, up_held: 0 };
    for t in 0..steps {
        let (b1, b2) = loader.next_half_batches();
        // loss before (both halves) — params pulled once per phase
        let params = state.params_host(rt)?;
        let l1_before = batch_loss(rt, &logits, &params, &b1)?;
        let l2_before = batch_loss(rt, &logits, &params, &b2)?;
        // one update step computed ON b1
        step_exec.run(rt, &mut state, &b1.tokens, &b1.labels, (cfg.seed as u32, t as u32))?;
        // loss after
        let params = state.params_host(rt)?;
        let l1_after = batch_loss(rt, &logits, &params, &b1)?;
        let l2_after = batch_loss(rt, &logits, &params, &b2)?;

        cur.n += 1;
        if l1_after > l1_before {
            cur.up_same += 1;
        }
        if l2_after > l2_before {
            cur.up_held += 1;
        }
        if cur.n == window || t + 1 == steps {
            crate::info!(
                "[probe {}] window {} P(up|same)={:.2} P(up|held)={:.2} (n={})",
                cfg.optimizer,
                cur.window,
                cur.p_up_same(),
                cur.p_up_held(),
                cur.n
            );
            let next_idx = cur.window + 1;
            windows.push(cur);
            cur = ProbeWindow { window: next_idx, n: 0, up_same: 0, up_held: 0 };
        }
    }
    Ok(ProbeResult { optimizer: cfg.optimizer.clone(), windows })
}
