//! Convergence analysis: steps-to-target-accuracy and speedup ratios —
//! the quantities behind Fig. 1 ("3.5x speedup") and Fig. 3.

use crate::coordinator::trainer::CurvePoint;

/// First step at which the dev accuracy reaches `target` (sustained —
/// single-eval blips don't count; we require the NEXT eval point to stay
/// above target - slack, or be the last point).
pub fn steps_to_accuracy(curve: &[CurvePoint], target: f64, slack: f64) -> Option<usize> {
    for (i, pt) in curve.iter().enumerate() {
        if pt.dev_accuracy >= target {
            let sustained = match curve.get(i + 1) {
                Some(next) => next.dev_accuracy >= target - slack,
                None => true,
            };
            if sustained {
                return Some(pt.step);
            }
        }
    }
    None
}

/// Speedup of `fast` over `slow` at the highest target both reach.
/// Returns (target_accuracy, steps_slow, steps_fast, ratio).
pub fn speedup(slow: &[CurvePoint], fast: &[CurvePoint]) -> Option<(f64, usize, usize, f64)> {
    let best_slow = slow.iter().map(|c| c.dev_accuracy).fold(0.0, f64::max);
    let best_fast = fast.iter().map(|c| c.dev_accuracy).fold(0.0, f64::max);
    let target = best_slow.min(best_fast);
    if target <= 0.0 {
        return None;
    }
    // measure at 98% of the common ceiling to dodge plateau noise
    let target = target * 0.98;
    let s = steps_to_accuracy(slow, target, 0.05)?;
    let f = steps_to_accuracy(fast, target, 0.05)?;
    Some((target, s, f, s as f64 / f as f64))
}

/// Area-under-curve of accuracy over steps (normalized) — a blip-robust
/// secondary convergence metric used in EXPERIMENTS.md.
pub fn accuracy_auc(curve: &[CurvePoint]) -> f64 {
    if curve.len() < 2 {
        return curve.first().map(|c| c.dev_accuracy).unwrap_or(0.0);
    }
    let mut area = 0.0;
    for w in curve.windows(2) {
        let dx = (w[1].step - w[0].step) as f64;
        area += dx * 0.5 * (w[0].dev_accuracy + w[1].dev_accuracy);
    }
    area / (curve.last().unwrap().step - curve[0].step) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(usize, f64)]) -> Vec<CurvePoint> {
        points
            .iter()
            .map(|&(step, acc)| CurvePoint {
                step,
                dev_accuracy: acc,
                dev_loss: 0.0,
                train_loss_ema: 0.0,
            })
            .collect()
    }

    #[test]
    fn finds_first_sustained_crossing() {
        let c = curve(&[(100, 0.5), (200, 0.72), (300, 0.55), (400, 0.71), (500, 0.73)]);
        // 0.7 at step 200 is a blip (next point 0.55 < 0.7 - 0.05)
        assert_eq!(steps_to_accuracy(&c, 0.7, 0.05), Some(400));
        assert_eq!(steps_to_accuracy(&c, 0.9, 0.05), None);
    }

    #[test]
    fn last_point_counts() {
        let c = curve(&[(100, 0.5), (200, 0.8)]);
        assert_eq!(steps_to_accuracy(&c, 0.75, 0.05), Some(200));
    }

    #[test]
    fn speedup_ratio() {
        let slow = curve(&[(1000, 0.5), (2000, 0.6), (3000, 0.7), (4000, 0.70)]);
        let fast = curve(&[(1000, 0.7), (2000, 0.72), (3000, 0.72), (4000, 0.72)]);
        let (_t, s, f, r) = speedup(&slow, &fast).unwrap();
        assert_eq!(s, 3000);
        assert_eq!(f, 1000);
        assert!((r - 3.0).abs() < 1e-9);
    }

    #[test]
    fn auc_prefers_early_risers() {
        let early = curve(&[(0, 0.7), (100, 0.7)]);
        let late = curve(&[(0, 0.2), (100, 0.7)]);
        assert!(accuracy_auc(&early) > accuracy_auc(&late));
    }
}
