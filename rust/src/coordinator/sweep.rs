//! Hyperparameter sweeps — the Fig-2a learning-rate sensitivity harness
//! and the Table-10 sparsity sweep share this grid driver.
//!
//! Grid cells are **independent runs** (shared dataset + paired seeds,
//! nothing mutated across cells), so they fan out across the shared
//! [`WorkerPool`] — the same scheduler the data-parallel trainer and
//! sharded evaluator use — instead of spawning one ad-hoc thread per
//! cell: a sweep's concurrency is bounded by the pool size, and a sweep
//! can coexist with other pool workloads without oversubscribing the
//! machine. This is what the
//! [`Backend: Send + Sync`](crate::runtime::backend::Backend) bound
//! buys. Log lines from concurrent cells interleave on stderr; results
//! are returned in grid order regardless.
//!
//! Cells honor the base config's `workers` knob: `workers > 1` trains
//! each cell through the seed-sync data-parallel engine
//! ([`DpTrainer`](crate::parallel::DpTrainer)), bit-identical to the
//! serial trainer — so a sweep can use DP inside cells *and* cell-level
//! concurrency at once, all on the one shared pool.
//!
//! [`sweep_via_queue`] is the crash-durable variant: the same axis grid
//! routed through the persistent job queue as a sweep-grid job
//! ([`GridSpec`](crate::jobs::GridSpec)), so a killed table resumes
//! from its cells' step journals instead of restarting — with per-cell
//! results **bit-identical** to [`sweep`] (asserted in `tests/jobs.rs`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{ServeConfig, TrainConfig};
use crate::coordinator::evaluator;
use crate::coordinator::trainer::Trainer;
use crate::data::{tasks, Dataset};
use crate::jobs::{GridSpec, JobQueue, JobState, Scheduler};
use crate::parallel::{protocol, DpTrainer, WorkerPool};
use crate::runtime::exec::{Hypers, LogitsExec};
use crate::runtime::Runtime;
use crate::serve::ServeEngine;

/// Outcome of one grid cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// the swept hyper's value for this cell
    pub value: f64,
    /// test accuracy (None when the run diverged)
    pub test_accuracy: Option<f64>,
    /// best dev accuracy along the curve (model-selection metric)
    pub best_dev_accuracy: f64,
    /// whether divergence detection fired
    pub diverged: bool,
    /// last recorded training loss (NaN if none)
    pub final_train_loss: f64,
}

/// Which hyper the sweep varies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SweepAxis {
    /// vary `hypers.lr` (the Fig-2a axis)
    LearningRate,
    /// vary `hypers.sparsity` (the Table-10 axis)
    Sparsity,
}

/// One worker: train `base` with the axis hyper set to `v`. The cell's
/// evaluation passes shard across the same `pool` its cell runs on —
/// safe because `scatter` callers participate in draining the queue.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    rt: &Runtime,
    pool: &WorkerPool,
    base: &TrainConfig,
    model: &crate::runtime::ModelInfo,
    dataset: &Dataset,
    axis: SweepAxis,
    v: f64,
    init_params: Option<&[f32]>,
) -> Result<SweepCell> {
    let mut cfg = base.clone();
    match axis {
        SweepAxis::LearningRate => cfg.hypers.lr = v as f32,
        SweepAxis::Sparsity => cfg.hypers.sparsity = v as f32,
    }
    crate::info!("[sweep {:?}={v}] starting ({})", axis, cfg.label());
    // `cfg.workers > 1` routes the cell through the seed-sync DP engine
    // (bit-identical to the serial trainer, asserted in this module's
    // tests and tests/parallel.rs) — its replica phases and this cell's
    // sibling cells share the same pool, which is nesting-safe by the
    // caller-participation contract
    let result = if cfg.workers > 1 {
        let mut trainer = DpTrainer::new(rt, pool, cfg);
        if let Some(p) = init_params {
            trainer.initial_override = Some(p.to_vec());
        }
        trainer.run_on(model, dataset)?
    } else {
        let mut trainer = Trainer::new(rt, cfg).with_pool(pool);
        if let Some(p) = init_params {
            trainer.initial_override = Some(p.to_vec());
        }
        trainer.run_on(model, dataset)?
    };
    Ok(SweepCell {
        value: v,
        test_accuracy: result.test.map(|t| t.accuracy()),
        best_dev_accuracy: result.best_dev_accuracy(),
        diverged: result.diverged,
        final_train_loss: *result.train_losses.last().unwrap_or(&f32::NAN) as f64,
    })
}

/// Run `base` once per grid value (shared dataset + paired seeds) and
/// collect accuracy/divergence per cell. Cells execute concurrently on
/// the shared `pool`; the returned vector is in grid order.
pub fn sweep(
    rt: &Runtime,
    pool: &WorkerPool,
    base: &TrainConfig,
    dataset: &Dataset,
    axis: SweepAxis,
    grid: &[f64],
    init_params: Option<&[f32]>,
) -> Result<Vec<SweepCell>> {
    let model = rt.model(&base.model)?.clone();
    let results: Vec<Result<SweepCell>> = pool.scatter(grid.len(), |i| {
        run_cell(rt, pool, base, &model, dataset, axis, grid[i], init_params)
    });
    results.into_iter().collect()
}

/// The training-relevant knobs of two configs, for the parity guard:
/// every hyper bit plus steps/seed/workers.
fn hypers_bits(h: &Hypers) -> [u32; 8] {
    [
        h.lr.to_bits(),
        h.eps.to_bits(),
        h.sparsity.to_bits(),
        h.mask_seed.to_bits(),
        h.beta1.to_bits(),
        h.beta2.to_bits(),
        h.adam_eps.to_bits(),
        h.wd.to_bits(),
    ]
}

/// Run an axis grid through the persistent job queue instead of
/// in-process: submit (or, when a grid named `grid_name` already rests
/// in `queue_dir`, **resume**) a sweep-grid job whose cells train the
/// exact configs [`sweep`] would, drain it with a scheduler over
/// `engine_rt`, and rebuild the per-cell results from the cells'
/// journals. Final losses and parameters are bit-identical to the
/// serial sweep of the same grid; a cell's test accuracy is evaluated
/// after training from its replayed parameters (cells skip mid-run dev
/// evals — jobs disable them — so `best_dev_accuracy` carries the test
/// accuracy as the model-selection stand-in).
///
/// `init` is the shared starting point every cell trains from (what
/// [`sweep`]'s `init_params` provides; the repro harness passes its
/// pretrained base). `data_seed` pins the dataset independently of the
/// run seed, matching the harness convention of a fixed dataset seed.
///
/// The point of the detour: the grid survives kills. Rerunning the
/// same call after a crash finds the grid by name, re-queues its
/// interrupted cells, and continues from their `(seed, g)` journals —
/// a killed table resumes instead of restarting.
#[allow(clippy::too_many_arguments)]
pub fn sweep_via_queue(
    rt: &Runtime,
    engine_rt: Runtime,
    base: &TrainConfig,
    axis: SweepAxis,
    grid: &[f64],
    init: &[f32],
    queue_dir: &Path,
    grid_name: &str,
    data_seed: u64,
) -> Result<Vec<SweepCell>> {
    let model = rt.model(&base.model)?.clone();
    let queue = Arc::new(JobQueue::open(queue_dir)?);
    let grid_rec = match queue.find_grid(grid_name) {
        Some(g) => {
            crate::info!(
                "[sweep-queue] resuming grid '{grid_name}' (id {}, {} cells)",
                g.id,
                g.children.len()
            );
            g
        }
        None => {
            let mut spec = GridSpec {
                name: grid_name.to_string(),
                tasks: vec![base.task.clone()],
                optimizers: vec![base.optimizer.clone()],
                // pin every hyper the spec can carry so each cell
                // resolves to exactly base + the axis value, even when
                // base deviates from the presets
                lrs: vec![base.hypers.lr as f64],
                epss: vec![base.hypers.eps as f64],
                sparsities: vec![base.hypers.sparsity as f64],
                steps: base.steps,
                workers: base.workers.max(1),
                seed: base.seed,
                data_seed: Some(data_seed),
                ..GridSpec::default()
            };
            match axis {
                SweepAxis::LearningRate => spec.lrs = grid.to_vec(),
                SweepAxis::Sparsity => spec.sparsities = grid.to_vec(),
            }
            queue.submit_grid(spec)?
        }
    };
    if grid_rec.children.len() != grid.len() {
        bail!(
            "grid '{grid_name}' in {queue_dir:?} has {} cells but this sweep asks for {} — \
             stale queue directory? pick a new name or directory",
            grid_rec.children.len(),
            grid.len()
        );
    }

    // parity guard: every cell must resolve to exactly the config the
    // serial sweep would train (presets + the spec's lr/eps/sparsity
    // overrides == base + axis value). Hypers a JobSpec cannot carry
    // (betas, wd, mask_seed) must therefore already match the presets.
    for (i, &cid) in grid_rec.children.iter().enumerate() {
        let child = queue.get(cid)?;
        let mut want = base.clone();
        match axis {
            SweepAxis::LearningRate => want.hypers.lr = grid[i] as f32,
            SweepAxis::Sparsity => want.hypers.sparsity = grid[i] as f32,
        }
        let got = child.spec.train_config(&base.model)?;
        if hypers_bits(&got.hypers) != hypers_bits(&want.hypers)
            || got.steps != want.steps
            || got.seed != want.seed
            || got.workers != want.workers.max(1)
            || child.spec.dataset_seed() != data_seed
        {
            bail!(
                "grid '{grid_name}' cell {i} (job {cid}) resolves to a different config than \
                 the serial sweep would train — the base config must derive its non-axis \
                 hypers from the task/optimizer presets (or the queue dir holds a stale \
                 grid under this name)"
            );
        }
    }

    // drain: the same engine + scheduler the server hosts, minus HTTP.
    // The engine's resident base is `init`, so every cell trains from
    // the sweep's shared starting point — which is exactly why the
    // drain is restricted to this grid's cells: unrelated jobs sharing
    // the queue directory must not be trained against *this* base.
    let scfg = ServeConfig {
        model: base.model.clone(),
        workers: base.workers.max(1),
        max_adapters: grid_rec.children.len().max(1),
        ..ServeConfig::default()
    };
    let engine = Arc::new(
        ServeEngine::new(engine_rt, &scfg, init.to_vec())?.with_jobs(Arc::clone(&queue), 0),
    );
    let scheduler = Scheduler::new(engine, Arc::clone(&queue), 0);
    let slices = scheduler.drain_jobs(&grid_rec.children);
    crate::info!("[sweep-queue] grid '{grid_name}' drained in {slices} slices");

    // rebuild cells in grid order from the terminal job records: the
    // journal replay is the authoritative parameter source, and test
    // accuracy is evaluated exactly as the trainers do (full test
    // split, serial fold)
    let dataset = tasks::generate(&base.task, data_seed)?;
    let logits = LogitsExec::load(rt, &model)?;
    let mut cells = Vec::with_capacity(grid.len());
    for (i, &cid) in grid_rec.children.iter().enumerate() {
        let job = queue.get(cid)?;
        match job.state {
            JobState::Completed => {}
            JobState::Failed if job.diverged => {
                cells.push(SweepCell {
                    value: grid[i],
                    test_accuracy: None,
                    best_dev_accuracy: 0.0,
                    diverged: true,
                    final_train_loss: job.last_loss,
                });
                continue;
            }
            state => bail!(
                "grid '{grid_name}' cell {i} (job {cid}) ended {}{} — resume the grid or \
                 inspect its journal",
                state.as_str(),
                job.error.as_ref().map(|e| format!(": {e}")).unwrap_or_default()
            ),
        }
        let cfg = job.spec.train_config(&base.model)?;
        let (header, records) = protocol::load_journal(&queue.journal_path(cid))?;
        let outcome = protocol::replay_full(rt, &model, &cfg, &header, init, &records)?;
        let test = evaluator::evaluate(rt, &logits, &outcome.params, &dataset.test, 0)?;
        let acc = test.accuracy();
        cells.push(SweepCell {
            value: grid[i],
            test_accuracy: Some(acc),
            best_dev_accuracy: acc,
            diverged: false,
            final_train_loss: job.last_loss,
        });
    }
    Ok(cells)
}

/// Pick the best cell by dev accuracy, treating divergence as -inf
/// (the paper's model-selection protocol: grid search on dev).
pub fn best_cell(cells: &[SweepCell]) -> Option<&SweepCell> {
    cells
        .iter()
        .filter(|c| !c.diverged)
        .max_by(|a, b| a.best_dev_accuracy.partial_cmp(&b.best_dev_accuracy).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_cell_skips_divergence() {
        let cells = vec![
            SweepCell { value: 1e-3, test_accuracy: Some(0.7), best_dev_accuracy: 0.7, diverged: false, final_train_loss: 0.5 },
            SweepCell { value: 1e-2, test_accuracy: None, best_dev_accuracy: 0.9, diverged: true, final_train_loss: f64::NAN },
        ];
        assert_eq!(best_cell(&cells).unwrap().value, 1e-3);
    }

    #[test]
    fn best_cell_empty_on_all_diverged() {
        let cells = vec![SweepCell {
            value: 1.0,
            test_accuracy: None,
            best_dev_accuracy: 0.0,
            diverged: true,
            final_train_loss: f64::NAN,
        }];
        assert!(best_cell(&cells).is_none());
    }

    #[test]
    fn dp_cells_bit_identical_to_serial_cells() {
        // the ROADMAP "DP under repro/sweep" item: grid cells routed
        // through the seed-sync DP engine (workers > 1) must reproduce
        // the serial sweep bit for bit — same cells, same losses
        let rt = Runtime::native();
        let ds = crate::data::tasks::generate_sized("rte", 5, 48, 16, 16).unwrap();
        let mut serial_cfg = TrainConfig::resolve("llama_tiny", "rte", "smezo", None).unwrap();
        serial_cfg.steps = 4;
        serial_cfg.eval_every = 0;
        serial_cfg.eval_cap = 8;
        let mut dp_cfg = serial_cfg.clone();
        dp_cfg.workers = 2; // 2 divides the llama_tiny batch
        let grid = [1e-4, 3e-4];
        let pool = WorkerPool::new(2);
        let a = sweep(&rt, &pool, &serial_cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        let b = sweep(&rt, &pool, &dp_cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.value, y.value);
            assert_eq!(
                x.final_train_loss.to_bits(),
                y.final_train_loss.to_bits(),
                "lr {}",
                x.value
            );
            assert_eq!(x.diverged, y.diverged);
        }
    }

    #[test]
    fn parallel_sweep_preserves_grid_order_and_pairs_runs() {
        // two tiny cells on the native backend; results must come back in
        // grid order and a repeated sweep must be bit-identical (paired
        // seeds + shared init) — including across pool sizes
        let rt = Runtime::native();
        let ds = crate::data::tasks::generate_sized("rte", 5, 48, 16, 16).unwrap();
        let mut cfg = TrainConfig::resolve("llama_tiny", "rte", "smezo", None).unwrap();
        cfg.steps = 4;
        cfg.eval_every = 0;
        cfg.eval_cap = 8;
        let grid = [1e-4, 3e-4];
        let pool = WorkerPool::new(2);
        let serial = WorkerPool::new(0);
        let a = sweep(&rt, &pool, &cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        let b = sweep(&rt, &serial, &cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].value, 1e-4);
        assert_eq!(a[1].value, 3e-4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.final_train_loss.to_bits(), y.final_train_loss.to_bits());
        }
    }
}
