//! Hyperparameter sweeps — the Fig-2a learning-rate sensitivity harness
//! and the Table-10 sparsity sweep share this grid driver.
//!
//! Grid cells are **independent runs** (shared dataset + paired seeds,
//! nothing mutated across cells), so they fan out across the shared
//! [`WorkerPool`] — the same scheduler the data-parallel trainer and
//! sharded evaluator use — instead of spawning one ad-hoc thread per
//! cell: a sweep's concurrency is bounded by the pool size, and a sweep
//! can coexist with other pool workloads without oversubscribing the
//! machine. This is what the
//! [`Backend: Send + Sync`](crate::runtime::backend::Backend) bound
//! buys. Log lines from concurrent cells interleave on stderr; results
//! are returned in grid order regardless.
//!
//! Cells honor the base config's `workers` knob: `workers > 1` trains
//! each cell through the seed-sync data-parallel engine
//! ([`DpTrainer`](crate::parallel::DpTrainer)), bit-identical to the
//! serial trainer — so a sweep can use DP inside cells *and* cell-level
//! concurrency at once, all on the one shared pool.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::trainer::Trainer;
use crate::data::Dataset;
use crate::parallel::{DpTrainer, WorkerPool};
use crate::runtime::Runtime;

/// Outcome of one grid cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// the swept hyper's value for this cell
    pub value: f64,
    /// test accuracy (None when the run diverged)
    pub test_accuracy: Option<f64>,
    /// best dev accuracy along the curve (model-selection metric)
    pub best_dev_accuracy: f64,
    /// whether divergence detection fired
    pub diverged: bool,
    /// last recorded training loss (NaN if none)
    pub final_train_loss: f64,
}

/// Which hyper the sweep varies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SweepAxis {
    /// vary `hypers.lr` (the Fig-2a axis)
    LearningRate,
    /// vary `hypers.sparsity` (the Table-10 axis)
    Sparsity,
}

/// One worker: train `base` with the axis hyper set to `v`. The cell's
/// evaluation passes shard across the same `pool` its cell runs on —
/// safe because `scatter` callers participate in draining the queue.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    rt: &Runtime,
    pool: &WorkerPool,
    base: &TrainConfig,
    model: &crate::runtime::ModelInfo,
    dataset: &Dataset,
    axis: SweepAxis,
    v: f64,
    init_params: Option<&[f32]>,
) -> Result<SweepCell> {
    let mut cfg = base.clone();
    match axis {
        SweepAxis::LearningRate => cfg.hypers.lr = v as f32,
        SweepAxis::Sparsity => cfg.hypers.sparsity = v as f32,
    }
    crate::info!("[sweep {:?}={v}] starting ({})", axis, cfg.label());
    // `cfg.workers > 1` routes the cell through the seed-sync DP engine
    // (bit-identical to the serial trainer, asserted in this module's
    // tests and tests/parallel.rs) — its replica phases and this cell's
    // sibling cells share the same pool, which is nesting-safe by the
    // caller-participation contract
    let result = if cfg.workers > 1 {
        let mut trainer = DpTrainer::new(rt, pool, cfg);
        if let Some(p) = init_params {
            trainer.initial_override = Some(p.to_vec());
        }
        trainer.run_on(model, dataset)?
    } else {
        let mut trainer = Trainer::new(rt, cfg).with_pool(pool);
        if let Some(p) = init_params {
            trainer.initial_override = Some(p.to_vec());
        }
        trainer.run_on(model, dataset)?
    };
    Ok(SweepCell {
        value: v,
        test_accuracy: result.test.map(|t| t.accuracy()),
        best_dev_accuracy: result.best_dev_accuracy(),
        diverged: result.diverged,
        final_train_loss: *result.train_losses.last().unwrap_or(&f32::NAN) as f64,
    })
}

/// Run `base` once per grid value (shared dataset + paired seeds) and
/// collect accuracy/divergence per cell. Cells execute concurrently on
/// the shared `pool`; the returned vector is in grid order.
pub fn sweep(
    rt: &Runtime,
    pool: &WorkerPool,
    base: &TrainConfig,
    dataset: &Dataset,
    axis: SweepAxis,
    grid: &[f64],
    init_params: Option<&[f32]>,
) -> Result<Vec<SweepCell>> {
    let model = rt.model(&base.model)?.clone();
    let results: Vec<Result<SweepCell>> = pool.scatter(grid.len(), |i| {
        run_cell(rt, pool, base, &model, dataset, axis, grid[i], init_params)
    });
    results.into_iter().collect()
}

/// Pick the best cell by dev accuracy, treating divergence as -inf
/// (the paper's model-selection protocol: grid search on dev).
pub fn best_cell(cells: &[SweepCell]) -> Option<&SweepCell> {
    cells
        .iter()
        .filter(|c| !c.diverged)
        .max_by(|a, b| a.best_dev_accuracy.partial_cmp(&b.best_dev_accuracy).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_cell_skips_divergence() {
        let cells = vec![
            SweepCell { value: 1e-3, test_accuracy: Some(0.7), best_dev_accuracy: 0.7, diverged: false, final_train_loss: 0.5 },
            SweepCell { value: 1e-2, test_accuracy: None, best_dev_accuracy: 0.9, diverged: true, final_train_loss: f64::NAN },
        ];
        assert_eq!(best_cell(&cells).unwrap().value, 1e-3);
    }

    #[test]
    fn best_cell_empty_on_all_diverged() {
        let cells = vec![SweepCell {
            value: 1.0,
            test_accuracy: None,
            best_dev_accuracy: 0.0,
            diverged: true,
            final_train_loss: f64::NAN,
        }];
        assert!(best_cell(&cells).is_none());
    }

    #[test]
    fn dp_cells_bit_identical_to_serial_cells() {
        // the ROADMAP "DP under repro/sweep" item: grid cells routed
        // through the seed-sync DP engine (workers > 1) must reproduce
        // the serial sweep bit for bit — same cells, same losses
        let rt = Runtime::native();
        let ds = crate::data::tasks::generate_sized("rte", 5, 48, 16, 16).unwrap();
        let mut serial_cfg = TrainConfig::resolve("llama_tiny", "rte", "smezo", None).unwrap();
        serial_cfg.steps = 4;
        serial_cfg.eval_every = 0;
        serial_cfg.eval_cap = 8;
        let mut dp_cfg = serial_cfg.clone();
        dp_cfg.workers = 2; // 2 divides the llama_tiny batch
        let grid = [1e-4, 3e-4];
        let pool = WorkerPool::new(2);
        let a = sweep(&rt, &pool, &serial_cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        let b = sweep(&rt, &pool, &dp_cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.value, y.value);
            assert_eq!(
                x.final_train_loss.to_bits(),
                y.final_train_loss.to_bits(),
                "lr {}",
                x.value
            );
            assert_eq!(x.diverged, y.diverged);
        }
    }

    #[test]
    fn parallel_sweep_preserves_grid_order_and_pairs_runs() {
        // two tiny cells on the native backend; results must come back in
        // grid order and a repeated sweep must be bit-identical (paired
        // seeds + shared init) — including across pool sizes
        let rt = Runtime::native();
        let ds = crate::data::tasks::generate_sized("rte", 5, 48, 16, 16).unwrap();
        let mut cfg = TrainConfig::resolve("llama_tiny", "rte", "smezo", None).unwrap();
        cfg.steps = 4;
        cfg.eval_every = 0;
        cfg.eval_cap = 8;
        let grid = [1e-4, 3e-4];
        let pool = WorkerPool::new(2);
        let serial = WorkerPool::new(0);
        let a = sweep(&rt, &pool, &cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        let b = sweep(&rt, &serial, &cfg, &ds, SweepAxis::LearningRate, &grid, None).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].value, 1e-4);
        assert_eq!(a[1].value, 3e-4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.final_train_loss.to_bits(), y.final_train_loss.to_bits());
        }
    }
}
