//! Hyperparameter sweeps — the Fig-2a learning-rate sensitivity harness
//! and the Table-10 sparsity sweep share this grid driver.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::trainer::Trainer;
use crate::data::Dataset;
use crate::runtime::Runtime;

/// Outcome of one grid cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub value: f64,
    pub test_accuracy: Option<f64>,
    pub best_dev_accuracy: f64,
    pub diverged: bool,
    pub final_train_loss: f64,
}

/// Which hyper the sweep varies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SweepAxis {
    LearningRate,
    Sparsity,
}

/// Run `base` once per grid value (shared dataset + paired seeds) and
/// collect accuracy/divergence per cell.
pub fn sweep(
    rt: &Runtime,
    base: &TrainConfig,
    dataset: &Dataset,
    axis: SweepAxis,
    grid: &[f64],
    init_params: Option<&[f32]>,
) -> Result<Vec<SweepCell>> {
    let model = rt.model(&base.model)?.clone();
    let mut cells = Vec::with_capacity(grid.len());
    for &v in grid {
        let mut cfg = base.clone();
        match axis {
            SweepAxis::LearningRate => cfg.hypers.lr = v as f32,
            SweepAxis::Sparsity => cfg.hypers.sparsity = v as f32,
        }
        crate::info!("[sweep {:?}={v}] starting ({})", axis, cfg.label());
        let mut trainer = Trainer::new(rt, cfg);
        if let Some(p) = init_params {
            trainer.initial_override = Some(p.to_vec());
        }
        let result = trainer.run_on(&model, dataset)?;
        cells.push(SweepCell {
            value: v,
            test_accuracy: result.test.map(|t| t.accuracy()),
            best_dev_accuracy: result.best_dev_accuracy(),
            diverged: result.diverged,
            final_train_loss: *result.train_losses.last().unwrap_or(&f32::NAN) as f64,
        });
    }
    Ok(cells)
}

/// Pick the best cell by dev accuracy, treating divergence as -inf
/// (the paper's model-selection protocol: grid search on dev).
pub fn best_cell(cells: &[SweepCell]) -> Option<&SweepCell> {
    cells
        .iter()
        .filter(|c| !c.diverged)
        .max_by(|a, b| a.best_dev_accuracy.partial_cmp(&b.best_dev_accuracy).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_cell_skips_divergence() {
        let cells = vec![
            SweepCell { value: 1e-3, test_accuracy: Some(0.7), best_dev_accuracy: 0.7, diverged: false, final_train_loss: 0.5 },
            SweepCell { value: 1e-2, test_accuracy: None, best_dev_accuracy: 0.9, diverged: true, final_train_loss: f64::NAN },
        ];
        assert_eq!(best_cell(&cells).unwrap().value, 1e-3);
    }

    #[test]
    fn best_cell_empty_on_all_diverged() {
        let cells = vec![SweepCell {
            value: 1.0,
            test_accuracy: None,
            best_dev_accuracy: 0.0,
            diverged: true,
            final_train_loss: f64::NAN,
        }];
        assert!(best_cell(&cells).is_none());
    }
}
