//! LoRA-family training (lora_fo, mezo_lora): same loop as [`super::trainer`]
//! but the trainable state is the adapter block and evaluation goes through
//! the `logits_lora` program (base params + adapters).
//!
//! Packed state layout (python/compile/optimizers.py, mirrored by the
//! native backend):
//!   mezo_lora: [base P | adapters A                    | metrics]
//!   lora_fo:   [base P | adapters A | m A | v A | t(1) | metrics]
//! so in both cases `TrainState.p = P` and the adapters are the first A
//! floats of the slot block.

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::coordinator::evaluator::{score_batch, EvalResult};
use crate::coordinator::trainer::{CurvePoint, TrainResult, DIVERGENCE_LOSS};
use crate::data::batcher::{eval_batches, TrainLoader};
use crate::data::Dataset;
use crate::runtime::exec::{InitExec, InitLoraExec, LogitsLoraExec, StepExec, StepMetrics, ThreshExec};
use crate::runtime::{ModelInfo, Runtime, TrainState};

/// Driver for adapter-based training runs.
pub struct LoraTrainer<'rt> {
    /// the runtime (and through it, the compute backend) to train on
    pub rt: &'rt Runtime,
    /// fully-resolved run configuration
    pub cfg: TrainConfig,
    /// base params override (pretrained checkpoint); falls back to `init`
    pub base_params: Option<Vec<f32>>,
}

impl<'rt> LoraTrainer<'rt> {
    /// A LoRA trainer with freshly-initialized base params.
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> LoraTrainer<'rt> {
        LoraTrainer { rt, cfg, base_params: None }
    }

    fn eval(
        &self,
        model: &ModelInfo,
        logits: &LogitsLoraExec,
        base: &[f32],
        adapters: &[f32],
        examples: &[crate::data::Example],
        cap: usize,
    ) -> Result<EvalResult> {
        let slice = if cap > 0 && cap < examples.len() { &examples[..cap] } else { examples };
        let mut total = EvalResult { n: 0, correct: 0, mean_loss: 0.0 };
        for batch in eval_batches(slice, model.batch, model.seq_len) {
            let lg = logits.run(self.rt, base, adapters, &batch.tokens)?;
            let r = score_batch(&lg, model.vocab, &batch);
            total.mean_loss = (total.mean_loss * total.n as f64 + r.mean_loss * r.n as f64)
                / (total.n + r.n).max(1) as f64;
            total.n += r.n;
            total.correct += r.correct;
        }
        Ok(total)
    }

    /// Run against an explicit model + dataset (paired-comparison entry
    /// point used by the experiment harness).
    pub fn run_on(&mut self, model: &ModelInfo, dataset: &Dataset) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        if cfg.optimizer != "mezo_lora" && cfg.optimizer != "lora_fo" {
            bail!("LoraTrainer only handles mezo_lora / lora_fo, got {}", cfg.optimizer);
        }
        let t_total = std::time::Instant::now();
        let a = model.n_lora_params;

        // base params: pretrained override or fresh init
        let base = match &self.base_params {
            Some(p) => p.clone(),
            None => InitExec::load(self.rt, model)?.run(self.rt, (cfg.seed as u32, 0x1717))?,
        };
        let adapters0 = InitLoraExec::load(self.rt, model)?.run(self.rt, (cfg.seed as u32, 0xada))?;

        // thresholds input exists in the step ABI even though LoRA ignores it
        let thresh = ThreshExec::load(self.rt, model)?;
        let thresholds = thresh.run(self.rt, &base, cfg.hypers.sparsity)?;
        let step_exec = StepExec::load(self.rt, model, &cfg.optimizer, cfg.hypers, &thresholds)?;
        let logits = LogitsLoraExec::load(self.rt, model)?;

        // assemble packed state: [base | adapters | extra slots zeroed | K]
        let slots_total = step_exec.slots;
        if slots_total < a {
            bail!("slot count {slots_total} < adapter count {a}");
        }
        let mut slot_block = vec![0.0f32; slots_total];
        slot_block[..a].copy_from_slice(&adapters0);
        let mut state = TrainState::from_parts(self.rt, &base, &slot_block, model.n_metrics)?;

        let mut loader = TrainLoader::new(&dataset.train, model.batch, model.seq_len, cfg.seed)?;
        let mut curve = Vec::new();
        let mut train_losses = Vec::with_capacity(cfg.steps);
        let mut ema = crate::util::stats::Ema::new(0.95);
        let mut diverged = false;
        let mut step_seconds = 0.0f64;

        for t in 0..cfg.steps {
            let batch = loader.next_batch();
            let seed = (cfg.seed as u32, t as u32);
            let sp = crate::obs::span("train.step");
            step_exec.run(self.rt, &mut state, &batch.tokens, &batch.labels, seed)?;
            let mets = StepMetrics::from_tail(&state.metrics(self.rt)?)?;
            step_seconds += sp.end();
            crate::obs::counter("train_steps_total", &[]).inc();
            let loss = mets.train_loss;
            train_losses.push(loss);
            let smoothed = ema.update(loss as f64);

            if !loss.is_finite() || loss > DIVERGENCE_LOSS {
                diverged = true;
                break;
            }
            let is_last = t + 1 == cfg.steps;
            if (cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0) || is_last {
                let adapters = state.segment_slots(self.rt, a)?;
                let dev = self.eval(model, &logits, &base, &adapters, &dataset.dev, cfg.eval_cap)?;
                curve.push(CurvePoint {
                    step: t + 1,
                    dev_accuracy: dev.accuracy(),
                    dev_loss: dev.mean_loss,
                    train_loss_ema: smoothed,
                });
                crate::info!(
                    "[{}] step {}/{} dev acc {:.3}",
                    cfg.label(),
                    t + 1,
                    cfg.steps,
                    dev.accuracy()
                );
            }
        }

        let adapters = state.segment_slots(self.rt, a)?;
        let test = if !diverged {
            Some(self.eval(model, &logits, &base, &adapters, &dataset.test, 0)?)
        } else {
            None
        };
        let steps_run = train_losses.len();
        Ok(TrainResult {
            config_label: cfg.label(),
            steps_run,
            curve,
            final_dev: None,
            test,
            diverged,
            wallclock_s: t_total.elapsed().as_secs_f64(),
            sec_per_step: step_seconds / steps_run.max(1) as f64,
            params: adapters,
            train_losses,
        })
    }
}
