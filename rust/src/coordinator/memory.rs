//! Memory accounting — the Table-4 reproduction.
//!
//! Two complementary views:
//!
//! 1. **Analytic model** parameterized by the *real* artifact layouts
//!    (param counts, batch/seq shapes, optimizer slot counts from the
//!    manifest), evaluated at both this testbed's scale and, for the
//!    paper-facing table, at LLaMA-7B scale — reproducing Table 4's
//!    FT / LoRA / MeZO / S-MeZO(vanilla) / S-MeZO-EI rows.
//! 2. **Measured accounting** of live PJRT buffer bytes held by each
//!    execution path (`TrainState::device_bytes` + transient inputs),
//!    asserting the EI path's state == MeZO's state.
//!
//! The vanilla-vs-EI distinction (paper §3.3): vanilla S-MeZO stores the
//! mask (1-bit/param after quantization) AND a perturbed parameter copy;
//! the EI path recomputes the mask inside the forward pass and perturbs
//! in place via seed replay, so it holds exactly the inference footprint.

use crate::runtime::ModelInfo;

/// Scenario constants for the activation model.
#[derive(Debug, Clone, Copy)]
pub struct MemScenario {
    /// batch size
    pub batch: usize,
    /// sequence length
    pub seq_len: usize,
    /// bytes per element of weights/activations
    pub dtype_bytes: usize,
}

/// Breakdown in bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemBreakdown {
    /// parameter bytes (incl. fp32 master copies when mixed)
    pub params: usize,
    /// gradient bytes
    pub grads: usize,
    /// optimizer slot bytes
    pub opt_slots: usize,
    /// live activation bytes
    pub activations: usize,
    /// stored-mask bytes (vanilla S-MeZO only)
    pub mask: usize,
    /// perturbed parameter copy bytes (vanilla S-MeZO only)
    pub perturbed_copy: usize,
}

impl MemBreakdown {
    /// Total bytes.
    pub fn total(&self) -> usize {
        self.params + self.grads + self.opt_slots + self.activations + self.mask + self.perturbed_copy
    }
    /// Total in GB (1e9 bytes).
    pub fn gb(&self) -> f64 {
        self.total() as f64 / 1e9
    }
}

/// Activation bytes for one forward pass kept live. For ZO methods nothing
/// is retained across layers beyond the working set of one layer; for
/// backprop every layer's activations are held for the backward pass.
fn activation_bytes(
    n_layers: usize,
    d_model: usize,
    d_ff: usize,
    sc: &MemScenario,
    retain_all_layers: bool,
) -> usize {
    // per layer: pre-norm x, q/k/v/attn-out, mlp hidden(s) ~ 4*d + 2*ff
    let per_layer = sc.batch * sc.seq_len * (4 * d_model + 2 * d_ff) * sc.dtype_bytes;
    if retain_all_layers {
        n_layers * per_layer
    } else {
        // inference working set: one layer + residual stream
        per_layer + sc.batch * sc.seq_len * d_model * sc.dtype_bytes
    }
}

/// The methods Table 4 compares.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// full fine-tuning with Adam: params + grads + 2 moment slots + full
    /// activation retention
    FullFt,
    /// LoRA: frozen params + adapter grads/slots + full activations
    Lora { adapter_params: usize },
    /// MeZO: params + inference activations (seed replay)
    Mezo,
    /// vanilla S-MeZO: MeZO + stored 1-bit mask + perturbed copy (§3.3)
    SMezoVanilla,
    /// S-MeZO efficient implementation: identical to MeZO
    SMezoEi,
}

/// Evaluate the analytic model for a transformer with `n_params` params.
pub fn breakdown(
    n_params: usize,
    n_layers: usize,
    d_model: usize,
    d_ff: usize,
    method: Method,
    sc: &MemScenario,
) -> MemBreakdown {
    let pbytes = n_params * sc.dtype_bytes;
    // Mixed-precision Adam (the paper's 7B setting, dtype < 4 bytes) keeps
    // fp32 master weights, fp32 grads and two fp32 moments; full-fp32
    // training keeps grads + two moments in the native dtype.
    let mixed = sc.dtype_bytes < 4;
    match method {
        Method::FullFt => MemBreakdown {
            params: pbytes + if mixed { 4 * n_params } else { 0 }, // + fp32 master
            grads: if mixed { 4 * n_params } else { pbytes },
            opt_slots: if mixed { 8 * n_params } else { 2 * pbytes },
            activations: activation_bytes(n_layers, d_model, d_ff, sc, true),
            ..Default::default()
        },
        Method::Lora { adapter_params } => {
            let abytes = adapter_params * sc.dtype_bytes;
            MemBreakdown {
                params: pbytes + abytes,
                grads: abytes,
                opt_slots: 2 * abytes,
                // backprop still flows through the full network
                activations: activation_bytes(n_layers, d_model, d_ff, sc, true),
                ..Default::default()
            }
        }
        Method::Mezo | Method::SMezoEi => MemBreakdown {
            params: pbytes,
            activations: activation_bytes(n_layers, d_model, d_ff, sc, false),
            ..Default::default()
        },
        Method::SMezoVanilla => MemBreakdown {
            params: pbytes,
            activations: activation_bytes(n_layers, d_model, d_ff, sc, false),
            mask: n_params / 8, // 1-bit quantized mask (paper §3.3)
            perturbed_copy: pbytes,
            ..Default::default()
        },
    }
}

/// Evaluate all Table-4 rows for a manifest model at its exported shapes.
pub fn table4_rows(model: &ModelInfo, dtype_bytes: usize) -> Vec<(&'static str, MemBreakdown)> {
    let sc = MemScenario { batch: model.batch, seq_len: model.seq_len, dtype_bytes };
    let mk = |m| breakdown(model.n_params, model.n_layers, model.d_model, model.d_ff, m, &sc);
    vec![
        ("FT", mk(Method::FullFt)),
        ("LoRA", mk(Method::Lora { adapter_params: model.n_lora_params })),
        ("MeZO", mk(Method::Mezo)),
        ("S-MeZO (vanilla)", mk(Method::SMezoVanilla)),
        ("S-MeZO-EI", mk(Method::SMezoEi)),
    ]
}

/// Host bytes of one compacted sparse adapter as the serving registry
/// ([`crate::serve::registry`]) stores it: a 1-bit/param support bitset
/// (the paper §3.3 quantized-mask representation, reused for serving)
/// plus an `(u32 index, f32 value)` pair per touched coordinate. This is
/// the figure the registry's byte-budget eviction accounts in.
pub fn sparse_adapter_bytes(n_params: usize, nnz: usize) -> usize {
    ((n_params + 63) / 64) * 8 + nnz * 8
}

/// Serving-side memory model: one resident base parameter vector shared
/// by every tenant plus the registry's compacted adapters — versus the
/// naive design of one full fine-tuned copy per tenant. The adapter
/// bytes land in the `mask` field (they are §3.3-style sparse state, not
/// parameters), so [`MemBreakdown::total`] keeps working unchanged.
pub fn serving_breakdown(n_params: usize, adapter_nnz: &[usize], dtype_bytes: usize) -> MemBreakdown {
    MemBreakdown {
        params: n_params * dtype_bytes,
        mask: adapter_nnz.iter().map(|&z| sparse_adapter_bytes(n_params, z)).sum(),
        ..Default::default()
    }
}

/// The same rows at LLaMA-7B scale (paper's actual setting, fp16/bf16,
/// batch 1 as in Table 4) — the shape check against the published numbers.
pub fn table4_rows_7b() -> Vec<(&'static str, MemBreakdown)> {
    let n_params = 6_738_415_616usize; // LLaMA-7B
    let sc = MemScenario { batch: 1, seq_len: 2048, dtype_bytes: 2 };
    let mk = |m| breakdown(n_params, 32, 4096, 11008, m, &sc);
    vec![
        ("FT", mk(Method::FullFt)),
        ("LoRA", mk(Method::Lora { adapter_params: 4_194_304 })),
        ("MeZO", mk(Method::Mezo)),
        ("S-MeZO (vanilla)", mk(Method::SMezoVanilla)),
        ("S-MeZO-EI", mk(Method::SMezoEi)),
    ]
}

// ---------------------------------------------------------------------------
// measured micro-arms (`mem-report`)
// ---------------------------------------------------------------------------

/// One `mem-report` row: a measured optimizer micro-arm next to its
/// analytic [`breakdown`] prediction for the same shapes.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredRow {
    /// method label (matches the Table-4 row names)
    pub name: &'static str,
    /// the [`crate::obs::mem::PHASES`] entry the arm accounted under
    pub phase: &'static str,
    /// heap high-water mark over the arm, bytes
    /// ([`crate::obs::mem::window_peak`]; 0 if the tracking allocator
    /// is not installed in this binary)
    pub measured_peak: u64,
    /// the analytic model's prediction at the same `n_params`
    pub analytic: MemBreakdown,
}

/// Streaming quadratic loss `0.5 * mean(p^2)` — deliberately
/// allocation-free, so an arm's heap watermark is its *optimizer state*,
/// not forward-pass scratch (the testbed analogue of the paper running
/// all methods through one identical forward).
fn probe_loss(params: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for &p in params {
        acc += p as f64 * p as f64;
    }
    (0.5 * acc / params.len().max(1) as f64) as f32
}

/// The shared step noise: the same counter-PRNG stream the real
/// trainers replay, regenerated per coordinate — never materialized.
fn probe_z(seed: (u32, u32), i: usize) -> f32 {
    crate::util::prng::normal(crate::util::prng::layer_key(seed.0, seed.1, 0), i as u32)
}

const PROBE_EPS: f32 = 1e-3;
const PROBE_LR: f32 = 1e-4;
const PROBE_SEED: u32 = 7;

fn probe_params(n: usize) -> Vec<f32> {
    // deterministic mixed-magnitude init so a fixed threshold splits the
    // coordinates into masked and unmasked on every run
    (0..n).map(|i| ((i % 17) as f32 - 8.0) / 16.0).collect()
}

/// One in-place ZO arm (MeZO when `threshold` is `None`, the S-MeZO
/// efficient implementation when `Some`): perturb via seed replay,
/// score, revert, update — the mask is recomputed per coordinate on the
/// fly, so the arm holds exactly the parameter vector (§3.4's
/// inference-level claim, minus activations). These are *memory probes*:
/// deterministic and measurement-shaped, not convergence benchmarks.
fn run_arm_in_place(n: usize, steps: usize, threshold: Option<f32>) -> f32 {
    let mut params = probe_params(n);
    let on = |p: f32| threshold.map(|th| p.abs() >= th).unwrap_or(true);
    for t in 0..steps {
        let seed = (PROBE_SEED, t as u32);
        for (i, p) in params.iter_mut().enumerate() {
            if on(*p) {
                *p += PROBE_EPS * probe_z(seed, i);
            }
        }
        let l_plus = probe_loss(&params);
        for (i, p) in params.iter_mut().enumerate() {
            if on(*p) {
                *p -= 2.0 * PROBE_EPS * probe_z(seed, i);
            }
        }
        let l_minus = probe_loss(&params);
        let g = (l_plus - l_minus) / (2.0 * PROBE_EPS);
        for (i, p) in params.iter_mut().enumerate() {
            if on(*p) {
                let z = probe_z(seed, i);
                *p += PROBE_EPS * z - PROBE_LR * g * z;
            }
        }
    }
    probe_loss(&params)
}

/// The vanilla S-MeZO arm: genuinely stores the 1-bit mask (`n/8`
/// bytes) and clones a perturbed parameter copy every step (§3.3's two
/// costs the efficient implementation eliminates) — its heap watermark
/// exceeds the in-place arms' by exactly that storage.
fn run_arm_vanilla(n: usize, steps: usize, threshold: f32) -> f32 {
    let mut params = probe_params(n);
    let mut mask = vec![0u8; n.div_ceil(8)];
    for (i, p) in params.iter().enumerate() {
        if p.abs() >= threshold {
            mask[i / 8] |= 1 << (i % 8);
        }
    }
    let on = |mask: &[u8], i: usize| mask[i / 8] >> (i % 8) & 1 == 1;
    for t in 0..steps {
        let seed = (PROBE_SEED, t as u32);
        let mut perturbed = params.clone();
        for (i, p) in perturbed.iter_mut().enumerate() {
            if on(&mask, i) {
                *p += PROBE_EPS * probe_z(seed, i);
            }
        }
        let l_plus = probe_loss(&perturbed);
        for (i, p) in perturbed.iter_mut().enumerate() {
            if on(&mask, i) {
                *p = params[i] - PROBE_EPS * probe_z(seed, i);
            }
        }
        let l_minus = probe_loss(&perturbed);
        drop(perturbed);
        let g = (l_plus - l_minus) / (2.0 * PROBE_EPS);
        for (i, p) in params.iter_mut().enumerate() {
            if on(&mask, i) {
                *p -= PROBE_LR * g * probe_z(seed, i);
            }
        }
    }
    probe_loss(&params)
}

/// Run the three matched micro-arms (MeZO, S-MeZO-EI, vanilla S-MeZO)
/// at `model`'s parameter count and measure each one's heap watermark
/// against the analytic [`breakdown`] at the same shapes — the measured
/// side of the paper's memory table. Each arm is bracketed by
/// [`crate::obs::mem::reset_watermarks`] + a fresh window so its peak is
/// its own; arms run serially on the calling thread. With the tracking
/// allocator not installed (lib unit tests), `measured_peak` is 0.
pub fn measured_rows(model: &ModelInfo, steps: usize) -> Vec<MeasuredRow> {
    use crate::obs::mem;
    let n = model.n_params;
    let sc = MemScenario { batch: model.batch, seq_len: model.seq_len, dtype_bytes: 4 };
    let mk = |m| breakdown(n, model.n_layers, model.d_model, model.d_ff, m, &sc);
    let threshold = 0.25f32;
    let mut sink = 0.0f32;
    let mut measure = |phase: &'static str, f: &mut dyn FnMut() -> f32| -> u64 {
        mem::reset_watermarks();
        let scope = mem::mem_scope(phase);
        mem::reset_window();
        sink += f();
        scope.end();
        mem::window_peak()
    };
    let rows = vec![
        MeasuredRow {
            name: "MeZO",
            phase: "report.mezo",
            measured_peak: measure("report.mezo", &mut || run_arm_in_place(n, steps, None)),
            analytic: mk(Method::Mezo),
        },
        MeasuredRow {
            name: "S-MeZO-EI",
            phase: "report.smezo",
            measured_peak: measure("report.smezo", &mut || {
                run_arm_in_place(n, steps, Some(threshold))
            }),
            analytic: mk(Method::SMezoEi),
        },
        MeasuredRow {
            name: "S-MeZO (vanilla)",
            phase: "report.smezo_vanilla",
            measured_peak: measure("report.smezo_vanilla", &mut || {
                run_arm_vanilla(n, steps, threshold)
            }),
            analytic: mk(Method::SMezoVanilla),
        },
    ];
    // keep the arms' arithmetic observable so the optimizer can't elide
    // the allocations under measurement
    assert!(sink.is_finite(), "probe arms produced a non-finite loss");
    rows
}

// ---------------------------------------------------------------------------
// resident-vs-paged matched pairs (`mem-report` paged arm)
// ---------------------------------------------------------------------------

/// One resident-vs-paged matched pair from the `mem-report` paged arm:
/// the same probe arithmetic run once over an in-scope resident `Vec`
/// and once over an in-scope file-backed [`ParamStore`](crate::runtime::store::ParamStore)
/// bounded by the page-cache budget, each under the named live phase so
/// the run also lands in `mem_peak_bytes{phase}`.
#[derive(Debug, Clone, Copy)]
pub struct PagedPair {
    /// the live [`crate::obs::mem::PHASES`] entry both twins ran under
    pub phase: &'static str,
    /// heap high-water mark of the resident twin, bytes
    pub resident_peak: u64,
    /// heap high-water mark of the paged twin, bytes
    pub paged_peak: u64,
    /// final probe loss of the resident twin
    pub resident_loss: f32,
    /// final probe loss of the paged twin — must equal
    /// `resident_loss` bit-for-bit (the tiering correctness invariant)
    pub paged_loss: f32,
    /// page faults the paged twin took (0 would mean the cache silently
    /// held everything and the comparison proved nothing)
    pub faults: u64,
    /// page evictions the paged twin took
    pub evictions: u64,
}

/// [`probe_loss`] over a store, page run by page run — the runs arrive
/// in coordinate order, so the f64 accumulation order (and thus the
/// result's bits) is identical to the flat version.
fn probe_loss_store(store: &crate::runtime::store::ParamStore) -> f32 {
    let mut acc = 0.0f64;
    store.for_runs(0, store.len(), |_, run| {
        for &p in run {
            acc += p as f64 * p as f64;
        }
    });
    (0.5 * acc / store.len().max(1) as f64) as f32
}

/// The paged twin of [`run_arm_in_place`] with a threshold: the same
/// per-coordinate expressions in the same order, expressed over page
/// runs of a file-backed store created *inside* the measurement scope,
/// so the arm's watermark is the page cache, not a parameter copy.
fn run_train_arm_paged(
    n: usize,
    steps: usize,
    threshold: f32,
    cache_bytes: usize,
) -> crate::Result<(f32, u64, u64)> {
    let mut k = 0usize;
    let store = crate::runtime::store::ParamStore::file_backed_streaming(n, cache_bytes, || {
        let v = ((k % 17) as f32 - 8.0) / 16.0; // == probe_params, streamed
        k += 1;
        v
    })?;
    let on = |p: f32| p.abs() >= threshold;
    for t in 0..steps {
        let seed = (PROBE_SEED, t as u32);
        store.update_runs(0, n, |goff, run| {
            for (j, p) in run.iter_mut().enumerate() {
                if on(*p) {
                    *p += PROBE_EPS * probe_z(seed, goff + j);
                }
            }
        });
        let l_plus = probe_loss_store(&store);
        store.update_runs(0, n, |goff, run| {
            for (j, p) in run.iter_mut().enumerate() {
                if on(*p) {
                    *p -= 2.0 * PROBE_EPS * probe_z(seed, goff + j);
                }
            }
        });
        let l_minus = probe_loss_store(&store);
        let g = (l_plus - l_minus) / (2.0 * PROBE_EPS);
        store.update_runs(0, n, |goff, run| {
            for (j, p) in run.iter_mut().enumerate() {
                if on(*p) {
                    let z = probe_z(seed, goff + j);
                    *p += PROBE_EPS * z - PROBE_LR * g * z;
                }
            }
        });
    }
    Ok((probe_loss_store(&store), store.faults(), store.evictions()))
}

/// Run the resident-vs-paged matched pairs at `model`'s parameter count
/// under the two live phases the serving and training hot paths account
/// to — `train.step` (a thresholded ZO probe arm) and `serve.batch`
/// (repeated full read passes, the forward-pass access pattern). Each
/// twin allocates its parameter storage inside its own measurement
/// scope; the paged twin streams init straight to the scratch file so
/// no resident copy ever exists. `cache_bytes` is the paged twin's LRU
/// page-cache budget.
pub fn paged_pairs(
    model: &ModelInfo,
    steps: usize,
    cache_bytes: usize,
) -> crate::Result<Vec<PagedPair>> {
    use crate::obs::mem;
    use crate::runtime::store::ParamStore;
    let n = model.n_params;
    let threshold = 0.25f32;
    let mut measure = |phase: &'static str,
                       f: &mut dyn FnMut() -> crate::Result<(f32, u64, u64)>|
     -> crate::Result<(u64, f32, u64, u64)> {
        mem::reset_watermarks();
        let scope = mem::mem_scope(phase);
        mem::reset_window();
        let (loss, faults, evictions) = f()?;
        scope.end();
        Ok((mem::window_peak(), loss, faults, evictions))
    };

    // train.step: the S-MeZO-EI probe arm, resident vs paged
    let (res_peak, res_loss, _, _) = measure("train.step", &mut || {
        Ok((run_arm_in_place(n, steps, Some(threshold)), 0, 0))
    })?;
    let (pag_peak, pag_loss, faults, evictions) =
        measure("train.step", &mut || run_train_arm_paged(n, steps, threshold, cache_bytes))?;
    let train = PagedPair {
        phase: "train.step",
        resident_peak: res_peak,
        paged_peak: pag_peak,
        resident_loss: res_loss,
        paged_loss: pag_loss,
        faults,
        evictions,
    };

    // serve.batch: read-only forward-style passes, resident vs paged
    let passes = steps.max(1);
    let (res_peak, res_loss, _, _) = measure("serve.batch", &mut || {
        let params = probe_params(n);
        let mut loss = 0.0f32;
        for _ in 0..passes {
            loss = probe_loss(&params);
        }
        Ok((loss, 0, 0))
    })?;
    let (pag_peak, pag_loss, faults, evictions) = measure("serve.batch", &mut || {
        let mut k = 0usize;
        let store = ParamStore::file_backed_streaming(n, cache_bytes, || {
            let v = ((k % 17) as f32 - 8.0) / 16.0;
            k += 1;
            v
        })?;
        let mut loss = 0.0f32;
        for _ in 0..passes {
            loss = probe_loss_store(&store);
        }
        Ok((loss, store.faults(), store.evictions()))
    })?;
    let serve = PagedPair {
        phase: "serve.batch",
        resident_peak: res_peak,
        paged_peak: pag_peak,
        resident_loss: res_loss,
        paged_loss: pag_loss,
        faults,
        evictions,
    };
    Ok(vec![train, serve])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_rows() -> Vec<(&'static str, MemBreakdown)> {
        let sc = MemScenario { batch: 16, seq_len: 32, dtype_bytes: 4 };
        [
            ("ft", Method::FullFt),
            ("lora", Method::Lora { adapter_params: 1000 }),
            ("mezo", Method::Mezo),
            ("van", Method::SMezoVanilla),
            ("ei", Method::SMezoEi),
        ]
        .into_iter()
        .map(|(n, m)| (n, breakdown(1_000_000, 4, 128, 256, m, &sc)))
        .collect()
    }

    #[test]
    fn paper_orderings_hold() {
        let rows = toy_rows();
        let get = |n: &str| rows.iter().find(|(k, _)| *k == n).unwrap().1.total();
        // Table 4's shape: FT >> LoRA > vanilla S-MeZO > MeZO == S-MeZO-EI
        assert!(get("ft") > get("lora"));
        assert!(get("lora") > get("van"));
        assert!(get("van") > get("mezo"));
        assert_eq!(get("mezo"), get("ei"));
    }

    #[test]
    fn ei_saves_roughly_param_copy() {
        let rows = toy_rows();
        let get = |n: &str| rows.iter().find(|(k, _)| *k == n).unwrap().1;
        let saved = get("van").total() - get("ei").total();
        // savings = perturbed copy (4 MB) + mask (125 KB)
        assert!(saved >= 4_000_000, "saved {saved}");
    }

    #[test]
    fn serving_n_tenants_beats_n_full_copies() {
        // N tenants at sparsity 0.75 (~25% nnz, so an adapter's
        // (idx, val) pairs cost ~53% of one full copy): shared base +
        // compact adapters undercut N full parameter copies from n=4 on
        // and approach the ~1.9x asymptotic saving as n grows
        let p = 1_000_000usize;
        let nnz = p / 4;
        for n in [4usize, 8, 32] {
            let served = serving_breakdown(p, &vec![nnz; n], 4).total();
            let naive = n * p * 4;
            assert!(served < naive, "n={n}: served {served} vs naive {naive}");
        }
        let served32 = serving_breakdown(p, &vec![nnz; 32], 4).total();
        assert!(served32 * 3 < 32 * p * 4 * 2, "asymptotic saving lost: {served32}");
        // and the per-adapter figure is dominated by the value pairs
        let one = sparse_adapter_bytes(p, nnz);
        assert!(one >= nnz * 8 && one < nnz * 8 + p / 4, "{one}");
    }

    fn toy_model() -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            family: "llama".into(),
            size: "tiny".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            d_ff: 64,
            vocab: 16,
            seq_len: 16,
            batch: 4,
            window: 0,
            n_params: 4_096,
            n_lora_params: 0,
            lora_rank: 0,
            n_entries: 0,
            n_hypers: 8,
            n_metrics: 8,
            layout: vec![],
            lora_layout: vec![],
            programs: std::collections::BTreeMap::new(),
        }
    }

    #[test]
    fn measured_rows_run_without_installed_allocator() {
        // the lib test binary has no tracking allocator, so peaks are 0
        // here — this exercises the arms' arithmetic and the analytic
        // pairing; the measured inequality is asserted in tests/obs.rs
        // where the allocator IS installed
        let rows = measured_rows(&toy_model(), 2);
        assert_eq!(rows.len(), 3);
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        assert_eq!(get("MeZO").analytic.total(), get("S-MeZO-EI").analytic.total());
        assert!(get("S-MeZO (vanilla)").analytic.total() > get("S-MeZO-EI").analytic.total());
        assert_eq!(get("S-MeZO-EI").phase, "report.smezo");
    }

    #[test]
    fn paged_pairs_bit_identical_and_faulting() {
        // a 1-byte budget rounds up to a single cached page, so every
        // run the probe touches beyond it faults; the losses must still
        // equal the resident twins' bit-for-bit
        let pairs = paged_pairs(&toy_model(), 2, 1).unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[0].phase, "train.step");
        assert_eq!(pairs[1].phase, "serve.batch");
        for p in &pairs {
            assert_eq!(
                p.resident_loss.to_bits(),
                p.paged_loss.to_bits(),
                "{}: resident {} vs paged {}",
                p.phase,
                p.resident_loss,
                p.paged_loss
            );
            assert!(p.faults >= 1, "{}: faults {}", p.phase, p.faults);
        }
    }

    #[test]
    fn seven_b_scale_matches_paper_magnitudes() {
        let rows = table4_rows_7b();
        let get = |n: &str| rows.iter().find(|(k, _)| *k == n).unwrap().1.gb();
        // paper Table 4: FT ~115-160 GB, MeZO/EI ~14.6 GB, vanilla ~28.3 GB
        let ft = get("FT");
        let mezo = get("MeZO");
        let van = get("S-MeZO (vanilla)");
        let ei = get("S-MeZO-EI");
        assert!(ft > 100.0 && ft < 170.0, "FT {ft}");
        assert!(mezo > 10.0 && mezo < 20.0, "MeZO {mezo}");
        assert!((van / mezo) > 1.8 && (van / mezo) < 2.3, "vanilla/mezo {}", van / mezo);
        assert_eq!(ei, mezo);
        // the paper's "roughly 12 times less GPU memory than FT"
        let ratio = ft / ei;
        assert!(ratio > 7.0 && ratio < 14.0, "ratio {ratio}");
    }
}
