//! Checkpointing: raw little-endian f32 params (+ optional slots) with a
//! JSON sidecar carrying the ABI fingerprint, so a checkpoint can't be
//! silently loaded into the wrong model.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::ModelInfo;
use crate::util::json::{self, Json};

/// On-disk checkpoint.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// model name the params belong to
    pub model: String,
    /// parameter count (ABI fingerprint)
    pub n_params: usize,
    /// training step the checkpoint was taken at
    pub step: usize,
    /// flat parameters
    pub params: Vec<f32>,
    /// optimizer slots (empty for ZO-SGD-family)
    pub slots: Vec<f32>,
    /// free-form provenance (task, optimizer, hypers) for reports
    pub meta: Json,
}

impl Checkpoint {
    /// Write payload + JSON sidecar (creating parent dirs).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut bytes = Vec::with_capacity(4 * (self.params.len() + self.slots.len()));
        for x in self.params.iter().chain(self.slots.iter()) {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&bytes)?;
        let sidecar = Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("n_params", Json::Num(self.n_params as f64)),
            ("n_slots", Json::Num(self.slots.len() as f64)),
            ("step", Json::Num(self.step as f64)),
            ("meta", self.meta.clone()),
        ]);
        std::fs::write(sidecar_path(path), sidecar.to_string())?;
        Ok(())
    }

    /// Best-effort load for the job orchestrator's slice-resume fast
    /// path: `Some` only when the file exists, parses, matches
    /// `expect`'s ABI **and** was taken at exactly `step`. Any mismatch
    /// — including a checkpoint that lags its step journal after a
    /// crash between the two writes — returns `None` and the caller
    /// falls back to the journal replay, which is always authoritative.
    pub fn load_if_matching(path: &Path, expect: &ModelInfo, step: usize) -> Option<Checkpoint> {
        let ck = Checkpoint::load(path, expect).ok()?;
        (ck.step == step).then_some(ck)
    }

    /// Load and validate against the expected model ABI.
    pub fn load(path: &Path, expect: &ModelInfo) -> Result<Checkpoint> {
        let sidecar = std::fs::read_to_string(sidecar_path(path))
            .with_context(|| format!("sidecar for {path:?}"))?;
        let meta = json::parse(&sidecar)?;
        let model = meta.req("model")?.as_str()?.to_string();
        let n_params = meta.req("n_params")?.as_usize()?;
        let n_slots = meta.req("n_slots")?.as_usize()?;
        let step = meta.req("step")?.as_usize()?;
        if model != expect.name {
            bail!("checkpoint is for model '{model}', expected '{}'", expect.name);
        }
        if n_params != expect.n_params {
            bail!("checkpoint has {n_params} params, model expects {}", expect.n_params);
        }
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut bytes)?;
        let want = 4 * (n_params + n_slots);
        if bytes.len() != want {
            bail!("checkpoint {path:?}: {} bytes, expected {want}", bytes.len());
        }
        let mut all = Vec::with_capacity(n_params + n_slots);
        for chunk in bytes.chunks_exact(4) {
            all.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        let slots = all.split_off(n_params);
        Ok(Checkpoint {
            model,
            n_params,
            step,
            params: all,
            slots,
            meta: meta.get("meta").cloned().unwrap_or(Json::Null),
        })
    }
}

fn sidecar_path(path: &Path) -> std::path::PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".json");
    std::path::PathBuf::from(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{LayoutEntry, ModelInfo};
    use std::collections::BTreeMap;

    fn model(n_params: usize) -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            family: "llama".into(),
            size: "tiny".into(),
            n_layers: 1,
            d_model: 4,
            n_heads: 1,
            d_ff: 8,
            vocab: 16,
            seq_len: 8,
            batch: 2,
            window: 0,
            n_params,
            n_lora_params: 0,
            lora_rank: 0,
            n_entries: 1,
            n_hypers: 8,
            n_metrics: 8,
            layout: vec![LayoutEntry {
                name: "w".into(),
                shape: vec![n_params],
                kind: "matrix".into(),
                offset: 0,
                size: n_params,
                layer_id: 0,
            }],
            lora_layout: vec![],
            programs: BTreeMap::new(),
        }
    }

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join(format!("smz_ckpt_{}", std::process::id()));
        let path = dir.join("p.bin");
        let ck = Checkpoint {
            model: "toy".into(),
            n_params: 5,
            step: 123,
            params: vec![1.0, -2.0, 3.5, 0.0, 1e-8],
            slots: vec![9.0, 8.0],
            meta: Json::obj(vec![("task", Json::Str("rte".into()))]),
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path, &model(5)).unwrap();
        assert_eq!(back.params, ck.params);
        assert_eq!(back.slots, ck.slots);
        assert_eq!(back.step, 123);
        assert_eq!(back.meta.req("task").unwrap().as_str().unwrap(), "rte");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_model() {
        let dir = std::env::temp_dir().join(format!("smz_ckpt2_{}", std::process::id()));
        let path = dir.join("p.bin");
        let ck = Checkpoint {
            model: "toy".into(),
            n_params: 3,
            step: 0,
            params: vec![1.0, 2.0, 3.0],
            slots: vec![],
            meta: Json::Null,
        };
        ck.save(&path).unwrap();
        // wrong param count
        assert!(Checkpoint::load(&path, &model(4)).is_err());
        // truncated payload
        std::fs::write(&path, [0u8; 5]).unwrap();
        assert!(Checkpoint::load(&path, &model(3)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
