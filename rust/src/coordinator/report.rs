//! Report rendering: paper-style markdown tables + curve CSVs.
//!
//! Every repro subcommand funnels its numbers through here so results/
//! contains a uniform set of `tableN.md` / `figN.csv` files that
//! EXPERIMENTS.md references.

use std::path::Path;

use anyhow::Result;

/// A markdown table builder with right-aligned numeric cells.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with a title + header row.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (width-checked against the header).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as an aligned markdown table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out.push('\n');
        out
    }

    /// Render to a file (creating parent dirs).
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())?;
        crate::info!("wrote {}", path.display());
        Ok(())
    }
}

/// Format an accuracy as the paper does (xx.x, percent).
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Accuracy with a delta annotation against a baseline, paper-style:
/// "80.7 (+9.0)".
pub fn pct_delta(x: f64, baseline: f64) -> String {
    let d = 100.0 * (x - baseline);
    if d.abs() < 0.05 {
        pct(x)
    } else {
        format!("{} ({}{:.1})", pct(x), if d > 0.0 { "+" } else { "" }, d)
    }
}

/// Write a CSV of (x, series...) rows for figures.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        let cells: Vec<String> = row
            .iter()
            .map(|v| if v.is_finite() { format!("{v}") } else { String::new() })
            .collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    crate::info!("wrote {}", path.display());
    Ok(())
}

/// Render a figure as an ASCII sparkline block (terminal-friendly "plot").
pub fn ascii_curve(title: &str, series: &[(&str, Vec<(f64, f64)>)], width: usize, height: usize) -> String {
    let mut out = format!("{title}\n");
    let (mut xmin, mut xmax, mut ymin, mut ymax) =
        (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
    for (_, pts) in series {
        for &(x, y) in pts {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !xmin.is_finite() || xmax <= xmin {
        return out;
    }
    if ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['o', 'x', '+', '*', '#', '@'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts {
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    out.push_str(&format!("  y: [{ymin:.3}, {ymax:.3}]\n"));
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("   x: [{xmin:.0}, {xmax:.0}]  legend: "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Test", &["Method", "RTE"]);
        t.row(vec!["MeZO".into(), "71.7".into()]);
        t.row(vec!["S-MeZO".into(), "80.7 (+9.0)".into()]);
        let s = t.render();
        assert!(s.contains("### Test"));
        assert!(s.contains("| S-MeZO"));
        // all data lines have the same width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.807), "80.7");
        assert_eq!(pct_delta(0.807, 0.717), "80.7 (+9.0)");
        assert_eq!(pct_delta(0.5, 0.5), "50.0");
        assert!(pct_delta(0.5, 0.6).contains("-10.0"));
    }

    #[test]
    fn ascii_curve_renders() {
        let s = ascii_curve(
            "fig",
            &[("a", vec![(0.0, 0.5), (100.0, 0.8)]), ("b", vec![(0.0, 0.5), (100.0, 0.6)])],
            40,
            8,
        );
        assert!(s.contains('o') && s.contains('x'));
    }
}
