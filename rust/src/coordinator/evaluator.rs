//! Evaluation: candidate-scored accuracy + cross-entropy from last-position
//! logits (the MeZO protocol: the prediction is the argmax over the
//! example's candidate answer tokens, not the full vocabulary).
//!
//! [`evaluate`] is the serial reference implementation;
//! [`evaluate_sharded`](crate::parallel::eval::evaluate_sharded) splits
//! the same batches across the worker pool and folds the per-batch
//! results with the same running-mean formula in batch order, so both
//! return bit-identical numbers. The trainer picks per its `pool` field;
//! keep the fold formulas in lockstep if either changes.

use anyhow::Result;

use crate::data::batcher::{eval_batches, Batch};
use crate::data::Example;
use crate::runtime::exec::LogitsExec;
use crate::runtime::Runtime;

/// Result of one evaluation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    /// examples scored
    pub n: usize,
    /// correct candidate-restricted predictions
    pub correct: usize,
    /// mean cross-entropy of the gold answer token
    pub mean_loss: f64,
}

impl EvalResult {
    /// Fraction correct (0 when nothing was scored).
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.n.max(1) as f64
    }
}

/// log-softmax cross-entropy of `label` under `logits` (one row).
pub fn row_loss(logits: &[f32], label: i32) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&l| ((l as f64) - max).exp()).sum::<f64>().ln() + max;
    lse - logits[label as usize] as f64
}

/// Candidate-restricted argmax prediction for one row.
pub fn row_prediction(logits: &[f32], candidates: &[i32]) -> i32 {
    *candidates
        .iter()
        .max_by(|&&a, &&b| logits[a as usize].partial_cmp(&logits[b as usize]).unwrap())
        .expect("non-empty candidates")
}

/// Score a batch of logits rows against the batch's labels/candidates.
pub fn score_batch(logits: &[f32], vocab: usize, batch: &Batch) -> EvalResult {
    let mut correct = 0usize;
    let mut loss = 0.0f64;
    for row in 0..batch.real {
        let lg = &logits[row * vocab..(row + 1) * vocab];
        if row_prediction(lg, &batch.candidates[row]) == batch.labels[row] {
            correct += 1;
        }
        loss += row_loss(lg, batch.labels[row]);
    }
    EvalResult { n: batch.real, correct, mean_loss: loss / batch.real.max(1) as f64 }
}

/// Evaluate `params` over `examples` (optionally capped for speed).
pub fn evaluate(
    rt: &Runtime,
    logits: &LogitsExec,
    params: &[f32],
    examples: &[Example],
    cap: usize,
) -> Result<EvalResult> {
    let slice = if cap > 0 && cap < examples.len() { &examples[..cap] } else { examples };
    let mut total = EvalResult { n: 0, correct: 0, mean_loss: 0.0 };
    for batch in eval_batches(slice, logits.batch, logits.seq_len) {
        let lg = logits.run(rt, params, &batch.tokens)?;
        let r = score_batch(&lg, logits.vocab, &batch);
        total.mean_loss = (total.mean_loss * total.n as f64 + r.mean_loss * r.n as f64)
            / (total.n + r.n).max(1) as f64;
        total.n += r.n;
        total.correct += r.correct;
    }
    Ok(total)
}

/// Mean training-style loss of `params` on an explicit token/label batch
/// (used by the Fig-2b probe, which needs loss-at-theta without a step).
pub fn batch_loss(
    rt: &Runtime,
    logits: &LogitsExec,
    params: &[f32],
    batch: &Batch,
) -> Result<f64> {
    let lg = logits.run(rt, params, &batch.tokens)?;
    let mut loss = 0.0;
    for row in 0..batch.real {
        loss += row_loss(&lg[row * logits.vocab..(row + 1) * logits.vocab], batch.labels[row]);
    }
    Ok(loss / batch.real.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_prediction_restricted_to_candidates() {
        // vocab of 6; token 5 has the max logit but is not a candidate
        let logits = [0.0, 1.0, 0.5, -1.0, 0.0, 9.0];
        assert_eq!(row_prediction(&logits, &[1, 2]), 1);
        assert_eq!(row_prediction(&logits, &[3, 4]), 4);
    }

    #[test]
    fn row_loss_matches_manual_softmax() {
        let logits = [1.0f32, 2.0, 3.0];
        let l = row_loss(&logits, 2);
        let z: f64 = (1f64.exp() + 2f64.exp() + 3f64.exp()).ln();
        assert!((l - (z - 3.0)).abs() < 1e-9);
    }

    #[test]
    fn score_batch_respects_real() {
        let batch = Batch {
            tokens: vec![0; 2 * 4],
            labels: vec![1, 2],
            real: 1, // second row is padding
            candidates: vec![vec![1, 2], vec![1, 2]],
        };
        // row0 predicts 1 (correct); row1 would predict 2 but must be ignored
        let logits = vec![
            0.0, 5.0, 1.0, 0.0, // row 0
            0.0, 1.0, 5.0, 0.0, // row 1
        ];
        let r = score_batch(&logits, 4, &batch);
        assert_eq!(r.n, 1);
        assert_eq!(r.correct, 1);
    }

    #[test]
    fn eval_result_accuracy() {
        let r = EvalResult { n: 10, correct: 7, mean_loss: 0.0 };
        assert!((r.accuracy() - 0.7).abs() < 1e-12);
    }
}
