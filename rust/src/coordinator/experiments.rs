//! The repro harness: one function per paper table/figure (DESIGN.md §4).
//!
//! Every experiment writes a markdown table and/or CSV under `--out`
//! (default `results/`) and returns its headline-shape verdicts, which
//! EXPERIMENTS.md aggregates. Scale knobs (`steps`, `seeds`, model) default
//! to CPU-feasible values; the full-scale settings are documented per
//! experiment in EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::{presets, TrainConfig};
use crate::coordinator::convergence;
use crate::coordinator::lora::LoraTrainer;
use crate::coordinator::memory;
use crate::coordinator::pretrain::{pretrained_params, PretrainConfig};
use crate::coordinator::probe;
use crate::coordinator::report::{ascii_curve, pct, pct_delta, write_csv, Table};
use crate::coordinator::sweep::{self, SweepAxis};
use crate::coordinator::trainer::{in_context, zero_shot, TrainResult, Trainer};
use crate::data::{tasks, Dataset};
use crate::parallel::WorkerPool;
use crate::runtime::exec::Hypers;
use crate::runtime::Runtime;

/// Dataset seed shared by every repro table/figure. This is the single
/// source for both execution paths: the in-process sweeps generate
/// their datasets from it, and `--via-queue` grid cells pin it as
/// their `data_seed` — one constant, so the two paths can never
/// silently train on different batches.
pub const DATASET_SEED: u64 = 1234;

/// Shared experiment context: runtime, output dir, scale knobs.
pub struct Ctx<'rt> {
    /// runtime to execute on
    pub rt: &'rt Runtime,
    /// output directory for tables/CSVs
    pub out: PathBuf,
    /// ZO training steps per run
    pub zo_steps: usize,
    /// first-order training steps per run
    pub fo_steps: usize,
    /// dev-eval cadence
    pub eval_every: usize,
    /// dev examples per eval (0 = all 500)
    pub eval_cap: usize,
    /// seeds averaged per cell (paper uses 3)
    pub seeds: Vec<u64>,
    /// pretraining steps for the shared base checkpoints
    pub pretrain_steps: usize,
    /// checkpoint cache dir
    pub ckpt_dir: PathBuf,
    /// shared worker pool: sweep cells and sharded evals schedule here
    pub pool: WorkerPool,
    /// route sweep-driven tables through the persistent job queue in
    /// this directory (`repro --via-queue DIR`): each grid survives
    /// kills and resumes from its cells' step journals, bit-identical
    /// to the in-process sweep. `None` = run in-process.
    pub via_queue: Option<PathBuf>,
    /// artifact directory (used to stand up the queue-drain engine's
    /// runtime in `--via-queue` mode)
    pub artifacts: PathBuf,
}

impl<'rt> Ctx<'rt> {
    /// Context with CPU-feasible default scale knobs.
    pub fn new(rt: &'rt Runtime, out: PathBuf) -> Ctx<'rt> {
        Ctx {
            rt,
            out,
            zo_steps: 4000,
            fo_steps: 1000,
            eval_every: 500,
            eval_cap: 150,
            seeds: vec![17],
            pretrain_steps: 3000,
            ckpt_dir: PathBuf::from("checkpoints"),
            pool: WorkerPool::new(WorkerPool::default_size()),
            via_queue: None,
            artifacts: PathBuf::from("artifacts"),
        }
    }

    /// One axis grid for a repro table: in-process on the shared pool
    /// by default, or through the persistent job queue when
    /// `--via-queue` is set — the cells become grid-job children that
    /// survive kills and resume from their journals, with bit-identical
    /// per-cell results ([`sweep::sweep_via_queue`]).
    fn sweep_cells(
        &self,
        cfg: &TrainConfig,
        dataset: &Dataset,
        axis: SweepAxis,
        grid: &[f64],
        init: &[f32],
        grid_name: &str,
    ) -> Result<Vec<sweep::SweepCell>> {
        match &self.via_queue {
            None => sweep::sweep(self.rt, &self.pool, cfg, dataset, axis, grid, Some(init)),
            Some(dir) => {
                let engine_rt = Runtime::new(&self.artifacts)?;
                sweep::sweep_via_queue(
                    self.rt,
                    engine_rt,
                    cfg,
                    axis,
                    grid,
                    init,
                    dir,
                    grid_name,
                    DATASET_SEED,
                )
            }
        }
    }

    fn base(&self, model: &str) -> Result<Vec<f32>> {
        pretrained_params(
            self.rt,
            model,
            &self.ckpt_dir,
            Some(PretrainConfig {
                model: model.to_string(),
                steps: self.pretrain_steps,
                ..Default::default()
            }),
        )
    }

    /// Train `optimizer` on `dataset` from `base` params; mean test
    /// accuracy over seeds (and the last run's curve for figures).
    fn run_method(
        &self,
        model: &str,
        dataset: &Dataset,
        optimizer: &str,
        base: &[f32],
        hypers_override: Option<Hypers>,
    ) -> Result<(f64, TrainResult)> {
        let mut accs = Vec::new();
        let mut last: Option<TrainResult> = None;
        for &seed in &self.seeds {
            let mut cfg = TrainConfig::resolve(model, &dataset.task, optimizer, None)?;
            if let Some(h) = hypers_override {
                cfg.hypers = h;
            }
            cfg.seed = seed;
            cfg.steps = if presets::is_zeroth_order(optimizer) { self.zo_steps } else { self.fo_steps };
            cfg.eval_every = self.eval_every;
            cfg.eval_cap = self.eval_cap;
            let model_info = self.rt.model(model)?.clone();
            let result = if optimizer == "mezo_lora" || optimizer == "lora_fo" {
                let mut t = LoraTrainer::new(self.rt, cfg);
                t.base_params = Some(base.to_vec());
                t.run_on(&model_info, dataset)?
            } else {
                let mut t = Trainer::new(self.rt, cfg);
                t.initial_override = Some(base.to_vec());
                t.run_on(&model_info, dataset)?
            };
            accs.push(result.test.map(|t| t.accuracy()).unwrap_or(0.0));
            last = Some(result);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        Ok((mean, last.unwrap()))
    }

    fn datasets(&self, names: &[&str]) -> Result<Vec<Dataset>> {
        names.iter().map(|t| tasks::generate(t, DATASET_SEED)).collect()
    }
}

// ---------------------------------------------------------------------------
// Tables 1 / 2 / 11 / 13 share one "methods x tasks" grid driver
// ---------------------------------------------------------------------------

fn method_task_grid(
    ctx: &Ctx,
    model: &str,
    task_names: &[&str],
    methods: &[&str],
    title: &str,
    out_name: &str,
) -> Result<BTreeMap<(String, String), f64>> {
    let base = ctx.base(model)?;
    let datasets = ctx.datasets(task_names)?;
    let mut accs: BTreeMap<(String, String), f64> = BTreeMap::new();

    for ds in &datasets {
        for &m in methods {
            let acc = match m {
                "zero_shot" => zero_shot(ctx.rt, model, ds, &base, 0)?.accuracy(),
                "icl" => in_context(ctx.rt, model, ds, &base, 4, 0)?.accuracy(),
                _ => ctx.run_method(model, ds, m, &base, None)?.0,
            };
            crate::info!("[{title}] {} on {}: {:.3}", m, ds.task, acc);
            accs.insert((m.to_string(), ds.task.clone()), acc);
        }
    }

    // render paper-style table with deltas vs MeZO for S-MeZO rows
    let mut header: Vec<&str> = vec!["Method"];
    header.extend(task_names.iter());
    header.push("Average");
    let mut table = Table::new(title, &header);
    for &m in methods {
        let label = match m {
            "zero_shot" => "Zero-Shot".to_string(),
            "icl" => "ICL".to_string(),
            other => presets::display_name(other).to_string(),
        };
        let mut cells = vec![label];
        let mut sum = 0.0;
        for &t in task_names {
            let a = accs[&(m.to_string(), t.to_string())];
            sum += a;
            if m == "smezo" {
                let mezo = accs
                    .get(&("mezo".to_string(), t.to_string()))
                    .copied()
                    .unwrap_or(a);
                cells.push(pct_delta(a, mezo));
            } else {
                cells.push(pct(a));
            }
        }
        cells.push(pct(sum / task_names.len() as f64));
        table.row(cells);
    }
    table.write(&ctx.out.join(out_name))?;
    Ok(accs)
}

/// Table 1/12: the main SuperGLUE grid.
pub fn table1(ctx: &Ctx, model: &str) -> Result<()> {
    method_task_grid(
        ctx,
        model,
        &["boolq", "rte", "wic", "multirc", "sst2", "copa"],
        &["zero_shot", "icl", "lora_fo", "fo_adam", "mezo", "mezo_lora", "rmezo", "smezo"],
        &format!("Table 1 — Accuracy of fine-tuning {model} on SuperGLUE analogs"),
        "table1.md",
    )?;
    Ok(())
}

/// Table 2: the ZO-variant zoo.
pub fn table2(ctx: &Ctx, model: &str) -> Result<()> {
    method_task_grid(
        ctx,
        model,
        &["boolq", "rte", "wic", "sst2"],
        &[
            "lora_fo", "mezo", "mezo_lora", "zo_sign", "zo_cons", "zo_adam",
            "zo_adamu", "zo_mom", "rmezo", "smezo",
        ],
        &format!("Table 2 — ZO-variant comparison on {model}"),
        "table2.md",
    )?;
    Ok(())
}

/// Table 3: harder tasks on the Mistral-family model.
pub fn table3(ctx: &Ctx) -> Result<()> {
    method_task_grid(
        ctx,
        "mistral_small",
        &["boolq", "piqa", "siqa", "aqua"],
        &["mezo", "smezo"],
        "Table 3 — Mistral-family on commonsense/math analogs",
        "table3.md",
    )?;
    Ok(())
}

/// Table 11: Mistral SuperGLUE grid.
pub fn table11(ctx: &Ctx) -> Result<()> {
    method_task_grid(
        ctx,
        "mistral_small",
        &["boolq", "rte", "wic", "multirc", "sst2", "copa"],
        &["zero_shot", "icl", "lora_fo", "fo_adam", "mezo", "mezo_lora", "rmezo", "smezo"],
        "Table 11 — Mistral-family on SuperGLUE analogs",
        "table11.md",
    )?;
    Ok(())
}

/// Table 13: OPT-family, three tasks, ZO methods.
pub fn table13(ctx: &Ctx) -> Result<()> {
    method_task_grid(
        ctx,
        "opt_small",
        &["boolq", "rte", "wic"],
        &["zero_shot", "mezo", "rmezo", "smezo"],
        "Table 13 — OPT-family on SuperGLUE analogs",
        "table13.md",
    )?;
    Ok(())
}

/// Table 5: scale axis (tiny vs med, MeZO vs S-MeZO).
pub fn table5(ctx: &Ctx) -> Result<()> {
    let task_names = ["boolq", "rte", "wic"];
    let mut table = Table::new(
        "Table 5 — Scaling: llama_tiny vs llama_med",
        &["Model", "Method", "boolq", "rte", "wic"],
    );
    for model in ["llama_tiny", "llama_med"] {
        let base = ctx.base(model)?;
        let datasets = ctx.datasets(&task_names)?;
        for m in ["mezo", "smezo"] {
            let mut cells = vec![model.to_string(), presets::display_name(m).to_string()];
            for ds in &datasets {
                let (acc, _) = ctx.run_method(model, ds, m, &base, None)?;
                crate::info!("[table5] {model}/{m}/{}: {acc:.3}", ds.task);
                cells.push(pct(acc));
            }
            table.row(cells);
        }
    }
    table.write(&ctx.out.join("table5.md"))?;
    Ok(())
}

/// Table 10: sparsity sweep for S-MeZO.
pub fn table10(ctx: &Ctx, model: &str) -> Result<()> {
    let base = ctx.base(model)?;
    // S-MeZO sparsity grid; the MeZO column is a separate run at MeZO's
    // OWN calibrated LR (running sparsity=0 at S-MeZO's larger LR would
    // just reproduce the Fig-2a divergence, not the paper's baseline).
    let grid = [0.5, 0.6, 0.7, 0.8];
    let task_names = ["rte", "boolq", "wic"];
    let mut header = vec!["Task".to_string(), "MeZO".to_string()];
    header.extend(grid.iter().map(|s| format!("r={s}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("Table 10 — Effect of sparsity (S-MeZO on {model})"),
        &header_refs,
    );
    for t in task_names {
        let ds = tasks::generate(t, DATASET_SEED)?;
        let (mezo_acc, _) = ctx.run_method(model, &ds, "mezo", &base, None)?;
        let mut cfg = TrainConfig::resolve(model, t, "smezo", None)?;
        cfg.steps = ctx.zo_steps;
        cfg.eval_every = ctx.eval_every;
        cfg.eval_cap = ctx.eval_cap;
        cfg.seed = ctx.seeds[0];
        let cells_res = ctx.sweep_cells(
            &cfg,
            &ds,
            SweepAxis::Sparsity,
            &grid.to_vec(),
            &base,
            &format!("repro-table10-{model}-{t}"),
        )?;
        let mut cells = vec![t.to_string()];
        cells.push(pct(mezo_acc));
        for c in cells_res.iter() {
            cells.push(pct_delta(c.test_accuracy.unwrap_or(0.0), mezo_acc));
        }
        table.row(cells);
    }
    table.write(&ctx.out.join("table10.md"))?;
    Ok(())
}

/// Table 4: memory usage (analytic 7B scale + this-testbed scale +
/// measured live state bytes).
pub fn table4(ctx: &Ctx, model: &str) -> Result<()> {
    let info = ctx.rt.model(model)?.clone();

    let mut t7 = Table::new(
        "Table 4 — Memory (analytic, LLaMA-7B scale, GB; paper setting)",
        &["Method", "Params", "Grads", "OptSlots", "Activations", "Mask", "PerturbCopy", "Total GB"],
    );
    for (name, b) in memory::table4_rows_7b() {
        t7.row(vec![
            name.to_string(),
            format!("{:.1}", b.params as f64 / 1e9),
            format!("{:.1}", b.grads as f64 / 1e9),
            format!("{:.1}", b.opt_slots as f64 / 1e9),
            format!("{:.1}", b.activations as f64 / 1e9),
            format!("{:.3}", b.mask as f64 / 1e9),
            format!("{:.1}", b.perturbed_copy as f64 / 1e9),
            format!("{:.1}", b.gb()),
        ]);
    }
    t7.write(&ctx.out.join("table4_7b.md"))?;

    let mut tl = Table::new(
        &format!("Table 4 — Memory (analytic, {model} as exported, MB)"),
        &["Method", "Total MB"],
    );
    for (name, b) in memory::table4_rows(&info, 4) {
        tl.row(vec![name.to_string(), format!("{:.2}", b.total() as f64 / 1e6)]);
    }

    // measured: live packed-state bytes per optimizer (the EI claim —
    // smezo's training state is byte-identical in size to mezo's)
    let mut measured = Table::new(
        &format!("Table 4 (measured) — live device state bytes, {model}"),
        &["Optimizer", "State floats", "Bytes"],
    );
    for opt in ["mezo", "smezo", "smezo_const", "zo_adam", "fo_adam"] {
        if let Ok(prog) = info.step_program(opt) {
            let state_len = prog.state_len.unwrap_or(0);
            measured.row(vec![
                presets::display_name(opt).to_string(),
                format!("{state_len}"),
                format!("{}", state_len * 4),
            ]);
        }
    }
    let mezo_len = info.step_program("mezo")?.state_len.unwrap_or(0);
    let smezo_len = info.step_program("smezo")?.state_len.unwrap_or(0);
    if mezo_len != smezo_len {
        bail!("EI violation: smezo state {smezo_len} != mezo state {mezo_len}");
    }
    let mut out = tl.render();
    out.push_str(&measured.render());
    out.push_str(&format!(
        "\nEI check: S-MeZO packed state == MeZO packed state == {} floats \
         (dynamic mask is recomputed inside the step; nothing stored). \
         The 'const mask' ablation stores the mask and pays {} extra floats.\n",
        mezo_len,
        info.step_program("smezo_const").map(|p| p.state_len.unwrap_or(0) - mezo_len).unwrap_or(0),
    ));
    std::fs::create_dir_all(&ctx.out)?;
    std::fs::write(ctx.out.join("table4.md"), out)?;
    crate::info!("wrote {}", ctx.out.join("table4.md").display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Fig 1 + Fig 3: convergence curves MeZO vs S-MeZO (vs R-MeZO) and the
/// steps-to-accuracy speedup.
pub fn fig13(ctx: &Ctx, model: &str, task_names: &[&str], out_name: &str) -> Result<()> {
    let base = ctx.base(model)?;
    let mut summary = Table::new(
        &format!("Fig 1/3 — convergence speedup on {model}"),
        &["Task", "MeZO best", "S-MeZO best", "target", "MeZO steps", "S-MeZO steps", "speedup"],
    );
    for &t in task_names {
        let ds = tasks::generate(t, DATASET_SEED)?;
        let (_, mezo) = ctx.run_method(model, &ds, "mezo", &base, None)?;
        let (_, smezo) = ctx.run_method(model, &ds, "smezo", &base, None)?;
        // CSV of both curves
        let mut rows = Vec::new();
        for c in &mezo.curve {
            rows.push(vec![c.step as f64, c.dev_accuracy, f64::NAN]);
        }
        for c in &smezo.curve {
            rows.push(vec![c.step as f64, f64::NAN, c.dev_accuracy]);
        }
        write_csv(
            &ctx.out.join(format!("{out_name}_{t}.csv")),
            &["step", "mezo_acc", "smezo_acc"],
            &rows,
        )?;
        let spd = convergence::speedup(&mezo.curve, &smezo.curve);
        let (target, ms, ss, ratio) = spd.unwrap_or((0.0, 0, 0, f64::NAN));
        summary.row(vec![
            t.to_string(),
            pct(mezo.best_dev_accuracy()),
            pct(smezo.best_dev_accuracy()),
            pct(target),
            format!("{ms}"),
            format!("{ss}"),
            format!("{ratio:.2}x"),
        ]);
        let plot = ascii_curve(
            &format!("dev accuracy vs steps — {t}"),
            &[
                ("mezo", mezo.curve.iter().map(|c| (c.step as f64, c.dev_accuracy)).collect()),
                ("smezo", smezo.curve.iter().map(|c| (c.step as f64, c.dev_accuracy)).collect()),
            ],
            64,
            12,
        );
        println!("{plot}");
    }
    summary.write(&ctx.out.join(format!("{out_name}.md")))?;
    Ok(())
}

/// Fig 2a: LR sensitivity — MeZO vs S-MeZO over the LR grid.
pub fn fig2a(ctx: &Ctx, model: &str, task: &str) -> Result<()> {
    let base = ctx.base(model)?;
    let ds = tasks::generate(task, DATASET_SEED)?;
    let grid: Vec<f64> = presets::ZO_LR_GRID.iter().map(|&x| x as f64).collect();
    let mut rows = Vec::new();
    let mut table = Table::new(
        &format!("Fig 2a — LR sensitivity on {task} ({model})"),
        &["lr", "MeZO acc", "MeZO diverged", "S-MeZO acc", "S-MeZO diverged"],
    );
    for opt in ["mezo", "smezo"] {
        let mut cfg = TrainConfig::resolve(model, task, opt, None)?;
        cfg.steps = ctx.zo_steps;
        cfg.eval_every = ctx.eval_every;
        cfg.eval_cap = ctx.eval_cap;
        cfg.seed = ctx.seeds[0];
        let cells = ctx.sweep_cells(
            &cfg,
            &ds,
            SweepAxis::LearningRate,
            &grid,
            &base,
            &format!("repro-fig2a-{model}-{task}-{opt}"),
        )?;
        for (i, c) in cells.iter().enumerate() {
            if rows.len() <= i {
                rows.push(vec![c.value, f64::NAN, 0.0, f64::NAN, 0.0]);
            }
            let (acc_col, div_col) = if opt == "mezo" { (1, 2) } else { (3, 4) };
            rows[i][acc_col] = c.test_accuracy.unwrap_or(f64::NAN);
            rows[i][div_col] = if c.diverged { 1.0 } else { 0.0 };
        }
    }
    for r in &rows {
        table.row(vec![
            format!("{:.0e}", r[0]),
            if r[1].is_finite() { pct(r[1]) } else { "—".into() },
            if r[2] > 0.0 { "DIVERGED".into() } else { "".into() },
            if r[3].is_finite() { pct(r[3]) } else { "—".into() },
            if r[4] > 0.0 { "DIVERGED".into() } else { "".into() },
        ]);
    }
    table.write(&ctx.out.join("fig2a.md"))?;
    write_csv(
        &ctx.out.join("fig2a.csv"),
        &["lr", "mezo_acc", "mezo_diverged", "smezo_acc", "smezo_diverged"],
        &rows,
    )?;
    Ok(())
}

/// Fig 2b + Fig 4: half-batch generalization probes (MeZO vs SGD).
pub fn fig2b4(ctx: &Ctx, model: &str, task: &str, steps: usize) -> Result<()> {
    let base = ctx.base(model)?;
    let ds = tasks::generate(task, DATASET_SEED)?;
    let window = (steps / 6).max(1);
    let mut rows = Vec::new();
    let mut table = Table::new(
        &format!("Fig 2b/4 — P(loss increase) on {task} ({model}, {steps} probe steps)"),
        &["Estimator", "P(up | same batch)", "P(up | held-out)", "95% CI held-out"],
    );
    for opt in ["mezo", "fo_sgd"] {
        let mut cfg = TrainConfig::resolve(model, task, opt, None)?;
        cfg.seed = ctx.seeds[0];
        if opt == "fo_sgd" {
            // probe in the small-step regime where the sign of the held-out
            // loss change reflects the DIRECTION's generalization (at the
            // training LR every single-batch step overfits its batch and
            // the contrast washes out — see EXPERIMENTS.md)
            cfg.hypers.lr = 1e-3;
        }
        let res = probe::half_batch_probe(ctx.rt, &cfg, &ds, &base, steps, window)?;
        for w in &res.windows {
            rows.push(vec![
                if opt == "mezo" { 0.0 } else { 1.0 },
                w.window as f64,
                w.p_up_same(),
                w.p_up_held(),
            ]);
        }
        let overall_held = res.overall_up_held();
        let (lo, hi) = crate::util::stats::wilson_interval(
            res.windows.iter().map(|w| w.up_held).sum(),
            res.windows.iter().map(|w| w.n).sum(),
            1.96,
        );
        table.row(vec![
            if opt == "mezo" { "MeZO (ZO)".into() } else { "SGD (exact)".to_string() },
            format!("{:.2}", res.overall_up_same()),
            format!("{overall_held:.2}"),
            format!("[{lo:.2}, {hi:.2}]"),
        ]);
    }
    table.write(&ctx.out.join("fig2b_fig4.md"))?;
    write_csv(
        &ctx.out.join("fig4.csv"),
        &["is_sgd", "window", "p_up_same", "p_up_held"],
        &rows,
    )?;
    Ok(())
}

/// Fig 2c: from a MeZO-trained point, branch into small-mask / large-mask /
/// dense continuations.
pub fn fig2c(ctx: &Ctx, model: &str, task: &str) -> Result<()> {
    let base = ctx.base(model)?;
    let ds = tasks::generate(task, DATASET_SEED)?;

    // phase 1: MeZO at an aggressive LR to manufacture the accuracy drop
    let mut cfg = TrainConfig::resolve(model, task, "mezo", None)?;
    cfg.hypers.lr *= 2.0;
    cfg.steps = ctx.zo_steps / 2;
    cfg.eval_every = ctx.eval_every;
    cfg.eval_cap = ctx.eval_cap;
    cfg.seed = ctx.seeds[0];
    let model_info = ctx.rt.model(model)?.clone();
    let mut t = Trainer::new(ctx.rt, cfg.clone());
    t.initial_override = Some(base.clone());
    let phase1 = t.run_on(&model_info, &ds)?;
    let drop_params = phase1.params.clone();
    crate::info!("[fig2c] phase-1 MeZO best dev {:.3}", phase1.best_dev_accuracy());

    // phase 2: branch
    let mut series: Vec<(&str, Vec<(f64, f64)>)> = Vec::new();
    let mut rows = Vec::new();
    let mut table = Table::new(
        &format!("Fig 2c — continuing from the drop point on {task}"),
        &["Continuation", "best dev acc", "final dev acc"],
    );
    let offset = phase1.steps_run;
    let mut all_curves = Vec::new();
    for (label, opt) in [
        ("small weights (S-MeZO)", "smezo"),
        ("large weights only", "smezo_large"),
        ("all weights (MeZO)", "mezo"),
    ] {
        let mut cfg2 = TrainConfig::resolve(model, task, opt, None)?;
        // paired comparison: every continuation arm uses the SAME LR
        // (phase 1's aggressive setting), so the outcome isolates WHICH
        // weights are updated — the paper's Fig-2c design
        cfg2.hypers.lr = cfg.hypers.lr;
        cfg2.steps = ctx.zo_steps / 2;
        cfg2.eval_every = ctx.eval_every;
        cfg2.eval_cap = ctx.eval_cap;
        cfg2.seed = ctx.seeds[0] + 1;
        let mut t2 = Trainer::new(ctx.rt, cfg2);
        t2.initial_override = Some(drop_params.clone());
        let r = t2.run_on(&model_info, &ds)?;
        let curve: Vec<(f64, f64)> =
            r.curve.iter().map(|c| ((offset + c.step) as f64, c.dev_accuracy)).collect();
        table.row(vec![
            label.to_string(),
            pct(r.best_dev_accuracy()),
            pct(r.curve.last().map(|c| c.dev_accuracy).unwrap_or(0.0)),
        ]);
        for c in &r.curve {
            rows.push(vec![
                (offset + c.step) as f64,
                match opt {
                    "smezo" => 0.0,
                    "smezo_large" => 1.0,
                    _ => 2.0,
                },
                c.dev_accuracy,
            ]);
        }
        all_curves.push((label, curve));
    }
    for (l, c) in &all_curves {
        series.push((l, c.clone()));
    }
    println!("{}", ascii_curve("Fig 2c — recovery from the drop point", &series, 64, 12));
    table.write(&ctx.out.join("fig2c.md"))?;
    write_csv(&ctx.out.join("fig2c.csv"), &["step", "arm", "dev_acc"], &rows)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

/// Every experiment name [`run`] understands.
pub const ALL: [&str; 14] = [
    "table1", "table2", "table3", "table4", "table5", "table10", "table11", "table13",
    "fig1", "fig2a", "fig2b", "fig2c", "fig3", "fig4",
];

/// Dispatch one experiment by name.
pub fn run(ctx: &Ctx, name: &str, model: &str) -> Result<()> {
    match name {
        "table1" => table1(ctx, model),
        "table2" => table2(ctx, model),
        "table3" => table3(ctx),
        "table4" => table4(ctx, model),
        "table5" => table5(ctx),
        "table10" => table10(ctx, model),
        "table11" => table11(ctx),
        "table13" => table13(ctx),
        "fig1" => fig13(ctx, model, &["rte"], "fig1"),
        "fig3" => fig13(ctx, model, &["rte", "boolq", "wic"], "fig3"),
        "fig2a" => fig2a(ctx, model, "rte"),
        "fig2b" | "fig4" => fig2b4(ctx, model, "rte", 120),
        "fig2c" => fig2c(ctx, model, "rte"),
        "all" => {
            for n in ALL {
                // fig2b/fig4 share one harness; skip the duplicate
                if n == "fig4" {
                    continue;
                }
                run(ctx, n, model)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (known: {}, all)", ALL.join(", ")),
    }
}
