//! Learning-rate schedules. MeZO-family runs use a constant LR (the
//! paper's protocol); first-order baselines get optional warmup+decay.

#[derive(Debug, Clone, Copy, PartialEq)]
/// A learning-rate schedule (multiplier over steps).
pub enum Schedule {
    /// constant LR (the ZO-family protocol)
    Constant,
    /// linear warmup over `warmup` steps then constant
    Warmup { warmup: usize },
    /// linear warmup then linear decay to zero at `total`
    WarmupLinearDecay { warmup: usize, total: usize },
    /// cosine decay to `floor_frac * base` at `total`
    Cosine { total: usize, floor_frac: f64 },
}

impl Schedule {
    /// LR multiplier at `step` (0-based).
    pub fn factor(&self, step: usize) -> f64 {
        match *self {
            Schedule::Constant => 1.0,
            Schedule::Warmup { warmup } => {
                if warmup == 0 || step >= warmup {
                    1.0
                } else {
                    (step + 1) as f64 / warmup as f64
                }
            }
            Schedule::WarmupLinearDecay { warmup, total } => {
                if step < warmup {
                    return (step + 1) as f64 / warmup.max(1) as f64;
                }
                let span = total.saturating_sub(warmup).max(1) as f64;
                let done = (step - warmup) as f64;
                (1.0 - done / span).max(0.0)
            }
            Schedule::Cosine { total, floor_frac } => {
                let t = (step as f64 / total.max(1) as f64).min(1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                floor_frac + (1.0 - floor_frac) * cos
            }
        }
    }

    /// Scheduled LR at `step` for base LR `base`.
    pub fn lr_at(&self, base: f32, step: usize) -> f32 {
        (base as f64 * self.factor(step)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        assert_eq!(Schedule::Constant.factor(0), 1.0);
        assert_eq!(Schedule::Constant.factor(10_000), 1.0);
    }

    #[test]
    fn warmup_ramps() {
        let s = Schedule::Warmup { warmup: 10 };
        assert!(s.factor(0) < s.factor(5));
        assert_eq!(s.factor(10), 1.0);
        assert_eq!(s.factor(100), 1.0);
    }

    #[test]
    fn decay_hits_zero() {
        let s = Schedule::WarmupLinearDecay { warmup: 10, total: 110 };
        assert!((s.factor(110) - 0.0).abs() < 1e-9);
        assert!(s.factor(60) > 0.4 && s.factor(60) < 0.6);
    }

    #[test]
    fn cosine_monotone_down_with_floor() {
        let s = Schedule::Cosine { total: 100, floor_frac: 0.1 };
        assert!((s.factor(0) - 1.0).abs() < 1e-9);
        assert!(s.factor(50) < s.factor(10));
        assert!((s.factor(100) - 0.1).abs() < 1e-9);
        assert!((s.factor(500) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn lr_at_scales() {
        let s = Schedule::Warmup { warmup: 4 };
        assert!((s.lr_at(2.0, 0) - 0.5).abs() < 1e-6);
    }
}
