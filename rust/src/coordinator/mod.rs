//! The coordinator: everything between raw artifacts and paper results.

pub mod checkpoint;
pub mod convergence;
pub mod evaluator;
pub mod experiments;
pub mod lora;
pub mod memory;
pub mod pretrain;
pub mod probe;
pub mod report;
pub mod schedule;
pub mod sweep;
pub mod trainer;
