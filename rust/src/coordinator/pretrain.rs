//! Pretraining driver: first-order Adam on the LM objective over the
//! synthetic corpus, producing the "pretrained" checkpoints every
//! fine-tuning experiment starts from (DESIGN.md §2 — the substitute for
//! downloading LLaMA/OPT/Mistral weights).

use std::path::Path;

use anyhow::Result;

use crate::coordinator::checkpoint::Checkpoint;
use crate::data::corpus::Corpus;
use crate::runtime::exec::{Hypers, InitExec, PretrainExec, StepMetrics};
use crate::runtime::{Runtime, TrainState};
use crate::util::json::Json;

#[derive(Debug, Clone)]
/// Pretraining run configuration.
pub struct PretrainConfig {
    /// model name
    pub model: String,
    /// LM pretraining steps
    pub steps: usize,
    /// Adam learning rate
    pub lr: f32,
    /// corpus + init seed
    pub seed: u64,
    /// log cadence (0 = silent)
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig { model: "llama_tiny".into(), steps: 1500, lr: 3e-3, seed: 7, log_every: 100 }
    }
}

#[derive(Debug, Clone)]
/// Outcome of the LM pretraining phase.
pub struct PretrainResult {
    /// per-step LM losses
    pub losses: Vec<f32>,
    /// bias-corrected EMA of the final loss
    pub final_loss_ema: f64,
    /// final host parameters
    pub params: Vec<f32>,
    /// mean seconds per step
    pub sec_per_step: f64,
}

/// LM-pretrain `cfg.model` from scratch on the synthetic corpus.
pub fn pretrain(rt: &Runtime, cfg: &PretrainConfig) -> Result<PretrainResult> {
    let model = rt.model(&cfg.model)?.clone();
    let hypers = Hypers { lr: cfg.lr, ..Hypers::default() };
    let init = InitExec::load(rt, &model)?;
    let params = init.run(rt, (cfg.seed as u32, 0x9e37))?;
    let exec = PretrainExec::load(rt, &model, hypers)?;
    let mut state = TrainState::from_params(rt, &params, exec.slots, model.n_metrics)?;
    let mut corpus = Corpus::new(cfg.seed, model.seq_len);

    let mut losses = Vec::with_capacity(cfg.steps);
    let mut ema = crate::util::stats::Ema::new(0.98);
    let mut step_seconds = 0.0;
    for t in 0..cfg.steps {
        let tokens = corpus.batch(model.batch);
        let sp = crate::obs::span("pretrain.step");
        exec.run(rt, &mut state, &tokens, (cfg.seed as u32, t as u32))?;
        let mets = StepMetrics::from_tail(&state.metrics(rt)?)?;
        step_seconds += sp.end();
        losses.push(mets.train_loss);
        let s = ema.update(mets.train_loss as f64);
        if cfg.log_every > 0 && t % cfg.log_every == 0 {
            crate::info!("[pretrain {}] step {t}/{} lm loss {:.4} (ema {s:.4})", cfg.model, cfg.steps, mets.train_loss);
        }
        if !mets.train_loss.is_finite() {
            anyhow::bail!("pretraining diverged at step {t}");
        }
    }
    Ok(PretrainResult {
        final_loss_ema: ema.get(),
        params: state.params_host(rt)?,
        losses,
        sec_per_step: step_seconds / cfg.steps.max(1) as f64,
    })
}

/// Multi-task supervised tuning on HELD-OUT task data (data seed differs
/// from every fine-tuning experiment's): the substitute for the broad
/// instruction-ish pretraining a 7B checkpoint arrives with. It gives the
/// base model real task *features* (the regime in which MeZO-style ZO
/// works — Malladi et al.'s prompted-loss assumption) while leaving
/// per-task headroom for the fine-tuning comparison.
pub fn multitask_tune(
    rt: &Runtime,
    model_name: &str,
    params: Vec<f32>,
    steps: usize,
    seed: u64,
) -> Result<Vec<f32>> {
    use crate::data::batcher::TrainLoader;
    use crate::data::tasks;
    use crate::runtime::exec::{StepExec, StepMetrics, ThreshExec};

    let model = rt.model(model_name)?.clone();
    let hypers = Hypers { lr: 1e-3, ..Hypers::default() };
    let thresholds = ThreshExec::load(rt, &model)?.run(rt, &params, 0.0)?;
    let step = StepExec::load(rt, &model, "fo_adam", hypers, &thresholds)?;
    let mut state = TrainState::from_params(rt, &params, step.slots, model.n_metrics)?;

    // held-out data seed: multitask tuning must never see the fine-tuning
    // splits (which use the experiment data seed)
    let datasets: Vec<_> = tasks::ALL_TASKS
        .iter()
        .map(|t| tasks::generate_sized(t, seed ^ 0x9999, 600, 0, 0))
        .collect::<Result<Vec<_>>>()?;
    let mut loaders: Vec<TrainLoader> = datasets
        .iter()
        .map(|d| TrainLoader::new(&d.train, model.batch, model.seq_len, seed))
        .collect::<Result<Vec<_>>>()?;

    let mut ema = crate::util::stats::Ema::new(0.98);
    for t in 0..steps {
        let idx = t % loaders.len();
        let loader = &mut loaders[idx];
        let batch = loader.next_batch();
        step.run(rt, &mut state, &batch.tokens, &batch.labels, (seed as u32, t as u32))?;
        let mets = StepMetrics::from_tail(&state.metrics(rt)?)?;
        let s = ema.update(mets.train_loss as f64);
        if t % 200 == 0 {
            crate::info!("[multitask {model_name}] step {t}/{steps} loss {:.4} (ema {s:.4})", mets.train_loss);
        }
        if !mets.train_loss.is_finite() {
            anyhow::bail!("multitask tuning diverged at step {t}");
        }
    }
    state.params_host(rt)
}

/// Pretrain (or load a cached pretrain checkpoint) for `model`.
/// Checkpoints land in `<ckpt_dir>/<model>_pretrained.bin`; every
/// experiment shares them, so the expensive phase runs once per model.
pub fn pretrained_params(
    rt: &Runtime,
    model_name: &str,
    ckpt_dir: &Path,
    cfg_override: Option<PretrainConfig>,
) -> Result<Vec<f32>> {
    let model = rt.model(model_name)?.clone();
    let path = ckpt_dir.join(format!("{model_name}_pretrained.bin"));
    if path.exists() {
        match Checkpoint::load(&path, &model) {
            Ok(ck) => {
                crate::info!("loaded pretrained checkpoint {} (step {})", path.display(), ck.step);
                return Ok(ck.params);
            }
            Err(e) => crate::info!("stale pretrain checkpoint ({e}); re-pretraining"),
        }
    }
    let cfg = cfg_override.unwrap_or(PretrainConfig { model: model_name.into(), ..Default::default() });
    let result = pretrain(rt, &cfg)?;
    // phase 2: multi-task tuning on held-out data (see multitask_tune)
    let mt_steps = cfg.steps / 2;
    let params = multitask_tune(rt, model_name, result.params, mt_steps, cfg.seed)?;
    Checkpoint {
        model: model_name.into(),
        n_params: params.len(),
        step: cfg.steps + mt_steps,
        params: params.clone(),
        slots: vec![],
        meta: Json::obj(vec![
            ("kind", Json::Str("pretrain+multitask".into())),
            ("lm_loss_ema", Json::Num(result.final_loss_ema)),
            ("lr", Json::Num(cfg.lr as f64)),
            ("multitask_steps", Json::Num(mt_steps as f64)),
        ]),
    }
    .save(&path)?;
    crate::info!(
        "pretrained {model_name}: {} LM + {} multitask steps, lm loss ema {:.4} -> {}",
        cfg.steps,
        mt_steps,
        result.final_loss_ema,
        path.display()
    );
    Ok(params)
}
