//! Hyperparameter presets — the paper's appendix Tables 7–9, rescaled.
//!
//! The paper's absolute learning rates (1e-6-ish) belong to 7B-parameter
//! models; ZO step size scales roughly with 1/sqrt(d̂) and our models are
//! ~4 orders of magnitude smaller, so the presets below were calibrated
//! with the `sweep` subcommand (Fig-2a harness) on the tiny/small models
//! and keep the paper's *relationships*: S-MeZO runs at a higher LR than
//! MeZO (paper §4.1), R-MeZO uses the S-MeZO grid, FT-Adam uses a standard
//! first-order LR, eps = 1e-3 everywhere (paper's value).

use crate::runtime::exec::Hypers;

/// Per-task S-MeZO sparsity — paper Table 9 (LLaMA row), reused for every
/// magnitude-masked variant; tasks the paper didn't list default to 0.75.
pub fn task_sparsity(task: &str) -> f32 {
    match task {
        "sst2" => 0.70,
        "rte" => 0.75,
        "boolq" => 0.80,
        "wic" => 0.80,
        "multirc" => 0.80,
        _ => 0.75,
    }
}

/// The LR searched over by the Fig-2a sweep for ZO methods.
pub const ZO_LR_GRID: [f32; 6] = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2];

/// First-order LR grid (FT baseline).
pub const FO_LR_GRID: [f32; 3] = [1e-4, 3e-4, 1e-3];

/// Calibrated default hypers per optimizer (eps fixed at the paper's 1e-3).
pub fn default_hypers(optimizer: &str, task: &str) -> Hypers {
    let sparsity = task_sparsity(task);
    let mut h = Hypers { sparsity, ..Hypers::default() };
    h.lr = match optimizer {
        // Calibrated on llama_tiny (native backend, seeds 7/17/99/100):
        // MeZO diverges at 3e-3 (the Fig-2a mechanism); the magnitude-
        // masked variants run stably at ~30x higher LR — the d-hat << d
        // variance reduction of Theorem 1 — mirroring the paper's
        // S-MeZO-takes-larger-LR relationship.
        "mezo" => 3e-4,
        "smezo" | "smezo_const" | "smezo_pallas" => 1e-2,
        "smezo_large" => 1e-2,
        "rmezo" => 1e-3,
        "zo_sign" => 1e-4,
        "zo_cons" => 3e-4,
        "zo_adam" => 1e-4,
        "zo_adamu" => 3e-4,
        "zo_mom" => 1e-4,
        "mezo_lora" => 3e-3,
        "fo_sgd" => 1e-2,
        "fo_adam" => 3e-3,
        "lora_fo" => 1e-2,
        _ => 1e-3,
    };
    h
}

/// Default training length per optimizer (first-order converges in far
/// fewer steps — paper Table 4 note / standard MeZO protocol).
pub fn default_steps(optimizer: &str) -> usize {
    match optimizer {
        "fo_sgd" | "fo_adam" | "lora_fo" => 1000,
        _ => 6000,
    }
}

/// Which optimizers count as zeroth-order (reporting splits on this).
pub fn is_zeroth_order(optimizer: &str) -> bool {
    !matches!(optimizer, "fo_sgd" | "fo_adam" | "lora_fo")
}

/// Display names used in report tables (paper's row labels).
pub fn display_name(optimizer: &str) -> &'static str {
    match optimizer {
        "mezo" => "MeZO",
        "smezo" => "S-MeZO",
        "smezo_pallas" => "S-MeZO (Pallas)",
        "smezo_const" => "S-MeZO (const mask)",
        "smezo_large" => "S-MeZO (large-only)",
        "rmezo" => "R-MeZO",
        "zo_sign" => "ZO-SGD-Sign",
        "zo_cons" => "ZO-SGD-Cons",
        "zo_adam" => "ZO-SGD-Adam",
        "zo_adamu" => "ZO-AdaMU",
        "zo_mom" => "AdaZeta*",
        "mezo_lora" => "MeZO-LoRA",
        "fo_sgd" => "SGD (FO)",
        "fo_adam" => "FT",
        "lora_fo" => "LoRA",
        _ => "?",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table9_values() {
        assert_eq!(task_sparsity("sst2"), 0.70);
        assert_eq!(task_sparsity("rte"), 0.75);
        assert_eq!(task_sparsity("boolq"), 0.80);
        assert_eq!(task_sparsity("aqua"), 0.75); // default
    }

    #[test]
    fn smezo_lr_exceeds_mezo_lr() {
        // the paper's central hyperparameter relationship
        let m = default_hypers("mezo", "rte");
        let s = default_hypers("smezo", "rte");
        assert!(s.lr > m.lr);
        assert_eq!(m.eps, s.eps);
    }

    #[test]
    fn fo_split() {
        assert!(is_zeroth_order("mezo"));
        assert!(is_zeroth_order("zo_adamu"));
        assert!(!is_zeroth_order("fo_adam"));
        assert!(default_steps("fo_adam") < default_steps("mezo"));
    }

    #[test]
    fn display_names_cover_known() {
        for o in [
            "mezo", "smezo", "rmezo", "zo_sign", "zo_cons", "zo_adam", "zo_adamu",
            "zo_mom", "mezo_lora", "fo_sgd", "fo_adam", "lora_fo", "smezo_large",
        ] {
            assert_ne!(display_name(o), "?", "{o}");
        }
    }
}
