//! Configuration: presets + TOML overrides.
//!
//! Presets encode the paper's hyperparameter appendix (Tables 7–9) scaled
//! to this testbed's model sizes; `presets/*.toml` files in the repo carry
//! the same values in editable form and are parsed by [`load_overrides`].

pub mod presets;

use anyhow::{bail, Result};
use std::path::Path;

use crate::runtime::exec::Hypers;
use crate::util::json::Json;
use crate::util::toml;

/// A fully-resolved training run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// model name (manifest key)
    pub model: String,
    /// task name (see `data::tasks`)
    pub task: String,
    /// optimizer name (step-program suffix)
    pub optimizer: String,
    /// training steps
    pub steps: usize,
    /// step hyperparameters
    pub hypers: Hypers,
    /// data + noise seed for the run
    pub seed: u64,
    /// evaluate on dev every N steps (0 = never)
    pub eval_every: usize,
    /// log metrics every N steps
    pub log_every: usize,
    /// initialize params from this checkpoint (path) instead of `init`
    pub init_from: Option<String>,
    /// cap on dev examples per evaluation (speed knob; 0 = all)
    pub eval_cap: usize,
    /// data-parallel worker count (1 = serial trainer; >1 routes ZO
    /// runs through the seed-sync DP engine, `crate::parallel::dp`)
    pub workers: usize,
    /// page-cache budget in bytes for the tiered parameter store
    /// (0 = fully resident; >0 pages the parameter prefix out to a
    /// scratch file, stateless ZO family only — see `runtime::store`)
    pub page_cache_bytes: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "llama_tiny".into(),
            task: "rte".into(),
            optimizer: "smezo".into(),
            steps: 400,
            hypers: Hypers::default(),
            seed: 42,
            eval_every: 0,
            log_every: 25,
            init_from: None,
            eval_cap: 0,
            workers: 1,
            page_cache_bytes: 0,
        }
    }
}

impl TrainConfig {
    /// Resolve a config from presets, then apply an optional TOML file and
    /// then CLI-style key=value overrides.
    pub fn resolve(
        model: &str,
        task: &str,
        optimizer: &str,
        toml_path: Option<&Path>,
    ) -> Result<TrainConfig> {
        let mut cfg = TrainConfig {
            model: model.to_string(),
            task: task.to_string(),
            optimizer: optimizer.to_string(),
            ..TrainConfig::default()
        };
        cfg.hypers = presets::default_hypers(optimizer, task);
        cfg.steps = presets::default_steps(optimizer);
        if let Some(path) = toml_path {
            let doc = toml::parse_file(path)?;
            cfg.apply_json(&doc)?;
        }
        Ok(cfg)
    }

    /// Apply overrides from a parsed TOML/JSON tree.
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        if let Some(v) = doc.get("model") {
            self.model = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("task") {
            self.task = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("optimizer") {
            self.optimizer = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("steps") {
            self.steps = v.as_usize()?;
        }
        if let Some(v) = doc.get("seed") {
            self.seed = v.as_f64()? as u64;
        }
        if let Some(v) = doc.get("eval_every") {
            self.eval_every = v.as_usize()?;
        }
        if let Some(v) = doc.get("log_every") {
            self.log_every = v.as_usize()?;
        }
        if let Some(v) = doc.get("eval_cap") {
            self.eval_cap = v.as_usize()?;
        }
        if let Some(v) = doc.get("workers") {
            self.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("page_cache_bytes") {
            self.page_cache_bytes = v.as_usize()?;
        }
        if let Some(v) = doc.get("init_from") {
            self.init_from = Some(v.as_str()?.to_string());
        }
        if let Some(h) = doc.get("hypers") {
            for (key, field) in [
                ("lr", 0usize),
                ("eps", 1),
                ("sparsity", 2),
                ("mask_seed", 3),
                ("beta1", 4),
                ("beta2", 5),
                ("adam_eps", 6),
                ("wd", 7),
            ] {
                if let Some(v) = h.get(key) {
                    let x = v.as_f64()? as f32;
                    match field {
                        0 => self.hypers.lr = x,
                        1 => self.hypers.eps = x,
                        2 => self.hypers.sparsity = x,
                        3 => self.hypers.mask_seed = x,
                        4 => self.hypers.beta1 = x,
                        5 => self.hypers.beta2 = x,
                        6 => self.hypers.adam_eps = x,
                        _ => self.hypers.wd = x,
                    }
                }
            }
        }
        self.validate()
    }

    /// Reject out-of-range hypers/steps before any compute runs.
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if !(0.0..1.0).contains(&self.hypers.sparsity) {
            bail!("sparsity must be in [0, 1), got {}", self.hypers.sparsity);
        }
        if self.hypers.eps <= 0.0 {
            bail!("eps must be positive");
        }
        if self.hypers.lr < 0.0 {
            bail!("lr must be non-negative");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1 (1 = serial)");
        }
        Ok(())
    }

    /// Run label used in paths and reports.
    pub fn label(&self) -> String {
        format!("{}_{}_{}_s{}", self.model, self.task, self.optimizer, self.seed)
    }
}

/// A fully-resolved serving configuration (the `serve` subcommand and
/// the loopback test/bench harnesses).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// model name to serve (manifest key)
    pub model: String,
    /// loopback TCP port (0 = OS-assigned ephemeral)
    pub port: u16,
    /// worker-pool threads fused forward passes shard across
    pub workers: usize,
    /// micro-batch size trigger: flush an adapter group at this many rows
    pub max_batch_rows: usize,
    /// micro-batch deadline trigger in milliseconds
    pub flush_ms: u64,
    /// adapter registry count cap (LRU beyond it)
    pub max_adapters: usize,
    /// adapter registry byte budget (LRU beyond it)
    pub adapter_budget: usize,
    /// seed for the deterministic base init when no checkpoint is given
    pub seed: u64,
    /// base parameters from this checkpoint instead of `init`
    pub init_from: Option<String>,
    /// enable job orchestration persisted under this directory
    /// (`None` = the `/v1/jobs` API answers 400)
    pub jobs_dir: Option<String>,
    /// default optimizer steps per job-scheduler slice
    /// (0 = the scheduler's built-in default)
    pub slice_steps: usize,
    /// TCP address to park remote `worker` processes on
    /// (`None` = job slices always run their shards locally)
    pub listen_workers: Option<String>,
    /// block a drain until this many remote workers have connected
    pub min_workers: usize,
    /// page-cache budget in bytes for the base parameter store
    /// (0 = fully resident; >0 serves tenants as overlay views over a
    /// file-backed paged base — see `runtime::store`)
    pub page_cache_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "llama_tiny".into(),
            port: 0,
            workers: 2,
            max_batch_rows: 16,
            flush_ms: 5,
            max_adapters: 8,
            adapter_budget: 64 << 20,
            seed: 42,
            init_from: None,
            jobs_dir: None,
            slice_steps: 0,
            listen_workers: None,
            min_workers: 0,
            page_cache_bytes: 0,
        }
    }
}

impl ServeConfig {
    /// Defaults, then an optional TOML override file.
    pub fn resolve(toml_path: Option<&Path>) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(path) = toml_path {
            let doc = toml::parse_file(path)?;
            cfg.apply_json(&doc)?;
        }
        Ok(cfg)
    }

    /// Apply overrides from a parsed TOML/JSON tree.
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        if let Some(v) = doc.get("model") {
            self.model = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("port") {
            let p = v.as_usize()?;
            if p > u16::MAX as usize {
                bail!("port {p} out of range");
            }
            self.port = p as u16;
        }
        if let Some(v) = doc.get("workers") {
            self.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("max_batch_rows") {
            self.max_batch_rows = v.as_usize()?;
        }
        if let Some(v) = doc.get("flush_ms") {
            self.flush_ms = v.as_usize()? as u64;
        }
        if let Some(v) = doc.get("max_adapters") {
            self.max_adapters = v.as_usize()?;
        }
        if let Some(v) = doc.get("adapter_budget") {
            self.adapter_budget = v.as_usize()?;
        }
        if let Some(v) = doc.get("seed") {
            self.seed = v.as_f64()? as u64;
        }
        if let Some(v) = doc.get("init_from") {
            self.init_from = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("jobs_dir") {
            self.jobs_dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("slice_steps") {
            self.slice_steps = v.as_usize()?;
        }
        if let Some(v) = doc.get("listen_workers") {
            self.listen_workers = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("min_workers") {
            self.min_workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("page_cache_bytes") {
            self.page_cache_bytes = v.as_usize()?;
        }
        self.validate()
    }

    /// Reject nonsensical caps before any thread or socket exists.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch_rows == 0 {
            bail!("max_batch_rows must be >= 1");
        }
        if self.max_adapters == 0 || self.adapter_budget == 0 {
            bail!("adapter caps must be positive");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_defaults_and_overrides() {
        let cfg = ServeConfig::resolve(None).unwrap();
        assert_eq!(cfg.port, 0);
        assert!(cfg.validate().is_ok());
        let mut cfg = ServeConfig::default();
        let doc = crate::util::toml::parse(
            "model = \"llama_med\"\nport = 8080\nmax_batch_rows = 4\nflush_ms = 2\n\
             jobs_dir = \"jobs\"\nslice_steps = 10\n",
        )
        .unwrap();
        cfg.apply_json(&doc).unwrap();
        assert_eq!(cfg.model, "llama_med");
        assert_eq!(cfg.port, 8080);
        assert_eq!(cfg.max_batch_rows, 4);
        assert_eq!(cfg.flush_ms, 2);
        assert_eq!(cfg.jobs_dir.as_deref(), Some("jobs"));
        assert_eq!(cfg.slice_steps, 10);
        // bad values rejected
        let mut bad = ServeConfig::default();
        bad.max_batch_rows = 0;
        assert!(bad.validate().is_err());
        bad.max_batch_rows = 1;
        bad.workers = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn resolve_and_override() {
        let mut cfg = TrainConfig::resolve("llama_tiny", "rte", "smezo", None).unwrap();
        assert_eq!(cfg.task, "rte");
        assert_eq!(cfg.workers, 1);
        assert!(cfg.hypers.sparsity > 0.0);
        let doc =
            crate::util::toml::parse("steps = 10\nworkers = 4\n[hypers]\nlr = 0.5\nsparsity = 0.6\n")
                .unwrap();
        cfg.apply_json(&doc).unwrap();
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.hypers.lr, 0.5);
        assert_eq!(cfg.hypers.sparsity, 0.6);
    }

    #[test]
    fn zero_workers_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = TrainConfig::default();
        cfg.hypers.sparsity = 1.5;
        assert!(cfg.validate().is_err());
        cfg.hypers.sparsity = 0.5;
        cfg.hypers.eps = 0.0;
        assert!(cfg.validate().is_err());
        cfg.hypers.eps = 1e-3;
        cfg.steps = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn label_stable() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.label(), "llama_tiny_rte_smezo_s42");
    }
}
