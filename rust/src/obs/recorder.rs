//! Per-job flight recorder: bounded, byte-budgeted ZO training telemetry.
//!
//! The paper's claims are about *training dynamics* — convergence speed
//! (§4, 3.5× over dense MeZO on RTE), the instability of dense ZO at
//! high learning rate (Fig. 2a), the effect of masking small-magnitude
//! weights — so the operational layer must be able to answer "what has
//! this job's loss/`g`/sparsity curve looked like" live, without
//! re-reading journals. A [`FlightRecorder`] captures, per committed
//! step, exactly the scalars the trainer already computes for free:
//! loss, the projected-gradient scalar `g`, a running |g| EWMA, the
//! nonzero-mask count (effective sparsity), the mask epoch, and the mask
//! churn measured at each `mask_refresh` boundary — plus per-rank worker
//! attribution and slice/replay timings from the scheduler.
//!
//! **Memory contract**: the step history is byte-budgeted. When the
//! decimated buffer would exceed the budget, the power-of-two `stride`
//! doubles and older samples thin out (`step % stride == 0` survives,
//! plus the first step, always), so a 100k-step job costs the same
//! resident bytes as a 100-step one. The most recent step is tracked
//! separately and is always exact. `rust/tests/properties.rs` holds the
//! budget/decimation invariants under adversarial step counts.
//!
//! **The PR 7 invariant carries over**: recording consumes no PRNG
//! state and never writes into step journals — it is [`Instant`],
//! atomics and a mutex over plain memory. An instrumented run stays
//! bit-identical to an uninstrumented one (`rust/tests/obs.rs`).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Resident bytes one [`Sample`] is accounted as (its in-memory size).
pub const SAMPLE_BYTES: usize = std::mem::size_of::<Sample>();

/// Default per-job step-history budget. At ~40 bytes per sample this
/// holds ~1600 exact steps before the first decimation.
pub const DEFAULT_BUDGET_BYTES: usize = 64 * 1024;

/// Recent slice/replay timings kept (operational context, not history).
const TIMINGS_CAP: usize = 32;

/// Recent inter-step wall-clock intervals kept for the median step time.
const INTERVALS_CAP: usize = 64;

/// Mask-churn measurements kept, one per `mask_refresh` boundary.
const CHURN_CAP: usize = 64;

/// One committed optimizer step's telemetry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// optimizer step index
    pub step: u32,
    /// training loss at this step (mean over the batch)
    pub loss: f32,
    /// projected-gradient scalar `g = (l+ - l-) / 2eps`
    pub g: f32,
    /// running EWMA of |g| (decay 0.9), seeded at the first step
    pub g_abs_ewma: f32,
    /// nonzero entries of the step's mask (`total` when dense)
    pub nonzero: u64,
    /// total trainable parameters
    pub total: u64,
    /// §8.2 threshold generation the step ran under
    pub mask_epoch: u32,
    /// mask churn measured at this sample's epoch boundary (fraction of
    /// coordinates whose mask bit flipped; 0 within epoch 0)
    pub churn: f32,
}

impl Sample {
    fn json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("loss", Json::Num(self.loss as f64)),
            ("g", Json::Num(self.g as f64)),
            ("g_abs_ewma", Json::Num(self.g_abs_ewma as f64)),
            ("nonzero", Json::Num(self.nonzero as f64)),
            ("total", Json::Num(self.total as f64)),
            ("mask_epoch", Json::Num(self.mask_epoch as f64)),
            ("churn", Json::Num(self.churn as f64)),
        ])
    }
}

struct Inner {
    budget: usize,
    /// decimation stride (power of two; 1 = every step retained)
    stride: u64,
    /// decimated history; `samples[0]` is the first step ever recorded
    samples: Vec<Sample>,
    /// the most recent step, always exact (outside the decimated buffer)
    latest: Option<Sample>,
    /// total steps ever recorded (survives decimation)
    seen: u64,
    g_abs_ewma: f64,
    /// fast/slow loss EWMAs feeding the loss-divergence alert rule
    loss_fast: f64,
    loss_slow: f64,
    /// the mask captured at the current epoch's first recorded step,
    /// compared against at the next epoch boundary to measure churn
    epoch_mask: Option<(u32, Option<Vec<u8>>)>,
    last_churn: f32,
    churn_history: Vec<(u32, f32)>,
    /// rank -> live steps attributed (rank 0 is the coordinator)
    workers: BTreeMap<u32, u64>,
    worker_lost: u64,
    slices: u64,
    slice_seconds: Vec<f64>,
    replay_seconds: Vec<f64>,
    step_intervals: Vec<f64>,
    last_step_at: Option<Instant>,
    /// highest per-slice heap watermark observed for this job (bytes,
    /// from [`crate::obs::mem::window_peak`]; 0 when tracking is off)
    mem_peak_bytes: u64,
}

/// Point-in-time copy of a recorder's state (alert evaluation, tests).
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// current decimation stride (power of two)
    pub stride: u64,
    /// the byte budget the history is held under
    pub budget_bytes: usize,
    /// decimated history with the exact latest step appended
    pub samples: Vec<Sample>,
    /// total steps ever recorded
    pub seen: u64,
    /// running EWMA of |g|
    pub g_abs_ewma: f64,
    /// fast loss EWMA (decay 0.5)
    pub loss_fast: f64,
    /// slow loss EWMA (decay 0.98)
    pub loss_slow: f64,
    /// `(epoch, churn)` per mask-refresh boundary, oldest first
    pub churn_history: Vec<(u32, f32)>,
    /// rank -> live steps attributed
    pub workers: BTreeMap<u32, u64>,
    /// lost-worker events charged to this job
    pub worker_lost: u64,
    /// slices run
    pub slices: u64,
    /// recent slice wall-clock seconds
    pub slice_seconds: Vec<f64>,
    /// recent journal-replay wall-clock seconds
    pub replay_seconds: Vec<f64>,
    /// median of recent inter-step intervals (0 with <2 steps)
    pub median_step_seconds: f64,
    /// seconds since the last recorded step, if any
    pub last_step_age_seconds: Option<f64>,
    /// highest per-slice heap watermark observed for this job (bytes;
    /// 0 when the tracking allocator is not installed)
    pub mem_peak_bytes: u64,
}

impl Snapshot {
    /// Resident bytes of the returned step history.
    pub fn history_bytes(&self) -> usize {
        self.samples.len() * SAMPLE_BYTES
    }
}

/// Per-job telemetry sink. Shared `Arc`-style between the scheduler,
/// the DP trainer and the HTTP timeline endpoint; every method takes
/// `&self` behind one mutex (cold path — once per committed step).
pub struct FlightRecorder {
    inner: Mutex<Inner>,
}

/// Mask churn between two epoch-boundary masks: the fraction of
/// coordinates whose mask bit differs. `None` means dense (all ones).
fn mask_churn(prev: &Option<Vec<u8>>, next: Option<&[u8]>) -> f32 {
    match (prev.as_deref(), next) {
        (None, None) => 0.0,
        (Some(m), None) | (None, Some(m)) => {
            if m.is_empty() {
                return 0.0;
            }
            let zeros = m.iter().filter(|&&b| b == 0).count();
            zeros as f32 / m.len() as f32
        }
        (Some(a), Some(b)) => {
            let n = a.len().min(b.len());
            if n == 0 {
                return 0.0;
            }
            let diff = a.iter().zip(b).filter(|(x, y)| x != y).count();
            diff as f32 / n as f32
        }
    }
}

fn push_capped<T>(v: &mut Vec<T>, x: T, cap: usize) {
    if v.len() >= cap {
        v.remove(0);
    }
    v.push(x);
}

impl FlightRecorder {
    /// A recorder holding its step history under `budget_bytes`
    /// (clamped to at least a handful of samples so decimation always
    /// terminates).
    pub fn new(budget_bytes: usize) -> FlightRecorder {
        FlightRecorder {
            inner: Mutex::new(Inner {
                budget: budget_bytes.max(8 * SAMPLE_BYTES),
                stride: 1,
                samples: Vec::new(),
                latest: None,
                seen: 0,
                g_abs_ewma: 0.0,
                loss_fast: 0.0,
                loss_slow: 0.0,
                epoch_mask: None,
                last_churn: 0.0,
                churn_history: Vec::new(),
                workers: BTreeMap::new(),
                worker_lost: 0,
                slices: 0,
                slice_seconds: Vec::new(),
                replay_seconds: Vec::new(),
                step_intervals: Vec::new(),
                last_step_at: None,
                mem_peak_bytes: 0,
            }),
        }
    }

    /// Record one committed step. `mask` is the step's sparse mask
    /// (`None` = dense); churn is measured lazily at `mask_epoch`
    /// boundaries against the previous epoch's stored mask.
    pub fn record_step(
        &self,
        step: u32,
        loss: f32,
        g: f32,
        mask: Option<&[u8]>,
        total: u64,
        mask_epoch: u32,
    ) {
        let mut guard = self.inner.lock().unwrap();
        let i = &mut *guard;
        // mask churn at epoch boundaries only (one copy per epoch)
        match i.epoch_mask.take() {
            None => i.epoch_mask = Some((mask_epoch, mask.map(|m| m.to_vec()))),
            Some((e, prev)) if e != mask_epoch => {
                let churn = mask_churn(&prev, mask);
                i.last_churn = churn;
                push_capped(&mut i.churn_history, (mask_epoch, churn), CHURN_CAP);
                i.epoch_mask = Some((mask_epoch, mask.map(|m| m.to_vec())));
            }
            kept => i.epoch_mask = kept,
        }
        let g_abs = (g as f64).abs();
        i.g_abs_ewma =
            if i.seen == 0 { g_abs } else { 0.9 * i.g_abs_ewma + 0.1 * g_abs };
        let l = loss as f64;
        if i.seen == 0 {
            i.loss_fast = l;
            i.loss_slow = l;
        } else {
            i.loss_fast = 0.5 * i.loss_fast + 0.5 * l;
            i.loss_slow = 0.98 * i.loss_slow + 0.02 * l;
        }
        let now = Instant::now();
        if let Some(prev) = i.last_step_at {
            push_capped(
                &mut i.step_intervals,
                now.duration_since(prev).as_secs_f64(),
                INTERVALS_CAP,
            );
        }
        i.last_step_at = Some(now);
        let nonzero = mask.map(|m| m.iter().map(|&b| b as u64).sum()).unwrap_or(total);
        let sample = Sample {
            step,
            loss,
            g,
            g_abs_ewma: i.g_abs_ewma as f32,
            nonzero,
            total,
            mask_epoch,
            churn: i.last_churn,
        };
        i.latest = Some(sample);
        i.seen += 1;
        if i.samples.is_empty() || step as u64 % i.stride == 0 {
            i.samples.push(sample);
        }
        // enforce the byte budget (+1 accounts for `latest`, which the
        // snapshot appends): double the stride, thin the history, repeat
        while (i.samples.len() + 1) * SAMPLE_BYTES > i.budget {
            i.stride = i.stride.saturating_mul(2);
            let stride = i.stride;
            let mut first = true;
            i.samples.retain(|s| std::mem::take(&mut first) || s.step as u64 % stride == 0);
            if i.stride == u64::MAX {
                break;
            }
        }
        crate::obs::counter("recorder_steps_total", &[]).inc();
    }

    /// Attribute one finished slice: wall-clock seconds, committed step
    /// count, and the remote shard ranks that participated (rank 0, the
    /// coordinator, is always credited).
    pub fn note_slice(&self, seconds: f64, committed: u64, remote_ranks: &[u32]) {
        let mut i = self.inner.lock().unwrap();
        i.slices += 1;
        push_capped(&mut i.slice_seconds, seconds, TIMINGS_CAP);
        *i.workers.entry(0).or_insert(0) += committed;
        for &r in remote_ranks {
            *i.workers.entry(r).or_insert(0) += committed;
        }
    }

    /// Attribute a journal-replay pass (resume / publish verification).
    pub fn note_replay(&self, seconds: f64) {
        let mut i = self.inner.lock().unwrap();
        push_capped(&mut i.replay_seconds, seconds, TIMINGS_CAP);
    }

    /// Charge one lost-worker event (rank attribution via `workers`).
    pub fn note_worker_lost(&self, rank: u32) {
        let mut i = self.inner.lock().unwrap();
        i.worker_lost += 1;
        i.workers.entry(rank).or_insert(0);
    }

    /// Fold one slice's heap watermark into the job's running peak
    /// (bytes; typically [`crate::obs::mem::window_peak`] measured over
    /// the slice). A 0 — tracking allocator not installed — is a no-op.
    pub fn note_mem_peak(&self, bytes: u64) {
        let mut i = self.inner.lock().unwrap();
        i.mem_peak_bytes = i.mem_peak_bytes.max(bytes);
    }

    /// Point-in-time copy (history + the exact latest step appended).
    pub fn snapshot(&self) -> Snapshot {
        let i = self.inner.lock().unwrap();
        let mut samples = i.samples.clone();
        if let Some(last) = i.latest {
            if samples.last().map(|s| s.step) != Some(last.step) {
                samples.push(last);
            }
        }
        let median = if i.step_intervals.len() < 2 {
            0.0
        } else {
            let mut xs = i.step_intervals.clone();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            xs[xs.len() / 2]
        };
        Snapshot {
            stride: i.stride,
            budget_bytes: i.budget,
            samples,
            seen: i.seen,
            g_abs_ewma: i.g_abs_ewma,
            loss_fast: i.loss_fast,
            loss_slow: i.loss_slow,
            churn_history: i.churn_history.clone(),
            workers: i.workers.clone(),
            worker_lost: i.worker_lost,
            slices: i.slices,
            slice_seconds: i.slice_seconds.clone(),
            replay_seconds: i.replay_seconds.clone(),
            median_step_seconds: median,
            last_step_age_seconds: i.last_step_at.map(|t| t.elapsed().as_secs_f64()),
            mem_peak_bytes: i.mem_peak_bytes,
        }
    }

    /// The `GET /v1/jobs/{id}/timeline` body (minus job metadata and
    /// alerts, which the HTTP layer composes in): parallel JSON series
    /// plus attribution and timing context. Series values round-trip
    /// bit-exactly (f32 → f64 → shortest-round-trip decimal).
    pub fn timeline_json(&self) -> Json {
        let snap = self.snapshot();
        let nums = |f: &dyn Fn(&Sample) -> f64| {
            Json::Arr(snap.samples.iter().map(|s| Json::Num(f(s))).collect())
        };
        let series = Json::obj(vec![
            ("step", nums(&|s| s.step as f64)),
            ("loss", nums(&|s| s.loss as f64)),
            ("g", nums(&|s| s.g as f64)),
            ("g_abs_ewma", nums(&|s| s.g_abs_ewma as f64)),
            ("nonzero", nums(&|s| s.nonzero as f64)),
            ("sparsity", nums(&|s| {
                if s.total == 0 {
                    0.0
                } else {
                    1.0 - s.nonzero as f64 / s.total as f64
                }
            })),
            ("mask_epoch", nums(&|s| s.mask_epoch as f64)),
            ("churn", nums(&|s| s.churn as f64)),
        ]);
        let workers = Json::Obj(
            snap.workers
                .iter()
                .map(|(r, n)| (r.to_string(), Json::Num(*n as f64)))
                .collect(),
        );
        let churn = Json::Arr(
            snap.churn_history
                .iter()
                .map(|(e, c)| {
                    Json::Arr(vec![Json::Num(*e as f64), Json::Num(*c as f64)])
                })
                .collect(),
        );
        let timings = Json::obj(vec![
            (
                "slice_seconds",
                Json::Arr(snap.slice_seconds.iter().map(|&s| Json::Num(s)).collect()),
            ),
            (
                "replay_seconds",
                Json::Arr(snap.replay_seconds.iter().map(|&s| Json::Num(s)).collect()),
            ),
            ("median_step_seconds", Json::Num(snap.median_step_seconds)),
        ]);
        Json::obj(vec![
            ("stride", Json::Num(snap.stride as f64)),
            ("budget_bytes", Json::Num(snap.budget_bytes as f64)),
            ("samples", Json::Num(snap.samples.len() as f64)),
            ("seen", Json::Num(snap.seen as f64)),
            ("series", series),
            (
                "latest",
                snap.samples.last().map(|s| s.json()).unwrap_or(Json::Null),
            ),
            ("workers", workers),
            ("worker_lost", Json::Num(snap.worker_lost as f64)),
            ("slices", Json::Num(snap.slices as f64)),
            ("churn_by_epoch", churn),
            ("timings", timings),
            (
                "mem",
                Json::obj(vec![(
                    "peak_bytes",
                    Json::Num(snap.mem_peak_bytes as f64),
                )]),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// process-wide recorder registry (job id -> recorder)
// ---------------------------------------------------------------------------

static RECORDERS: OnceLock<Mutex<BTreeMap<u64, Arc<FlightRecorder>>>> = OnceLock::new();

fn registry() -> &'static Mutex<BTreeMap<u64, Arc<FlightRecorder>>> {
    RECORDERS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// The recorder for `job`, created (with [`DEFAULT_BUDGET_BYTES`]) on
/// first use. Each job's history is byte-budgeted, so the map's resident
/// cost is bounded by the queue's job count.
pub fn for_job(job: u64) -> Arc<FlightRecorder> {
    let mut map = registry().lock().unwrap();
    let rec = map
        .entry(job)
        .or_insert_with(|| Arc::new(FlightRecorder::new(DEFAULT_BUDGET_BYTES)));
    crate::obs::gauge("recorder_jobs", &[]).set(map.len() as i64);
    rec.clone()
}

/// The recorder for `job`, if any step of it has been observed.
pub fn get(job: u64) -> Option<Arc<FlightRecorder>> {
    registry().lock().unwrap().get(&job).cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decimation_keeps_first_and_last_exact() {
        let r = FlightRecorder::new(16 * SAMPLE_BYTES);
        for step in 0..10_000u32 {
            r.record_step(step, 1.0, 0.5, None, 100, 0);
        }
        let snap = r.snapshot();
        assert!(snap.history_bytes() <= snap.budget_bytes, "over budget");
        assert_eq!(snap.samples.first().unwrap().step, 0, "first step lost");
        assert_eq!(snap.samples.last().unwrap().step, 9_999, "last step lost");
        assert!(snap.stride.is_power_of_two());
        assert!(snap.stride > 1, "10k steps in 16 slots must decimate");
        for s in &snap.samples[1..snap.samples.len() - 1] {
            assert_eq!(s.step as u64 % snap.stride, 0, "non-grid sample survived");
        }
        assert_eq!(snap.seen, 10_000);
    }

    #[test]
    fn churn_measured_at_epoch_boundaries() {
        let r = FlightRecorder::new(DEFAULT_BUDGET_BYTES);
        let m0 = vec![1u8, 1, 0, 0];
        let m1 = vec![1u8, 0, 1, 0]; // 2 of 4 flipped
        r.record_step(0, 1.0, 0.1, Some(&m0), 4, 0);
        r.record_step(1, 1.0, 0.1, Some(&m0), 4, 0);
        r.record_step(2, 1.0, 0.1, Some(&m1), 4, 1);
        let snap = r.snapshot();
        assert_eq!(snap.churn_history, vec![(1, 0.5)]);
        assert_eq!(snap.samples[2].churn, 0.5);
        assert_eq!(snap.samples[0].churn, 0.0);
        assert_eq!(snap.samples[1].nonzero, 2);
    }

    #[test]
    fn attribution_and_timings_accumulate() {
        let r = FlightRecorder::new(DEFAULT_BUDGET_BYTES);
        r.note_slice(0.25, 3, &[1]);
        r.note_slice(0.50, 2, &[]);
        r.note_worker_lost(1);
        r.note_replay(0.125);
        let snap = r.snapshot();
        assert_eq!(snap.slices, 2);
        assert_eq!(snap.workers.get(&0), Some(&5));
        assert_eq!(snap.workers.get(&1), Some(&3));
        assert_eq!(snap.worker_lost, 1);
        assert_eq!(snap.slice_seconds, vec![0.25, 0.50]);
        assert_eq!(snap.replay_seconds, vec![0.125]);
    }

    #[test]
    fn timeline_json_series_round_trip_bits() {
        let r = FlightRecorder::new(DEFAULT_BUDGET_BYTES);
        let g = f32::from_bits(0x3f9d_70a4); // an awkward mantissa
        r.record_step(0, 0.6931472, g, None, 10, 0);
        let doc = r.timeline_json();
        let got = doc.req("series").unwrap().req("g").unwrap();
        let Json::Arr(items) = got else { panic!("g series not an array") };
        assert_eq!((items[0].as_f64().unwrap() as f32).to_bits(), g.to_bits());
    }
}
