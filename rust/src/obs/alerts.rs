//! Cheap per-job alert rules over flight-recorder telemetry.
//!
//! MeZO-style training is exactly the regime where silent pathologies
//! burn thousands of forward passes before anyone notices: a loss that
//! quietly diverges under too-aggressive a learning rate (the paper's
//! Fig. 2a failure mode), a worker lease that dies before committing a
//! step, a mask that stopped changing across `mask_refresh` epochs. The
//! [`evaluate_slice`] entry point runs a fixed rule catalog at slice
//! boundaries — O(1) per rule over a [`Snapshot`], no training-path
//! cost — and maintains three surfaces at once:
//!
//! - `alerts_active{job,rule}` gauge (1 while firing, 0 after clearing)
//!   plus `alerts_fired_total{rule}` / `alerts_cleared_total{rule}`
//!   counters on `/metrics`;
//! - `/healthz` degraded status (`alerts_active` count > 0);
//! - job-state annotations (the scheduler copies active rule names into
//!   the queue's job record, so `jobs show` and `GET /v1/jobs/{id}`
//!   carry them).
//!
//! Rule catalog (documented in README "Flight recorder & alerts"):
//!
//! | rule              | fires when                                          |
//! |-------------------|-----------------------------------------------------|
//! | `loss-divergence` | fast loss EWMA > 2× slow EWMA (≥8 steps warmup), or |
//! |                   | the trainer's divergence guard tripped              |
//! | `stall`           | a slice ended with zero committed steps while the   |
//! |                   | job is still runnable (e.g. its lease died first)   |
//! | `worker-flap`     | ≥2 lost-worker events charged to the job            |
//! | `mask-frozen`     | `mask_refresh` is on but the last two refresh       |
//! |                   | epochs measured zero mask churn                     |
//! | `mem-budget-exceeded` | a `--mem-budget BYTES` is set and the slice's   |
//! |                   | heap watermark ([`crate::obs::mem`]) went past it   |

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

use super::recorder::Snapshot;

/// Divergence guard threshold shared with the trainers: a mean loss at
/// or past this is treated as diverged regardless of EWMA warmup.
pub const DIVERGENCE_LOSS: f64 = 9.0;

/// Fast-vs-slow loss EWMA ratio that trips `loss-divergence`.
pub const DIVERGENCE_RATIO: f64 = 2.0;

/// Steps of warmup before the EWMA ratio is trusted.
pub const DIVERGENCE_WARMUP: u64 = 8;

/// Lost-worker events that trip `worker-flap`.
pub const FLAP_THRESHOLD: u64 = 2;

/// Consecutive zero-churn refresh epochs that trip `mask-frozen`.
pub const FROZEN_EPOCHS: usize = 2;

/// One active alert.
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    /// the job the alert is charged to
    pub job: u64,
    /// rule name (one of the catalog above)
    pub rule: &'static str,
    /// human-readable context captured when the rule fired
    pub detail: String,
}

static ACTIVE: OnceLock<Mutex<BTreeMap<(u64, &'static str), Alert>>> = OnceLock::new();

fn active_map() -> &'static Mutex<BTreeMap<(u64, &'static str), Alert>> {
    ACTIVE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Raise `rule` for `job` (idempotent). Returns whether it newly fired.
pub fn fire(job: u64, rule: &'static str, detail: String) -> bool {
    let mut map = active_map().lock().unwrap();
    if map.contains_key(&(job, rule)) {
        return false;
    }
    map.insert((job, rule), Alert { job, rule, detail });
    crate::obs::counter("alerts_fired_total", &[("rule", rule)]).inc();
    crate::obs::gauge("alerts_active", &[("job", &job.to_string()), ("rule", rule)]).set(1);
    crate::info!("[alerts] job {job}: {rule} fired");
    true
}

/// Clear `rule` for `job` (idempotent). Returns whether it was active.
pub fn clear(job: u64, rule: &'static str) -> bool {
    let mut map = active_map().lock().unwrap();
    if map.remove(&(job, rule)).is_none() {
        return false;
    }
    crate::obs::counter("alerts_cleared_total", &[("rule", rule)]).inc();
    crate::obs::gauge("alerts_active", &[("job", &job.to_string()), ("rule", rule)]).set(0);
    crate::info!("[alerts] job {job}: {rule} cleared");
    true
}

/// Clear every rule for `job` (terminal job states). Returns the rules
/// that were still active.
pub fn clear_job(job: u64) -> Vec<&'static str> {
    let rules: Vec<&'static str> = active_map()
        .lock()
        .unwrap()
        .keys()
        .filter(|(j, _)| *j == job)
        .map(|(_, r)| *r)
        .collect();
    for r in &rules {
        clear(job, r);
    }
    rules
}

/// Every currently-active alert, ordered by (job, rule).
pub fn active() -> Vec<Alert> {
    active_map().lock().unwrap().values().cloned().collect()
}

/// Active alerts for one job.
pub fn active_for(job: u64) -> Vec<Alert> {
    active_map()
        .lock()
        .unwrap()
        .values()
        .filter(|a| a.job == job)
        .cloned()
        .collect()
}

/// Count of active alerts across all jobs (`/healthz` degraded signal).
pub fn active_count() -> usize {
    active_map().lock().unwrap().len()
}

/// Active alerts for `job` as a JSON array (`/v1/jobs/{id}/timeline`).
pub fn alerts_json(job: u64) -> Json {
    Json::Arr(
        active_for(job)
            .into_iter()
            .map(|a| {
                Json::obj(vec![
                    ("rule", Json::Str(a.rule.to_string())),
                    ("detail", Json::Str(a.detail)),
                ])
            })
            .collect(),
    )
}

/// What one finished slice looked like, for rule evaluation.
#[derive(Debug, Clone, Copy)]
pub struct SliceObs {
    /// the job the slice belonged to
    pub job: u64,
    /// steps committed by the slice
    pub committed: u64,
    /// whether the job still has steps left to run
    pub runnable: bool,
    /// whether the trainer's divergence guard tripped this slice
    pub diverged: bool,
    /// the job spec's `mask_refresh` (0 = thresholds fixed at init)
    pub mask_refresh: usize,
    /// the slice's heap watermark in bytes (0 = tracking allocator off)
    pub mem_peak_bytes: u64,
}

/// Run the rule catalog against one slice's outcome plus the job's
/// recorder snapshot. Fires/clears rules as side effects; returns the
/// rule names active for the job afterwards (the scheduler copies these
/// into the job record as annotations).
pub fn evaluate_slice(obs: &SliceObs, snap: &Snapshot) -> Vec<&'static str> {
    let job = obs.job;

    // stall: the slice ended without committing anything while the job
    // still wants steps — its lease died before phase B, or the slice
    // never got scheduled work done. Deterministic (no clocks), so CI
    // can force it with the `--max-phase-a` kill hook. The median step
    // time rides along as context for the human reading the alert.
    if obs.committed == 0 && obs.runnable {
        fire(
            job,
            "stall",
            format!(
                "slice committed no steps (median step {:.4}s over {} steps seen)",
                snap.median_step_seconds, snap.seen
            ),
        );
    } else if obs.committed > 0 {
        clear(job, "stall");
    }

    // loss-divergence: trainer guard, non-finite loss, or the fast EWMA
    // running away from the slow one after warmup
    let last_loss = snap.samples.last().map(|s| s.loss as f64);
    let ratio_trip = snap.seen >= DIVERGENCE_WARMUP
        && snap.loss_fast > DIVERGENCE_RATIO * snap.loss_slow.max(1e-12);
    let loss_trip = last_loss.is_some_and(|l| !l.is_finite() || l >= DIVERGENCE_LOSS);
    if obs.diverged || ratio_trip || loss_trip {
        fire(
            job,
            "loss-divergence",
            format!(
                "loss fast-EWMA {:.4} vs slow {:.4} (last {:?}, guard {})",
                snap.loss_fast, snap.loss_slow, last_loss, obs.diverged
            ),
        );
    } else if snap.seen >= DIVERGENCE_WARMUP {
        clear(job, "loss-divergence");
    }

    // worker-flap: repeated lost-worker events charged to this job
    if snap.worker_lost >= FLAP_THRESHOLD {
        fire(
            job,
            "worker-flap",
            format!("{} lost-worker events", snap.worker_lost),
        );
    }

    // mem-budget-exceeded: an operator-supplied heap budget is in force
    // and the slice's measured watermark went past it. Only meaningful
    // with the tracking allocator installed (watermark 0 never fires).
    let budget = crate::obs::mem::budget();
    if budget > 0 {
        if obs.mem_peak_bytes > budget {
            fire(
                job,
                "mem-budget-exceeded",
                format!(
                    "slice heap peak {} bytes > budget {} bytes",
                    obs.mem_peak_bytes, budget
                ),
            );
        } else if obs.mem_peak_bytes > 0 {
            clear(job, "mem-budget-exceeded");
        }
    }

    // mask-frozen: refreshes are on but the mask stopped moving
    if obs.mask_refresh > 0 && snap.churn_history.len() >= FROZEN_EPOCHS {
        let tail = &snap.churn_history[snap.churn_history.len() - FROZEN_EPOCHS..];
        if tail.iter().all(|(_, c)| *c == 0.0) {
            fire(
                job,
                "mask-frozen",
                format!("zero churn across the last {FROZEN_EPOCHS} refresh epochs"),
            );
        } else {
            clear(job, "mask-frozen");
        }
    }

    let mut rules: Vec<&'static str> = active_for(job).iter().map(|a| a.rule).collect();
    rules.sort_unstable();
    rules
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::FlightRecorder;

    /// Unique job ids per test: the alert map is process-global.
    fn snap_of(rec: &FlightRecorder) -> Snapshot {
        rec.snapshot()
    }

    #[test]
    fn stall_fires_on_empty_slice_and_clears_on_progress() {
        let job = 9_001;
        let rec = FlightRecorder::new(4096);
        let obs = SliceObs {
            job,
            committed: 0,
            runnable: true,
            diverged: false,
            mask_refresh: 0,
            mem_peak_bytes: 0,
        };
        let rules = evaluate_slice(&obs, &snap_of(&rec));
        assert!(rules.contains(&"stall"), "{rules:?}");
        assert!(active_for(job).iter().any(|a| a.rule == "stall"));
        let obs = SliceObs { committed: 3, ..obs };
        let rules = evaluate_slice(&obs, &snap_of(&rec));
        assert!(!rules.contains(&"stall"), "{rules:?}");
        assert_eq!(active_for(job), vec![]);
        // gauge survives as an explicit 0 (fired-then-cleared is visible)
        assert_eq!(
            crate::obs::gauge("alerts_active", &[("job", "9001"), ("rule", "stall")]).get(),
            0
        );
    }

    #[test]
    fn divergence_fires_on_runaway_fast_ewma() {
        let job = 9_002;
        let rec = FlightRecorder::new(4096);
        for step in 0..DIVERGENCE_WARMUP as u32 {
            rec.record_step(step, 0.7, 0.1, None, 8, 0);
        }
        let obs = SliceObs {
            job,
            committed: 8,
            runnable: true,
            diverged: false,
            mask_refresh: 0,
            mem_peak_bytes: 0,
        };
        assert!(evaluate_slice(&obs, &snap_of(&rec)).is_empty());
        for step in 8..16 {
            rec.record_step(step, 6.0, 0.1, None, 8, 0);
        }
        let rules = evaluate_slice(&obs, &snap_of(&rec));
        assert!(rules.contains(&"loss-divergence"), "{rules:?}");
        clear_job(job);
    }

    #[test]
    fn flap_and_frozen_rules() {
        let job = 9_003;
        let rec = FlightRecorder::new(4096);
        rec.note_worker_lost(1);
        rec.note_worker_lost(1);
        let m = vec![1u8, 0, 1, 0];
        rec.record_step(0, 0.5, 0.1, Some(&m), 4, 0);
        rec.record_step(1, 0.5, 0.1, Some(&m), 4, 1); // zero churn
        rec.record_step(2, 0.5, 0.1, Some(&m), 4, 2); // zero churn
        let obs = SliceObs {
            job,
            committed: 3,
            runnable: true,
            diverged: false,
            mask_refresh: 1,
            mem_peak_bytes: 0,
        };
        let rules = evaluate_slice(&obs, &snap_of(&rec));
        assert!(rules.contains(&"worker-flap"), "{rules:?}");
        assert!(rules.contains(&"mask-frozen"), "{rules:?}");
        assert_eq!(clear_job(job).len(), 2);
        assert_eq!(active_count_for_test(job), 0);
    }

    fn active_count_for_test(job: u64) -> usize {
        active_for(job).len()
    }

    #[test]
    fn mem_budget_rule_fires_and_clears() {
        let _serial = crate::obs::mem::BUDGET_TEST_LOCK.lock().unwrap();
        let job = 9_005;
        let rec = FlightRecorder::new(4096);
        let base = SliceObs {
            job,
            committed: 3,
            runnable: true,
            diverged: false,
            mask_refresh: 0,
            mem_peak_bytes: 0,
        };
        // no budget set: watermark past anything still never fires
        crate::obs::mem::set_budget(0);
        let obs = SliceObs { mem_peak_bytes: u64::MAX, ..base };
        assert!(evaluate_slice(&obs, &snap_of(&rec)).is_empty());
        // budget in force: over fires, back under clears to explicit 0
        crate::obs::mem::set_budget(1_000);
        let obs = SliceObs { mem_peak_bytes: 2_000, ..base };
        let rules = evaluate_slice(&obs, &snap_of(&rec));
        assert!(rules.contains(&"mem-budget-exceeded"), "{rules:?}");
        let obs = SliceObs { mem_peak_bytes: 500, ..base };
        let rules = evaluate_slice(&obs, &snap_of(&rec));
        assert!(!rules.contains(&"mem-budget-exceeded"), "{rules:?}");
        // a 0 watermark (allocator off) neither fires nor clears
        let obs = SliceObs { mem_peak_bytes: 0, ..base };
        assert!(evaluate_slice(&obs, &snap_of(&rec)).is_empty());
        crate::obs::mem::set_budget(0);
        clear_job(job);
    }

    #[test]
    fn clear_job_is_idempotent_and_scoped() {
        let job = 9_004;
        fire(job, "stall", "test".into());
        fire(job + 1, "stall", "test".into());
        assert_eq!(clear_job(job), vec!["stall"]);
        assert!(clear_job(job).is_empty());
        assert_eq!(active_for(job + 1).len(), 1);
        clear_job(job + 1);
    }
}
